"""``repro.insitu`` — the public in-situ API, one import for everything.

Declare workflows with :class:`InSituPlan` (streams + triggers + task
bindings, loadable from a plain dict) and run them with :class:`Session`::

    from repro import insitu

    plan = insitu.InSituPlan.from_dict({
        "streams": ["grads", "train_state"],
        "tasks": {
            "grad_health": {"stream": "grads", "preset": "grad_health",
                            "every": 10},
            "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                           "every": 50,
                           "options": {"directory": "/tmp/ckpt"}},
        },
    })
    with insitu.Session(plan) as session:
        for step in range(n_steps):
            state = device_step(state)
            session.emit("grads", step, lambda: summarize(state))
            session.emit("train_state", step, lambda: state)

See ``repro/core/session.py`` for the full semantics. The legacy entry
points (``InSituEngine``, ``run_workflow``, ``run_pipeline``) remain as
deprecation shims in ``repro.core``.
"""
from repro.core.runtime import (FanoutStage, Placement, Stage,
                                TransientError)
from repro.core.session import (Adaptive, Every, InSituPlan, InSituTaskError,
                                Interval, PlanError, Session, StreamSpec,
                                TaskSpec, Trigger, When, preset_names,
                                register_preset)
from repro.core.transport import (CallableSink, FileSink, FileSource, Frame,
                                  FrameCorruptError, MemorySink, Sink, Source,
                                  StreamGapError, StreamSink, StreamSource,
                                  TransportError, as_sink, connect,
                                  decode_frame_payload)
from repro.distributed.fault import (ElasticRestore, FaultController,
                                     plan_elastic_remesh)

__all__ = [
    "Adaptive", "ElasticRestore", "Every", "FanoutStage", "FaultController",
    "InSituPlan", "InSituTaskError", "Interval", "Placement", "PlanError",
    "Session", "Stage", "StreamSpec", "TaskSpec", "TransientError", "Trigger",
    "When", "plan_elastic_remesh", "preset_names", "register_preset",
    "CallableSink", "FileSink", "FileSource", "Frame", "FrameCorruptError",
    "MemorySink", "Sink", "Source", "StreamGapError", "StreamSink",
    "StreamSource", "TransportError", "as_sink", "connect",
    "decode_frame_payload",
]
