"""Pallas TPU kernels for the spectral lossy codec (hybrid in-situ, §IV-B).

The paper's hybrid mode runs the physics-based lossy compression *on the
accelerator* (deeply coupled with NEKO) and only ships the reduced data to the
host for lossless coding. Its GPU implementation is dominated by two sort
kernels (finding F7) — a poor fit for the TPU, which has no efficient global
sort in the VPU. The TPU-native redesign (see kernels/ref.py for the oracle):

  kernel 1 (dct_hist_coarse): Y = X @ D^T on the MXU, and a one-pass absolute
                             log2-|Y| COARSE histogram (32 bins, each covering
                             16 fine bins) of (count, energy), accumulated
                             across the grid — sort-free selection statistics.
                             Binning is computed as mat-vecs against a one-hot
                             bin matrix, so even the "scatter" is MXU work.
  kernel 1b (hist_refine):   fine (count, energy) histogram of the 16 fine
                             bins inside the one coarse bin that straddles the
                             eps^2 energy budget. Together with the coarse
                             pass this is O(elements x 48) binning FLOPs at
                             the full 512-bin threshold resolution; the flat
                             O(elements x 512) ``dct_hist`` kernel is kept as
                             the reference/benchmark baseline.
  select (cheap, in-graph):  threshold = largest fine bin edge whose
                             below-edge cumulative energy fits the eps^2
                             budget (ref.select_coarse / ref.select_fine).
  kernel 2 (threshold_quant): zero sub-threshold coeffs, int8-quantize with a
                             per-block scale.
  kernel 3 (dequant_idct):   decompression, X̂ = (q * scale) @ D.

Tiling: blocks are BLOCK=256 wide (2 x 128 lanes; the DCT matmul contraction
dim is 256 — MXU-aligned). The flat histogram kernel uses a small block-tile
(8) so its (elements x NBINS) one-hot stays ~4 MB in VMEM; quant/dequant
kernels use 64-block tiles (64 x 256 f32 = 64 KB per operand). Every kernel
takes a ``tile=`` override so ``ops.py`` can swap in an autotuned tile per
power-of-two shape bucket; buffers whose block count is not a tile multiple
are zero-padded up to it and the result sliced back (a prime block count must
never silently degrade the launch to single-block grid steps).

All kernels run under interpret=True on CPU (tests/CI) and compile for TPU
unchanged; ``ops.py`` picks the mode from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (BLOCK, LOG2_HI, LOG2_LO, NBINS, NBINS_COARSE,
                               NBINS_FINE, dct_matrix)

HIST_TILE = 8      # blocks per grid step in the histogram passes
QUANT_TILE = 64    # blocks per grid step in quant/dequant passes


def _check_blocks(xb: jax.Array, tile: int, name: str) -> None:
    """Loud shape validation (a bare assert would vanish under python -O)."""
    if xb.ndim != 2 or xb.shape[1] != BLOCK:
        raise ValueError(
            f"{name}: expected a (n_blocks, {BLOCK}) blocked buffer, got "
            f"shape {tuple(xb.shape)}")
    if xb.shape[0] % tile:
        raise ValueError(
            f"{name}: n_blocks={xb.shape[0]} must be a multiple of the "
            f"{tile}-block tile (pad with ops._pad_blocks first)")


def _pad_rows(buf: jax.Array, pad: int, value: float = 0.0) -> jax.Array:
    if not pad:
        return buf
    width = ((0, pad), (0, 0)) if buf.ndim == 2 else ((0, pad),)
    return jnp.pad(buf, width, constant_values=value)


def _tile_and_pad(n_blocks: int, want: int) -> tuple[int, int]:
    """Full-width tile for an arbitrary block count: never shrink the tile
    to a divisor (a prime ``n_blocks`` used to degrade to tile=1 — an
    n_blocks-step grid of single-block kernel invocations); instead the
    caller zero-pads to the next tile multiple and slices the result."""
    tile = max(1, min(want, n_blocks))
    return tile, (-n_blocks) % tile


def _bin_idx(a: jax.Array) -> jax.Array:
    """Flat 512-level bin index (same math as ref.bin_index; the coarse
    kernel derives coarse bins by integer division so coarse/fine binning
    can never disagree near a bin boundary)."""
    lg = jnp.where(a > 0, jnp.log2(jnp.maximum(a, 1e-38)), LOG2_LO)
    return jnp.clip(((lg - LOG2_LO) * (NBINS / (LOG2_HI - LOG2_LO)))
                    .astype(jnp.int32), 0, NBINS - 1)


# ---------------------------------------------------------------------------
# kernel 1: DCT + histogram accumulation
# ---------------------------------------------------------------------------

def _dct_and_bins(x_ref, d_ref, y_ref):
    """Shared kernel prologue: DCT matmul + flat bin indices of the tile."""
    x = x_ref[...].astype(jnp.float32)          # (TILE, BLOCK)
    d = d_ref[...]                              # (BLOCK, BLOCK)
    y = jax.lax.dot_general(                    # y = x @ d.T   (MXU)
        x, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = y
    a = jnp.abs(y.reshape(-1))                  # (TILE*BLOCK,)
    return a * a, _bin_idx(a)


def _onehot_hist(a2, idx, nbins):
    """One-hot binning as matmul work (no scatter on the VPU)."""
    bins = jax.lax.broadcasted_iota(jnp.int32, (a2.shape[0], nbins), 1)
    onehot = (idx[:, None] == bins).astype(jnp.float32)
    cnt = jnp.sum(onehot, axis=0)
    eng = jax.lax.dot_general(
        a2, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return cnt, eng


def _dct_hist_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        eng_ref[...] = jnp.zeros_like(eng_ref)

    a2, idx = _dct_and_bins(x_ref, d_ref, y_ref)
    cnt, eng = _onehot_hist(a2, idx, NBINS)
    cnt_ref[...] += cnt
    eng_ref[...] += eng


def dct_hist(xb: jax.Array, *, interpret: bool = True,
             tile: int | None = None):
    """xb: (n_blocks, BLOCK) f32 -> (y, counts, energies).

    The flat 512-bin histogram pass — kept as the baseline the two-level
    (``dct_hist_coarse`` + ``hist_refine``) pair is benchmarked against.
    """
    tile = tile or HIST_TILE
    _check_blocks(xb, tile, "dct_hist")
    n_blocks = xb.shape[0]
    d = jnp.asarray(dct_matrix(BLOCK))
    grid = (n_blocks // tile,)
    return pl.pallas_call(
        _dct_hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((NBINS,), lambda i: (0,)),
            pl.BlockSpec((NBINS,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((NBINS,), jnp.float32),
            jax.ShapeDtypeStruct((NBINS,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


# ---------------------------------------------------------------------------
# kernel 1 (two-level): DCT + coarse 32-bin histogram
# ---------------------------------------------------------------------------

def _dct_hist_coarse_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        eng_ref[...] = jnp.zeros_like(eng_ref)

    a2, idx = _dct_and_bins(x_ref, d_ref, y_ref)
    cnt, eng = _onehot_hist(a2, idx // NBINS_FINE, NBINS_COARSE)
    cnt_ref[...] += cnt
    eng_ref[...] += eng


def dct_hist_coarse(xb: jax.Array, *, interpret: bool = True,
                    tile: int | None = None):
    """xb: (n_blocks, BLOCK) f32 -> (y, counts (32,), energies (32,)).

    First pass of the two-level selector: same DCT matmul as ``dct_hist``
    but the one-hot binning runs against 32 coarse bins (each covering 16
    fine bins of the flat histogram) — O(elements x 32) binning FLOPs.
    """
    tile = tile or HIST_TILE
    _check_blocks(xb, tile, "dct_hist_coarse")
    n_blocks = xb.shape[0]
    d = jnp.asarray(dct_matrix(BLOCK))
    return pl.pallas_call(
        _dct_hist_coarse_kernel,
        grid=(n_blocks // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((NBINS_COARSE,), lambda i: (0,)),
            pl.BlockSpec((NBINS_COARSE,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((NBINS_COARSE,), jnp.float32),
            jax.ShapeDtypeStruct((NBINS_COARSE,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


# ---------------------------------------------------------------------------
# kernel 1r (two-level): fine refine histogram inside one coarse bin
# ---------------------------------------------------------------------------

def _hist_refine_kernel(y_ref, c_ref, cnt_ref, eng_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        eng_ref[...] = jnp.zeros_like(eng_ref)

    y = y_ref[...]                               # (TILE, BLOCK)
    a = jnp.abs(y)
    idx = _bin_idx(a)                            # (TILE, BLOCK) flat bins
    c = c_ref[...][:, None]                      # (TILE, 1) coarse bin/block
    member = (idx // NBINS_FINE) == c
    fine = jnp.where(member, idx - c * NBINS_FINE, 0).reshape(-1)
    w = member.astype(jnp.float32).reshape(-1)
    a2 = (a * a).reshape(-1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (fine.shape[0], NBINS_FINE), 1)
    onehot = (fine[:, None] == bins).astype(jnp.float32) * w[:, None]
    cnt_ref[...] += jnp.sum(onehot, axis=0)
    eng_ref[...] += jax.lax.dot_general(
        a2, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def hist_refine(y: jax.Array, coarse: jax.Array, *, interpret: bool = True,
                tile: int | None = None):
    """y: (n_blocks, BLOCK) DCT coefficients, coarse: per-block coarse bin
    (scalar or (n_blocks,) int32 — per-block so one invocation refines a
    packed multi-leaf buffer) -> (counts (16,), energies (16,)).

    Second pass of the two-level selector: fine (count, energy) histogram
    of the 16 fine bins inside each block's coarse bin — O(elements x 16)
    binning FLOPs. Elements outside the coarse bin contribute exactly 0.0,
    so each fine energy is bitwise the flat histogram's bin 16*coarse+k.
    """
    tile = tile or HIST_TILE
    _check_blocks(y, tile, "hist_refine")
    n_blocks = y.shape[0]
    coarse = jnp.asarray(coarse, jnp.int32)
    if coarse.ndim == 0 or coarse.size == 1:
        coarse = jnp.broadcast_to(coarse.reshape(()), (n_blocks,))
    return pl.pallas_call(
        _hist_refine_kernel,
        grid=(n_blocks // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((NBINS_FINE,), lambda i: (0,)),
            pl.BlockSpec((NBINS_FINE,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NBINS_FINE,), jnp.float32),
            jax.ShapeDtypeStruct((NBINS_FINE,), jnp.float32),
        ],
        interpret=interpret,
    )(y, coarse)


# ---------------------------------------------------------------------------
# kernel 1b: DCT + per-tile histogram (fused-tree variant)
# ---------------------------------------------------------------------------
#
# Same DCT matmul and one-hot binning as kernel 1, but instead of
# accumulating one global histogram across the grid, each grid step writes
# its own (count, energy) row. The caller segment-sums tile rows back to
# per-leaf histograms — which is how ONE kernel invocation over a packed
# multi-leaf buffer still yields per-leaf thresholds (leaves are padded to
# HIST_TILE multiples before packing, so no tile straddles two leaves).

def _dct_hist_tiled_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    a2, idx = _dct_and_bins(x_ref, d_ref, y_ref)
    cnt, eng = _onehot_hist(a2, idx, NBINS)
    cnt_ref[...] = cnt[None]
    eng_ref[...] = eng[None]


def dct_hist_tiled(xb: jax.Array, *, interpret: bool = True,
                   tile: int | None = None):
    """xb: (n_blocks, BLOCK) f32 -> (y, counts (n_tiles, NBINS), energies)."""
    tile = tile or HIST_TILE
    _check_blocks(xb, tile, "dct_hist_tiled")
    n_blocks = xb.shape[0]
    d = jnp.asarray(dct_matrix(BLOCK))
    n_tiles = n_blocks // tile
    return pl.pallas_call(
        _dct_hist_tiled_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


def _dct_hist_coarse_tiled_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    a2, idx = _dct_and_bins(x_ref, d_ref, y_ref)
    cnt, eng = _onehot_hist(a2, idx // NBINS_FINE, NBINS_COARSE)
    cnt_ref[...] = cnt[None]
    eng_ref[...] = eng[None]


def dct_hist_coarse_tiled(xb: jax.Array, *, interpret: bool = True,
                          tile: int | None = None):
    """xb -> (y, counts (n_tiles, 32), energies (n_tiles, 32)).

    Per-tile coarse histograms for the fused multi-leaf dispatch: the
    caller segment-sums tile rows back to per-leaf coarse histograms
    (leaves are padded to tile multiples, so no tile straddles two leaves).
    """
    tile = tile or HIST_TILE
    _check_blocks(xb, tile, "dct_hist_coarse_tiled")
    n_blocks = xb.shape[0]
    d = jnp.asarray(dct_matrix(BLOCK))
    n_tiles = n_blocks // tile
    return pl.pallas_call(
        _dct_hist_coarse_tiled_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS_COARSE), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS_COARSE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS_COARSE), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS_COARSE), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


def _hist_refine_tiled_kernel(y_ref, c_ref, cnt_ref, eng_ref):
    y = y_ref[...]
    a = jnp.abs(y)
    idx = _bin_idx(a)
    c = c_ref[...][:, None]
    member = (idx // NBINS_FINE) == c
    fine = jnp.where(member, idx - c * NBINS_FINE, 0).reshape(-1)
    w = member.astype(jnp.float32).reshape(-1)
    a2 = (a * a).reshape(-1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (fine.shape[0], NBINS_FINE), 1)
    onehot = (fine[:, None] == bins).astype(jnp.float32) * w[:, None]
    cnt_ref[...] = jnp.sum(onehot, axis=0)[None]
    eng_ref[...] = jax.lax.dot_general(
        a2, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]


def hist_refine_tiled(y: jax.Array, coarse: jax.Array, *,
                      interpret: bool = True, tile: int | None = None):
    """y: (n_blocks, BLOCK), coarse: (n_blocks,) int32 per-block coarse bin
    -> (counts (n_tiles, 16), energies (n_tiles, 16)).

    Tiled refine pass for the fused multi-leaf dispatch: every block of a
    leaf carries the leaf's selected coarse bin, tile rows segment-sum back
    to per-leaf fine histograms.
    """
    tile = tile or HIST_TILE
    _check_blocks(y, tile, "hist_refine_tiled")
    n_blocks = y.shape[0]
    n_tiles = n_blocks // tile
    coarse = jnp.asarray(coarse, jnp.int32)
    if coarse.ndim == 0 or coarse.size == 1:
        coarse = jnp.broadcast_to(coarse.reshape(()), (n_blocks,))
    return pl.pallas_call(
        _hist_refine_tiled_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, NBINS_FINE), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS_FINE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, NBINS_FINE), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS_FINE), jnp.float32),
        ],
        interpret=interpret,
    )(y, coarse)


# ---------------------------------------------------------------------------
# kernel 2: threshold + int8 quantize
# ---------------------------------------------------------------------------

def _threshold_quant_kernel(y_ref, t_ref, q_ref, s_ref):
    y = y_ref[...]                               # (TILE, BLOCK) f32
    t = t_ref[...][:, None]                      # (TILE, 1) per-block threshold
    kept = jnp.where(jnp.abs(y) >= t, y, 0.0)
    amax = jnp.max(jnp.abs(kept), axis=-1)       # (TILE,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(kept / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def threshold_quant(y: jax.Array, t: jax.Array, *, interpret: bool = True,
                    tile: int | None = None):
    """``t`` is a scalar threshold or a per-block (n_blocks,) vector — the
    latter lets one invocation quantize a packed multi-leaf buffer where
    every leaf carries its own eps-derived threshold.

    Block counts that are not a tile multiple are zero-padded up to it and
    the pad rows sliced off the result (each block quantizes independently,
    so real rows are bit-identical); the tile itself is never shrunk.
    """
    if y.ndim != 2 or y.shape[1] != BLOCK:
        raise ValueError(
            f"threshold_quant: expected (n_blocks, {BLOCK}) coefficients, "
            f"got shape {tuple(y.shape)}")
    n_blocks = y.shape[0]
    tile, pad = _tile_and_pad(n_blocks, tile or QUANT_TILE)
    t = jnp.asarray(t, jnp.float32)
    if t.ndim == 0 or t.size == 1:
        t = jnp.broadcast_to(t.reshape(()), (n_blocks,))
    y = _pad_rows(y, pad)
    t = _pad_rows(t, pad)
    q, s = pl.pallas_call(
        _threshold_quant_kernel,
        grid=((n_blocks + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks + pad, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks + pad,), jnp.float32),
        ],
        interpret=interpret,
    )(y, t)
    return (q[:n_blocks], s[:n_blocks]) if pad else (q, s)


# ---------------------------------------------------------------------------
# kernel 3: dequantize + inverse DCT
# ---------------------------------------------------------------------------

def _dequant_idct_kernel(q_ref, s_ref, d_ref, x_ref):
    y = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]
    x_ref[...] = jax.lax.dot_general(            # x = y @ d    (MXU)
        y, d_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def dequant_idct(q: jax.Array, scale: jax.Array, *, interpret: bool = True,
                 tile: int | None = None):
    if q.ndim != 2 or q.shape[1] != BLOCK:
        raise ValueError(
            f"dequant_idct: expected (n_blocks, {BLOCK}) int8 coefficients, "
            f"got shape {tuple(q.shape)}")
    n_blocks = q.shape[0]
    tile, pad = _tile_and_pad(n_blocks, tile or QUANT_TILE)
    d = jnp.asarray(dct_matrix(BLOCK))
    q = _pad_rows(q, pad)
    scale = _pad_rows(scale, pad, 1.0)   # pad rows dequantize 0*1 -> 0
    x = pl.pallas_call(
        _dequant_idct_kernel,
        grid=((n_blocks + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks + pad, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, scale, d)
    return x[:n_blocks] if pad else x
