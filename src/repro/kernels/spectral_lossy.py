"""Pallas TPU kernels for the spectral lossy codec (hybrid in-situ, §IV-B).

The paper's hybrid mode runs the physics-based lossy compression *on the
accelerator* (deeply coupled with NEKO) and only ships the reduced data to the
host for lossless coding. Its GPU implementation is dominated by two sort
kernels (finding F7) — a poor fit for the TPU, which has no efficient global
sort in the VPU. The TPU-native redesign (see kernels/ref.py for the oracle):

  kernel 1 (dct_hist):       Y = X @ D^T on the MXU, and a one-pass absolute
                             log2-|Y| histogram of (count, energy) per bin,
                             accumulated across the grid — sort-free selection
                             statistics. Histogram binning is computed as two
                             mat-vecs against a one-hot bin matrix, so even the
                             "scatter" is MXU work.
  host (cheap, O(NBINS)):    threshold = largest bin edge whose below-edge
                             cumulative energy fits the eps^2 budget.
  kernel 2 (threshold_quant): zero sub-threshold coeffs, int8-quantize with a
                             per-block scale.
  kernel 3 (dequant_idct):   decompression, X̂ = (q * scale) @ D.

Tiling: blocks are BLOCK=256 wide (2 x 128 lanes; the DCT matmul contraction
dim is 256 — MXU-aligned). The histogram kernel uses a small block-tile (8)
so its (elements x NBINS) one-hot stays ~4 MB in VMEM; quant/dequant kernels
use 64-block tiles (64 x 256 f32 = 64 KB per operand).

All kernels run under interpret=True on CPU (tests/CI) and compile for TPU
unchanged; ``ops.py`` picks the mode from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (BLOCK, LOG2_HI, LOG2_LO, NBINS, dct_matrix)

HIST_TILE = 8      # blocks per grid step in the histogram pass
QUANT_TILE = 64    # blocks per grid step in quant/dequant passes


def _pick_tile(n_blocks: int, want: int) -> int:
    t = min(want, n_blocks)
    while n_blocks % t:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# kernel 1: DCT + histogram accumulation
# ---------------------------------------------------------------------------

def _dct_hist_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        eng_ref[...] = jnp.zeros_like(eng_ref)

    x = x_ref[...].astype(jnp.float32)          # (TILE, BLOCK)
    d = d_ref[...]                              # (BLOCK, BLOCK)
    y = jax.lax.dot_general(                    # y = x @ d.T   (MXU)
        x, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = y

    a = jnp.abs(y.reshape(-1))                  # (TILE*BLOCK,)
    a2 = a * a
    lg = jnp.where(a > 0, jnp.log2(jnp.maximum(a, 1e-38)), LOG2_LO)
    idx = jnp.clip(((lg - LOG2_LO) * (NBINS / (LOG2_HI - LOG2_LO)))
                   .astype(jnp.int32), 0, NBINS - 1)
    # one-hot binning as matmul work (no scatter on the VPU)
    bins = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], NBINS), 1)
    onehot = (idx[:, None] == bins).astype(jnp.float32)
    cnt_ref[...] += jnp.sum(onehot, axis=0)
    eng_ref[...] += jax.lax.dot_general(
        a2, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def dct_hist(xb: jax.Array, *, interpret: bool = True):
    """xb: (n_blocks, BLOCK) f32 -> (y, counts, energies)."""
    n_blocks = xb.shape[0]
    assert n_blocks % HIST_TILE == 0 and xb.shape[1] == BLOCK
    d = jnp.asarray(dct_matrix(BLOCK))
    grid = (n_blocks // HIST_TILE,)
    return pl.pallas_call(
        _dct_hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((HIST_TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((HIST_TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((NBINS,), lambda i: (0,)),
            pl.BlockSpec((NBINS,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((NBINS,), jnp.float32),
            jax.ShapeDtypeStruct((NBINS,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


# ---------------------------------------------------------------------------
# kernel 1b: DCT + per-tile histogram (fused-tree variant)
# ---------------------------------------------------------------------------
#
# Same DCT matmul and one-hot binning as kernel 1, but instead of
# accumulating one global histogram across the grid, each grid step writes
# its own (count, energy) row. The caller segment-sums tile rows back to
# per-leaf histograms — which is how ONE kernel invocation over a packed
# multi-leaf buffer still yields per-leaf thresholds (leaves are padded to
# HIST_TILE multiples before packing, so no tile straddles two leaves).

def _dct_hist_tiled_kernel(x_ref, d_ref, y_ref, cnt_ref, eng_ref):
    x = x_ref[...].astype(jnp.float32)          # (TILE, BLOCK)
    d = d_ref[...]                              # (BLOCK, BLOCK)
    y = jax.lax.dot_general(                    # y = x @ d.T   (MXU)
        x, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = y

    a = jnp.abs(y.reshape(-1))                  # (TILE*BLOCK,)
    a2 = a * a
    lg = jnp.where(a > 0, jnp.log2(jnp.maximum(a, 1e-38)), LOG2_LO)
    idx = jnp.clip(((lg - LOG2_LO) * (NBINS / (LOG2_HI - LOG2_LO)))
                   .astype(jnp.int32), 0, NBINS - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], NBINS), 1)
    onehot = (idx[:, None] == bins).astype(jnp.float32)
    cnt_ref[...] = jnp.sum(onehot, axis=0)[None]
    eng_ref[...] = jax.lax.dot_general(
        a2, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]


def dct_hist_tiled(xb: jax.Array, *, interpret: bool = True):
    """xb: (n_blocks, BLOCK) f32 -> (y, counts (n_tiles, NBINS), energies)."""
    n_blocks = xb.shape[0]
    assert n_blocks % HIST_TILE == 0 and xb.shape[1] == BLOCK
    d = jnp.asarray(dct_matrix(BLOCK))
    n_tiles = n_blocks // HIST_TILE
    return pl.pallas_call(
        _dct_hist_tiled_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((HIST_TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((HIST_TILE, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, NBINS), jnp.float32),
        ],
        interpret=interpret,
    )(xb, d)


# ---------------------------------------------------------------------------
# kernel 2: threshold + int8 quantize
# ---------------------------------------------------------------------------

def _threshold_quant_kernel(y_ref, t_ref, q_ref, s_ref):
    y = y_ref[...]                               # (TILE, BLOCK) f32
    t = t_ref[...][:, None]                      # (TILE, 1) per-block threshold
    kept = jnp.where(jnp.abs(y) >= t, y, 0.0)
    amax = jnp.max(jnp.abs(kept), axis=-1)       # (TILE,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(kept / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def threshold_quant(y: jax.Array, t: jax.Array, *, interpret: bool = True):
    """``t`` is a scalar threshold or a per-block (n_blocks,) vector — the
    latter lets one invocation quantize a packed multi-leaf buffer where
    every leaf carries its own eps-derived threshold."""
    n_blocks = y.shape[0]
    tile = _pick_tile(n_blocks, QUANT_TILE)
    t = jnp.asarray(t, jnp.float32)
    if t.ndim == 0 or t.size == 1:
        t = jnp.broadcast_to(t.reshape(()), (n_blocks,))
    return pl.pallas_call(
        _threshold_quant_kernel,
        grid=(n_blocks // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(y, t)


# ---------------------------------------------------------------------------
# kernel 3: dequantize + inverse DCT
# ---------------------------------------------------------------------------

def _dequant_idct_kernel(q_ref, s_ref, d_ref, x_ref):
    y = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]
    x_ref[...] = jax.lax.dot_general(            # x = y @ d    (MXU)
        y, d_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def dequant_idct(q: jax.Array, scale: jax.Array, *, interpret: bool = True):
    n_blocks = q.shape[0]
    tile = _pick_tile(n_blocks, QUANT_TILE)
    d = jnp.asarray(dct_matrix(BLOCK))
    return pl.pallas_call(
        _dequant_idct_kernel,
        grid=(n_blocks // tile,),
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, scale, d)
