"""Fused gather + online-softmax decode attention over a paged KV cache.

The jnp reference path in ``models/attention.paged_decode_attention``
materializes the gathered cache — ``k_pages[page_table]`` allocates a
(B, P, page_size, N, D) copy in HBM every decode step, i.e. the whole
*logical* cache is re-written once per token just to feed one (B,1) query.
This kernel keeps the pool in place: the grid is one program per request
row, the page table rides in as scalar prefetch (available before the body
runs, the standard paged-attention trick), and each program walks its own
page chain with the flash-style online-softmax recurrence — live memory is
one (page_size, N, D) tile per step instead of the gathered sequence.

Positions past ``length`` are masked to NEG_INF exactly like the dense
slab's padding, so scratch/stale pages never contribute. Numerics match the
gather path to float tolerance (the accumulation order differs: per-page
online softmax vs one full-row softmax), so the engine keeps the gather
path wherever bitwise parity with the dense engine is asserted — this
kernel is the TPU fast path.

Off-TPU this runs in interpret mode (kernel tests); on TPU it compiles
natively.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   *, pages_per_seq: int, page_size: int, n_kv: int,
                   group: int, d_v: int):
    b = pl.program_id(0)
    length = len_ref[b]
    hq = n_kv * group
    d_k = q_ref.shape[-1]
    scale = 1.0 / math.sqrt(d_k)
    q3 = q_ref[0, 0].reshape(n_kv, group, d_k).astype(jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        page = table_ref[b * pages_per_seq + j]
        k = k_ref[pl.ds(page, 1)][0].astype(jnp.float32)   # (PS, N, Dk)
        v = v_ref[pl.ds(page, 1)][0].astype(jnp.float32)   # (PS, N, Dv)
        # (N,G,D) x (PS,N,D) -> (N,G,PS), batched over kv heads
        s = jax.lax.dot_general(
            q3, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        # (N,G,PS) x (PS,N,Dv) -> (N,G,Dv)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr + pv

    m0 = jnp.full((n_kv, group, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n_kv, group, 1), jnp.float32)
    a0 = jnp.zeros((n_kv, group, d_v), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, pages_per_seq, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-37)
    o_ref[0, 0] = out.reshape(hq, d_v).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,            # (B, 1, Hq, Dk)
    k_pages: jax.Array,      # (num_pages, page_size, N, Dk)
    v_pages: jax.Array,      # (num_pages, page_size, N, Dv)
    page_table: jax.Array,   # (B, P) int32
    length: jax.Array,       # (B,) valid prefix length
    *,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, _, hq, _ = q.shape
    _, page_size, n_kv, d_k = k_pages.shape
    d_v = v_pages.shape[-1]
    pages_per_seq = page_table.shape[1]
    kernel = functools.partial(
        _decode_kernel, pages_per_seq=pages_per_seq, page_size=page_size,
        n_kv=n_kv, group=hq // n_kv, d_v=d_v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # page table + lengths in SMEM
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, hq, d_k), lambda i, *_: (i, 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, hq, d_v), lambda i, *_: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, d_v), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(page_table.reshape(-1).astype(jnp.int32),
      length.astype(jnp.int32), q, k_pages, v_pages)
