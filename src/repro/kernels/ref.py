"""Pure-jnp oracle for the spectral lossy codec (+ the sort-based reference).

Pipeline (TPU-native adaptation of NEKO's physics-based lossy compression,
Otero et al. 2018 / paper §IV-B):

  1. blockize: flatten + zero-pad the tensor to (n_blocks, B), B=256
  2. transform: orthonormal DCT-II per block, recast as a matmul (MXU)
  3. select:   keep only the most *energetic* coefficients, subject to a
               relative-L2 error budget eps — discarded energy <= eps^2 * total
  4. quantize: survivors -> int8 with a per-block scale

The paper's GPU implementation selects by *sorting* coefficient magnitudes
(its two dominant kernels are sorts, §IV-B/NSight — finding F7). Sorts are a
poor fit for the TPU VPU, so the deployed kernel selects by *histogram
threshold*: one pass builds an absolute log2-magnitude histogram of
(count, energy) per bin; the threshold is the largest bin edge whose
below-edge cumulative energy fits the budget. That is sort-free, one extra
reduction pass, and conservative (never discards more energy than the sorted
selection would at the same threshold).

This module is the *oracle*: straight-line jnp, no tiling, plus the exact
sort-based selector so tests can prove

  energy(discarded by histogram-select) <= budget <= energy kept by sort-select
  and  |kept_hist| >= |kept_sort at same budget|  (conservatism, bin-resolution)

Everything here is used by tests and by ``core/lossy.py`` as a fallback when
Pallas is unavailable.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256            # spectral block size (2 x 128 lanes, MXU-aligned)
NBINS = 512            # log2-magnitude histogram bins
NBINS_COARSE = 32      # two-level selection: coarse bins (groups of fine bins)
NBINS_FINE = 16        # fine bins per coarse bin; NBINS == COARSE * FINE
LOG2_LO = -40.0        # histogram range: 2^-40 .. 2^40 (abs magnitudes)
LOG2_HI = 40.0


class Compressed(NamedTuple):
    """Device-side lossy representation (dense; host lossless packs it)."""
    q: jax.Array          # (n_blocks, BLOCK) int8 quantized coefficients
    scale: jax.Array      # (n_blocks,) f32 per-block dequant scale
    n_elements: int       # original element count (for unpad)
    shape: tuple          # original shape
    dtype: jnp.dtype      # original dtype


# ---------------------------------------------------------------------------
# DCT basis
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def dct_matrix(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II matrix D: y = D @ x, x = D.T @ y."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    d = np.cos(np.pi * k * (2 * i + 1) / (2 * n)) * np.sqrt(2.0 / n)
    d[0] /= np.sqrt(2.0)
    return d.astype(np.float32)


def blockize(x: jax.Array, block: int = BLOCK) -> tuple[jax.Array, int]:
    """Flatten + zero-pad to (n_blocks, block); returns (blocks, n_elements)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), n


def unblockize(blocks: jax.Array, n: int, shape: tuple, dtype) -> jax.Array:
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def dct_blocks(xb: jax.Array) -> jax.Array:
    d = jnp.asarray(dct_matrix(xb.shape[-1]))
    return xb @ d.T


def idct_blocks(yb: jax.Array) -> jax.Array:
    d = jnp.asarray(dct_matrix(yb.shape[-1]))
    return yb @ d


# ---------------------------------------------------------------------------
# Selection: histogram-threshold (TPU) and sort (GPU reference)
# ---------------------------------------------------------------------------

def bin_index(a: jax.Array) -> jax.Array:
    """Flat 512-level bin index of absolute magnitudes ``a``.

    This is THE binning used by every selection path — the coarse pass
    derives its 32 bins as ``bin_index(a) // NBINS_FINE`` rather than
    re-quantizing with a 32-bin formula, so an element can never land in a
    coarse bin inconsistent with its fine bin (float rounding near a bin
    boundary would otherwise disagree between the two formulas).

    Exact zeros land in bin 0 (they carry no energy, so they never affect
    the threshold decision).
    """
    lg = jnp.where(a > 0, jnp.log2(jnp.maximum(a, 1e-38)), LOG2_LO)
    return jnp.clip(
        ((lg - LOG2_LO) * (NBINS / (LOG2_HI - LOG2_LO))).astype(jnp.int32),
        0, NBINS - 1)


def energy_histogram(y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absolute log2-|y| histogram -> (counts, energies), each (NBINS,)."""
    a = jnp.abs(y.reshape(-1))
    idx = bin_index(a)
    counts = jnp.zeros(NBINS, jnp.float32).at[idx].add(1.0)
    energies = jnp.zeros(NBINS, jnp.float32).at[idx].add(a * a)
    return counts, energies


def coarse_energy_histogram(y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Coarse 32-bin histogram -> (counts, energies), each (NBINS_COARSE,).

    Coarse bin j aggregates fine bins [16j, 16j+16) — the first pass of the
    two-level selector. Device binning cost is O(elements x 32) instead of
    O(elements x 512).
    """
    a = jnp.abs(y.reshape(-1))
    idx = bin_index(a) // NBINS_FINE
    counts = jnp.zeros(NBINS_COARSE, jnp.float32).at[idx].add(1.0)
    energies = jnp.zeros(NBINS_COARSE, jnp.float32).at[idx].add(a * a)
    return counts, energies


def refine_energy_histogram(y: jax.Array, coarse: jax.Array
                            ) -> tuple[jax.Array, jax.Array]:
    """Fine histogram of the 16 bins inside coarse bin ``coarse``.

    Elements outside the coarse bin contribute exactly 0.0 to slot 0 —
    adding +0.0 is an exact float identity on the non-negative energies, so
    each fine-bin energy is bitwise what the flat 512-bin histogram puts in
    bin ``16*coarse + k``. Device binning cost is O(elements x 16).
    """
    a = jnp.abs(y.reshape(-1))
    idx = bin_index(a)
    member = (idx // NBINS_FINE) == coarse
    fine = jnp.where(member, idx - coarse * NBINS_FINE, 0)
    w = member.astype(jnp.float32)
    counts = jnp.zeros(NBINS_FINE, jnp.float32).at[fine].add(w)
    energies = jnp.zeros(NBINS_FINE, jnp.float32).at[fine].add(a * a * w)
    return counts, energies


def bin_edge(b) -> jax.Array:
    """Lower |y| edge of histogram bin b."""
    return 2.0 ** (LOG2_LO + jnp.asarray(b, jnp.float32)
                   * ((LOG2_HI - LOG2_LO) / NBINS))


def threshold_from_histogram(energies: jax.Array, eps: float) -> jax.Array:
    """Largest bin edge whose below-edge cumulative energy <= eps^2 * total.

    Discarding every |y| < t then provably discards <= budget (bin b holds
    magnitudes in [edge(b), edge(b+1)), so everything below edge(c) is exactly
    the bins < c).
    """
    total = jnp.sum(energies)
    budget = (eps * eps) * total
    below = jnp.concatenate([jnp.zeros(1), jnp.cumsum(energies)])  # below edge b
    ok = below[:NBINS + 1] <= budget + 1e-30
    c = jnp.sum(ok.astype(jnp.int32)) - 1          # last edge still within budget
    # budget >= total (eps >= 1, or no energy) drops everything
    # deterministically: the tie `cumsum(E)[-1] vs sum(E)` is otherwise
    # decided by fp summation order, which differs between selection paths.
    c = jnp.where(budget >= total, NBINS, c)
    t = bin_edge(c)
    return jnp.where(c <= 0, 0.0, t)


def select_coarse(coarse_energies: jax.Array, eps: float):
    """First half of the two-level selector, from a (NBINS_COARSE,) energy
    histogram. Returns ``(C, Cc, base, budget)``:

      C       last coarse edge (0..32) whose below-edge energy fits the
              eps^2 budget — 32 means even the full energy fits (drop all)
      Cc      C clamped to a valid coarse *bin* index for the refine pass
      base    cumulative energy below coarse edge Cc
      budget  eps^2 * total energy

    Separated from :func:`threshold_two_level` so the fused tree path can
    vmap it over per-leaf histograms between the coarse and refine kernels.
    """
    total = jnp.sum(coarse_energies)
    budget = (eps * eps) * total
    below = jnp.concatenate([jnp.zeros(1), jnp.cumsum(coarse_energies)])
    ok = below[:NBINS_COARSE + 1] <= budget + 1e-30
    c = jnp.sum(ok.astype(jnp.int32)) - 1       # >= 0: edge 0 is always ok
    # same drop-everything clamp as threshold_from_histogram: both
    # selectors compare their own budget against their own total, so the
    # eps >= 1 tie cannot be decided by fp summation order.
    c = jnp.where(budget >= total, NBINS_COARSE, c)
    cc = jnp.clip(c, 0, NBINS_COARSE - 1)
    return c, cc, below[cc], budget


def select_fine(fine_energies: jax.Array, c: jax.Array, cc: jax.Array,
                base: jax.Array, budget: jax.Array) -> jax.Array:
    """Second half of the two-level selector: pick the fine edge inside
    coarse bin ``cc`` and return the threshold (same quantized bin edges as
    the flat 512-bin selector)."""
    below = base + jnp.concatenate(
        [jnp.zeros(1), jnp.cumsum(fine_energies)])[:NBINS_FINE]
    ok = below <= budget + 1e-30
    k = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - 1, 0)
    edge = jnp.where(c >= NBINS_COARSE, NBINS, cc * NBINS_FINE + k)
    return jnp.where(edge <= 0, 0.0, bin_edge(edge))


def threshold_two_level(y: jax.Array, eps: float) -> jax.Array:
    """Two-level (coarse-32 then refine-16) threshold selection.

    Selects the same quantized bin edge as ``threshold_from_histogram``
    over the flat 512-bin histogram — both walk the identical edge grid,
    the coarse pass just narrows the search to the one coarse bin that
    straddles the eps^2 energy budget before spending the fine binning —
    at O(elements x 48) binning cost instead of O(elements x 512). Tests
    (test_kernels.py) prove bin-edge identity across every codec payload
    class, which is what keeps spectral_compress outputs bit-identical
    between the selectors.
    """
    _, ce = coarse_energy_histogram(y)
    c, cc, base, budget = select_coarse(ce, eps)
    _, fe = refine_energy_histogram(y, cc)
    return select_fine(fe, c, cc, base, budget)


def threshold_by_sort(y: jax.Array, eps: float) -> jax.Array:
    """The paper's GPU approach (F7): sort |y| and walk the energy CDF.

    Returns the *optimal* threshold: the magnitude of the smallest coefficient
    that must still be kept so that discarded energy <= eps^2 * total.
    """
    a = jnp.sort(jnp.abs(y.reshape(-1)))           # ascending
    e = a * a
    cum = jnp.cumsum(e)
    total = cum[-1]
    budget = (eps * eps) * total
    # keep everything above the largest prefix whose energy fits the budget
    n_drop = jnp.sum((cum <= budget).astype(jnp.int32))
    t = jnp.where(n_drop >= a.shape[0], jnp.inf, a[jnp.minimum(n_drop, a.shape[0] - 1)])
    return jnp.where(n_drop == 0, 0.0, t)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize_blocks(y: jax.Array, t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Zero sub-threshold coeffs, int8-quantize survivors per block."""
    kept = jnp.where(jnp.abs(y) >= t, y, 0.0)
    amax = jnp.max(jnp.abs(kept), axis=-1)                  # (n_blocks,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(kept / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[:, None]


# ---------------------------------------------------------------------------
# End-to-end oracle codec
# ---------------------------------------------------------------------------

def compress(x: jax.Array, eps: float = 1e-2, *,
             selector: str = "histogram") -> Compressed:
    xb, n = blockize(x)
    y = dct_blocks(xb)
    if selector == "histogram":
        _, energies = energy_histogram(y)
        t = threshold_from_histogram(energies, eps)
    elif selector == "two_level":
        t = threshold_two_level(y, eps)
    elif selector == "sort":
        t = threshold_by_sort(y, eps)
    else:
        raise ValueError(f"unknown selector {selector!r}")
    q, scale = quantize_blocks(y, t)
    return Compressed(q, scale, n, tuple(x.shape), x.dtype)


def decompress(c: Compressed) -> jax.Array:
    y = dequantize_blocks(c.q, c.scale)
    xb = idct_blocks(y)
    return unblockize(xb, c.n_elements, c.shape, c.dtype)


def rel_l2_error(x: jax.Array, xhat: jax.Array) -> float:
    num = jnp.linalg.norm((x - xhat).astype(jnp.float32).reshape(-1))
    den = jnp.linalg.norm(x.astype(jnp.float32).reshape(-1))
    return float(num / jnp.maximum(den, 1e-30))


def kept_fraction(c: Compressed) -> float:
    return float(jnp.mean((c.q != 0).astype(jnp.float32)))


def error_bound(eps: float) -> float:
    """Combined guarantee: threshold (<= eps) + int8 quantization.

    Quantization adds per-block L2 error <= (scale/2) * sqrt(B); with
    scale = max|y_b|/127 this is <= ||y_b|| * sqrt(B)/254 relative per block.
    The combined relative-L2 bound used by tests:
    """
    quant = math.sqrt(BLOCK) / 254.0
    return eps + quant
