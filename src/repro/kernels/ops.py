"""Jit'd public wrappers around the spectral-lossy Pallas kernels.

``spectral_compress(x, eps)`` / ``spectral_decompress(c)`` are the device-side
lossy codec used by core/lossy.py (checkpoint compression), the hybrid in-situ
step, and optim/grad_compress.py. On CPU (tests, this container) the kernels
run in interpret mode; on TPU they compile natively — callers never care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref, spectral_lossy as K
from repro.kernels.ref import BLOCK, Compressed


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_blocks(xb: jax.Array, tile: int) -> jax.Array:
    n = xb.shape[0]
    pad = (-n) % tile
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    return xb


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _compress_padded(xb: jax.Array, eps: float, interpret: bool):
    if interpret:
        # off-TPU: the pure-jnp oracle compiles to the same math (tests
        # assert bit-equal q); interpret-mode pallas is kept for kernel
        # tests only — it executes the kernel body per-block in python.
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, energies = K.dct_hist(xb, interpret=False)
    t = ref.threshold_from_histogram(energies, eps)
    return K.threshold_quant(y, t, interpret=False)


def spectral_compress(x: jax.Array, eps: float = 1e-2) -> Compressed:
    """Lossy-compress one tensor on device. Relative-L2 error <~ eps + quant."""
    xb, n = ref.blockize(x)
    xb = _pad_blocks(xb, K.HIST_TILE)
    q, scale = _compress_padded(xb, float(eps), _interpret())
    return Compressed(q, scale, n, tuple(x.shape), x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decompress_padded(q, scale, interpret: bool):
    if interpret:
        return ref.idct_blocks(ref.dequantize_blocks(q, scale))
    return K.dequant_idct(q, scale, interpret=False)


def spectral_decompress(c: Compressed) -> jax.Array:
    xb = _decompress_padded(c.q, c.scale, _interpret())
    return ref.unblockize(xb, c.n_elements, c.shape, c.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _compress_tree_packed(leaves: tuple, eps: float, interpret: bool):
    """ONE dispatch for every policy-selected leaf of a tree.

    All leaves (blockize normalizes every dtype to f32 blocks, so a single
    packed group covers the whole tree) are padded to HIST_TILE multiples and
    concatenated into one (total_blocks, BLOCK) buffer; the DCT runs once
    over the packed buffer. Thresholds stay *per leaf* — selection statistics
    are segment-summed back to per-leaf histograms — so the result is
    bit-identical to the per-leaf path, with O(1) instead of O(leaves) host
    dispatches.
    """
    blocks = []
    for x in leaves:
        xb, _ = ref.blockize(x)
        blocks.append(_pad_blocks(xb, K.HIST_TILE))
    counts = [b.shape[0] for b in blocks]
    packed = jnp.concatenate(blocks, 0) if len(blocks) > 1 else blocks[0]
    if interpret:
        # off-TPU: packed pure-jnp oracle (XLA compiles the unrolled
        # per-leaf selection into the same single program).
        y = ref.dct_blocks(packed)
        qs, ss = [], []
        off = 0
        for c in counts:
            yb = y[off:off + c]
            off += c
            _, energies = ref.energy_histogram(yb)
            t = ref.threshold_from_histogram(energies, eps)
            q, s = ref.quantize_blocks(yb, t)
            qs.append(q)
            ss.append(s)
        return tuple(qs), tuple(ss)
    # TPU: one dct_hist_tiled + one threshold_quant pallas invocation. Tile
    # rows never straddle leaves (each leaf is padded to a HIST_TILE
    # multiple), so per-tile histograms segment-sum exactly to the per-leaf
    # histograms the per-leaf kernels would have produced.
    import numpy as _np
    y, _, eng_t = K.dct_hist_tiled(packed, interpret=False)
    tile_seg = _np.repeat(_np.arange(len(counts)),
                          [c // K.HIST_TILE for c in counts])
    seg_eng = jnp.zeros((len(counts), ref.NBINS), jnp.float32
                        ).at[jnp.asarray(tile_seg)].add(eng_t)
    t_seg = jax.vmap(lambda e: ref.threshold_from_histogram(e, eps))(seg_eng)
    block_seg = _np.repeat(_np.arange(len(counts)), counts)
    q, s = K.threshold_quant(y, t_seg[jnp.asarray(block_seg)],
                             interpret=False)
    qs, ss, off = [], [], 0
    for c in counts:
        qs.append(q[off:off + c])
        ss.append(s[off:off + c])
        off += c
    return tuple(qs), tuple(ss)


def spectral_compress_tree(state, eps: float = 1e-2,
                           policy=None, *, fused: bool = True):
    """Device stage of the hybrid checkpoint pipeline: lossy-compress every
    leaf ``policy(keystr)`` selects; other leaves pass through untouched.

    Returns the same tree structure with ``Compressed`` leaves where the
    policy fired — the hand-off then ships int8 coefficients + scales.

    ``fused`` (default) packs all selected leaves into one flat blocked
    buffer and compresses the whole tree in a single dispatch (bit-identical
    to the per-leaf path, which ``fused=False`` preserves for comparison).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    new_leaves = [leaf for _, leaf in flat]
    selected = [i for i, (path, leaf) in enumerate(flat)
                if leaf is not None and policy is not None
                and policy(jax.tree_util.keystr(path))]
    if fused and len(selected) > 1:
        leaves = tuple(flat[i][1] for i in selected)
        qs, scales = _compress_tree_packed(leaves, float(eps), _interpret())
        for i, q, scale in zip(selected, qs, scales):
            leaf = flat[i][1]
            new_leaves[i] = Compressed(q, scale, int(leaf.size),
                                       tuple(leaf.shape), leaf.dtype)
    else:
        for i in selected:
            new_leaves[i] = spectral_compress(flat[i][1], eps)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# In-graph variant (hybrid in-situ: runs *inside* the jitted train step, like
# NEKO's on-GPU lossy pass). Takes/returns plain arrays so it can live in a
# pjit'd computation; threshold selection happens in-graph too.
# ---------------------------------------------------------------------------

def compress_in_graph(x: jax.Array, eps: float = 1e-2,
                      interpret: bool | None = None):
    """Returns (q:int8 (nb,B), scale:f32 (nb,)) — ~4-8x fewer D2H bytes.

    jnp DCT+histogram (XLA fuses these fine) so the op can inline into a
    sharded train step without a pallas_call on non-TPU backends; on TPU the
    pallas path is used.
    """
    if interpret is None:
        interpret = _interpret()
    xb, _ = ref.blockize(x)
    xb = _pad_blocks(xb, K.HIST_TILE)
    if interpret:
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, energies = K.dct_hist(xb, interpret=False)
    t = ref.threshold_from_histogram(energies, eps)
    return K.threshold_quant(y, t, interpret=False)
