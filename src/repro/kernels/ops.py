"""Jit'd public wrappers around the spectral-lossy Pallas kernels.

``spectral_compress(x, eps)`` / ``spectral_decompress(c)`` are the device-side
lossy codec used by core/lossy.py (checkpoint compression), the hybrid in-situ
step, and optim/grad_compress.py. On CPU (tests, this container) the kernels
run in interpret mode; on TPU they compile natively — callers never care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref, spectral_lossy as K
from repro.kernels.ref import BLOCK, Compressed


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_blocks(xb: jax.Array, tile: int) -> jax.Array:
    n = xb.shape[0]
    pad = (-n) % tile
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    return xb


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length() if n > 2 else max(n, 1)


def _bucket_rows(n_blocks: int) -> int:
    """Shape bucket for the fused tree path: next power-of-two block count
    (>= HIST_TILE, so every bucket stays tile-aligned)."""
    return max(K.HIST_TILE, _next_pow2(n_blocks))


def _pad_to_rows(xb: jax.Array, rows: int) -> jax.Array:
    pad = rows - xb.shape[0]
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    return xb


# ---------------------------------------------------------------------------
# Tile autotuning. Keyed by the same pow2 shape buckets as the fused-tree
# trace cache, so a tile is measured at most once per bucket per process.
# Off-TPU the defaults are returned untouched (interpret-mode timings would
# tune the python interpreter, not the hardware).
# ---------------------------------------------------------------------------

_TILE_CANDIDATES = {"hist": (8, 16, 32), "quant": (32, 64, 128, 256)}
_DEFAULT_TILE = {"hist": K.HIST_TILE, "quant": K.QUANT_TILE}
_TUNED: dict[tuple, int] = {}


def _measure_tile(kind: str, bucket_rows: int) -> int:
    """Time each candidate tile on the real kernel at the bucket shape and
    keep the fastest. Candidates and buckets are both powers of two, so no
    candidate ever needs padding."""
    import time as _time
    cands = [t for t in _TILE_CANDIDATES[kind] if t <= bucket_rows]
    if not cands:
        return min(_TILE_CANDIDATES[kind])
    best, best_dt = cands[0], float("inf")
    xb = jnp.ones((bucket_rows, BLOCK), jnp.float32)
    tvec = jnp.full((bucket_rows,), 1e-3, jnp.float32)
    for t in cands:
        if kind == "hist":
            fn = jax.jit(functools.partial(
                K.dct_hist_coarse, interpret=False, tile=t))
            args = (xb,)
        else:
            fn = jax.jit(functools.partial(
                K.threshold_quant, interpret=False, tile=t))
            args = (xb, tvec)
        out = fn(*args)
        jax.block_until_ready(out)  # compile + warm outside the timer
        t0 = _time.perf_counter()
        for _ in range(3):
            out = fn(*args)
        jax.block_until_ready(out)
        dt = (_time.perf_counter() - t0) / 3
        if dt < best_dt:
            best, best_dt = t, dt
    return best


def _tuned_tile(kind: str, bucket_rows: int, backend: str) -> int:
    key = (kind, bucket_rows, backend)
    if key not in _TUNED:
        _TUNED[key] = (_measure_tile(kind, bucket_rows)
                       if backend == "tpu" else _DEFAULT_TILE[kind])
    return _TUNED[key]


def tuned_tiles() -> dict:
    """Snapshot of the (kind, bucket_rows, backend) -> tile cache, for
    benchmark/report introspection."""
    return dict(_TUNED)


def _tiles_for(n_blocks: int) -> tuple[int, int]:
    if _interpret():
        return K.HIST_TILE, K.QUANT_TILE
    b = _bucket_rows(n_blocks)
    return (_tuned_tile("hist", b, "tpu"), _tuned_tile("quant", b, "tpu"))


def _compress_math(xb, eps: float, interpret: bool,
                   hist_tile: int, quant_tile: int):
    """Shared body of the single-tensor compress jits.

    TPU path is the two-level histogram: a coarse 32-bin pass, in-graph
    coarse-bin selection, then a 16-bin refine pass restricted to the coarse
    bin straddling the eps^2 energy budget — O(elem x 48) binning FLOPs
    instead of O(elem x 512), same bin edges as the flat selector.
    """
    if interpret:
        # off-TPU: the pure-jnp oracle compiles to the same math (tests
        # assert bit-equal q); interpret-mode pallas is kept for kernel
        # tests only — it executes the kernel body per-block in python.
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, ce = K.dct_hist_coarse(xb, interpret=False, tile=hist_tile)
    c, cc, base, budget = ref.select_coarse(ce, eps)
    _, fe = K.hist_refine(y, cc, interpret=False, tile=hist_tile)
    t = ref.select_fine(fe, c, cc, base, budget)
    return K.threshold_quant(y, t, interpret=False, tile=quant_tile)


@functools.partial(jax.jit, static_argnames=(
    "eps", "interpret", "hist_tile", "quant_tile"))
def _compress_padded(xb: jax.Array, eps: float, interpret: bool,
                     hist_tile: int = K.HIST_TILE,
                     quant_tile: int = K.QUANT_TILE):
    return _compress_math(xb, eps, interpret, hist_tile, quant_tile)


def spectral_compress(x: jax.Array, eps: float = 1e-2) -> Compressed:
    """Lossy-compress one tensor on device. Relative-L2 error <~ eps + quant."""
    xb, n = ref.blockize(x)
    hist_tile, quant_tile = _tiles_for(xb.shape[0])
    xb = _pad_blocks(xb, hist_tile)
    q, scale = _compress_padded(xb, float(eps), _interpret(),
                                hist_tile, quant_tile)
    return Compressed(q, scale, n, tuple(x.shape), x.dtype)


@functools.partial(jax.jit, static_argnames=(
    "eps", "interpret", "chunk_blocks", "hist_tile", "quant_tile"))
def _compress_padded_chunks(xb: jax.Array, eps: float, interpret: bool,
                            chunk_blocks: int, hist_tile: int,
                            quant_tile: int):
    """Same math as ``_compress_padded`` but the int8 output is pre-split
    into frame-chunk-aligned device buffers inside the jit — no extra device
    round-trip between quantize and codec chunking."""
    q, scale = _compress_math(xb, eps, interpret, hist_tile, quant_tile)
    n = q.shape[0]
    chunks = tuple(q[off:min(off + chunk_blocks, n)]
                   for off in range(0, n, chunk_blocks))
    return chunks, scale


def spectral_compress_chunked(x: jax.Array, eps: float = 1e-2, *,
                              chunk_blocks: int = 4096):
    """Fused quantize + frame-chunking: lossy-compress one tensor and return
    its int8 coefficients already split into ``chunk_blocks``-row device
    buffers (4096 blocks x 256 B = the codec's 1 MiB frame chunk), so the
    host framing path can D2H-copy and losslessly pack chunk-by-chunk
    instead of synchronising on one monolithic buffer.

    Returns ``(chunks, scale, n_elements)`` with ``concat(chunks)`` bitwise
    equal to ``spectral_compress(x, eps).q``.
    """
    xb, n = ref.blockize(x)
    hist_tile, quant_tile = _tiles_for(xb.shape[0])
    xb = _pad_blocks(xb, hist_tile)
    chunks, scale = _compress_padded_chunks(
        xb, float(eps), _interpret(), int(chunk_blocks),
        hist_tile, quant_tile)
    return chunks, scale, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decompress_padded(q, scale, interpret: bool):
    if interpret:
        return ref.idct_blocks(ref.dequantize_blocks(q, scale))
    return K.dequant_idct(q, scale, interpret=False)


def spectral_decompress(c: Compressed) -> jax.Array:
    xb = _decompress_padded(c.q, c.scale, _interpret())
    return ref.unblockize(xb, c.n_elements, c.shape, c.dtype)


@functools.partial(jax.jit, static_argnames=(
    "eps", "interpret", "hist_tile", "quant_tile"))
def _compress_tree_packed(blocks: tuple, eps: float, interpret: bool,
                          hist_tile: int = K.HIST_TILE,
                          quant_tile: int = K.QUANT_TILE):
    """ONE fused dispatch over pre-bucketed per-leaf block groups.

    ``blocks`` are the already-blockized leaves (f32 ``(rows_i, BLOCK)``,
    each padded to a power-of-two row count by the caller — the
    shape-bucketed trace cache); they are concatenated into one
    (total_blocks, BLOCK) buffer and the DCT runs once over the packed
    buffer. Thresholds stay *per leaf* — selection statistics are
    segment-summed back to per-leaf histograms — so the result is
    bit-identical to the per-leaf path (zero pad blocks carry zero energy
    and cannot move any leaf's threshold). The jit trace therefore keys on
    the *bucketed* row counts: an elastic mesh that resizes its leaves
    re-traces only when a leaf crosses a power-of-two block-count boundary,
    bounding compilation to O(log(max_blocks)) variants per leaf instead of
    one per shape.
    """
    counts = [b.shape[0] for b in blocks]
    packed = jnp.concatenate(blocks, 0) if len(blocks) > 1 else blocks[0]
    if interpret:
        # off-TPU: packed pure-jnp oracle (XLA compiles the unrolled
        # per-leaf selection into the same single program).
        y = ref.dct_blocks(packed)
        qs, ss = [], []
        off = 0
        for c in counts:
            yb = y[off:off + c]
            off += c
            _, energies = ref.energy_histogram(yb)
            t = ref.threshold_from_histogram(energies, eps)
            q, s = ref.quantize_blocks(yb, t)
            qs.append(q)
            ss.append(s)
        return tuple(qs), tuple(ss)
    # TPU: two-level selection in one fused graph — a coarse tiled pass,
    # per-leaf segment-summed coarse histograms, then a tiled refine pass
    # driven by each block's leaf coarse index. Tile rows never straddle
    # leaves (each leaf is padded to a pow2 bucket >= hist_tile), so
    # per-tile histograms segment-sum exactly to the per-leaf histograms
    # the per-leaf kernels would have produced.
    import numpy as _np
    y, _, eng_t = K.dct_hist_coarse_tiled(packed, interpret=False,
                                          tile=hist_tile)
    tile_seg = jnp.asarray(_np.repeat(_np.arange(len(counts)),
                                      [c // hist_tile for c in counts]))
    seg_ce = jnp.zeros((len(counts), ref.NBINS_COARSE), jnp.float32
                       ).at[tile_seg].add(eng_t)
    cs, ccs, bases, budgets = jax.vmap(
        lambda e: ref.select_coarse(e, eps))(seg_ce)
    block_seg = jnp.asarray(_np.repeat(_np.arange(len(counts)), counts))
    _, fine_t = K.hist_refine_tiled(y, ccs[block_seg], interpret=False,
                                    tile=hist_tile)
    seg_fe = jnp.zeros((len(counts), ref.NBINS_FINE), jnp.float32
                       ).at[tile_seg].add(fine_t)
    t_seg = jax.vmap(ref.select_fine)(seg_fe, cs, ccs, bases, budgets)
    q, s = K.threshold_quant(y, t_seg[block_seg], interpret=False,
                             tile=quant_tile)
    qs, ss, off = [], [], 0
    for c in counts:
        qs.append(q[off:off + c])
        ss.append(s[off:off + c])
        off += c
    return tuple(qs), tuple(ss)


def spectral_compress_tree(state, eps: float = 1e-2,
                           policy=None, *, fused: bool = True):
    """Device stage of the hybrid checkpoint pipeline: lossy-compress every
    leaf ``policy(keystr)`` selects; other leaves pass through untouched.

    Returns the same tree structure with ``Compressed`` leaves where the
    policy fired — the hand-off then ships int8 coefficients + scales.

    ``fused`` (default) packs all selected leaves into one flat blocked
    buffer and compresses the whole tree in a single fused dispatch
    (bit-identical to the per-leaf path, which ``fused=False`` preserves
    for comparison). Each leaf's block count is padded up to the next
    power of two before the fused call, so the jit trace cache buckets
    elastic-mesh shape drift instead of re-tracing per tree shape; the
    zero pad blocks carry no energy (thresholds are unchanged) and are
    sliced off the result.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    new_leaves = [leaf for _, leaf in flat]
    selected = [i for i, (path, leaf) in enumerate(flat)
                if leaf is not None and policy is not None
                and policy(jax.tree_util.keystr(path))]
    if fused and len(selected) > 1:
        blocks, keep_rows = [], []
        for i in selected:
            xb, _ = ref.blockize(flat[i][1])
            real = xb.shape[0] + ((-xb.shape[0]) % K.HIST_TILE)
            keep_rows.append(real)
            blocks.append(_pad_to_rows(xb, _bucket_rows(real)))
        hist_tile, quant_tile = _tiles_for(max(b.shape[0] for b in blocks))
        # tiles must never straddle leaves: clamp to the smallest bucket
        # (both are powers of two, so the smaller divides every bucket).
        hist_tile = min(hist_tile, min(b.shape[0] for b in blocks))
        qs, scales = _compress_tree_packed(tuple(blocks), float(eps),
                                           _interpret(),
                                           hist_tile, quant_tile)
        for i, q, scale, real in zip(selected, qs, scales, keep_rows):
            leaf = flat[i][1]
            new_leaves[i] = Compressed(q[:real], scale[:real],
                                       int(leaf.size),
                                       tuple(leaf.shape), leaf.dtype)
    else:
        for i in selected:
            new_leaves[i] = spectral_compress(flat[i][1], eps)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def packed_tree_cache_size() -> int:
    """Number of compiled variants of the fused tree kernel (trace-cache
    introspection for the shape-bucketing tests/benchmarks)."""
    return _compress_tree_packed._cache_size()


# ---------------------------------------------------------------------------
# In-graph variant (hybrid in-situ: runs *inside* the jitted train step, like
# NEKO's on-GPU lossy pass). Takes/returns plain arrays so it can live in a
# pjit'd computation; threshold selection happens in-graph too.
# ---------------------------------------------------------------------------

def compress_in_graph(x: jax.Array, eps: float = 1e-2,
                      interpret: bool | None = None):
    """Returns (q:int8 (nb,B), scale:f32 (nb,)) — ~4-8x fewer D2H bytes.

    jnp DCT+histogram (XLA fuses these fine) so the op can inline into a
    sharded train step without a pallas_call on non-TPU backends; on TPU the
    pallas path is used.
    """
    if interpret is None:
        interpret = _interpret()
    xb, _ = ref.blockize(x)
    xb = _pad_blocks(xb, K.HIST_TILE)
    if interpret:
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, ce = K.dct_hist_coarse(xb, interpret=False)
    c, cc, base, budget = ref.select_coarse(ce, eps)
    _, fe = K.hist_refine(y, cc, interpret=False)
    t = ref.select_fine(fe, c, cc, base, budget)
    return K.threshold_quant(y, t, interpret=False)
