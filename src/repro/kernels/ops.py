"""Jit'd public wrappers around the spectral-lossy Pallas kernels.

``spectral_compress(x, eps)`` / ``spectral_decompress(c)`` are the device-side
lossy codec used by core/lossy.py (checkpoint compression), the hybrid in-situ
step, and optim/grad_compress.py. On CPU (tests, this container) the kernels
run in interpret mode; on TPU they compile natively — callers never care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref, spectral_lossy as K
from repro.kernels.ref import BLOCK, Compressed


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_blocks(xb: jax.Array, tile: int) -> jax.Array:
    n = xb.shape[0]
    pad = (-n) % tile
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    return xb


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _compress_padded(xb: jax.Array, eps: float, interpret: bool):
    if interpret:
        # off-TPU: the pure-jnp oracle compiles to the same math (tests
        # assert bit-equal q); interpret-mode pallas is kept for kernel
        # tests only — it executes the kernel body per-block in python.
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, energies = K.dct_hist(xb, interpret=False)
    t = ref.threshold_from_histogram(energies, eps)
    return K.threshold_quant(y, t, interpret=False)


def spectral_compress(x: jax.Array, eps: float = 1e-2) -> Compressed:
    """Lossy-compress one tensor on device. Relative-L2 error <~ eps + quant."""
    xb, n = ref.blockize(x)
    xb = _pad_blocks(xb, K.HIST_TILE)
    q, scale = _compress_padded(xb, float(eps), _interpret())
    return Compressed(q, scale, n, tuple(x.shape), x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decompress_padded(q, scale, interpret: bool):
    if interpret:
        return ref.idct_blocks(ref.dequantize_blocks(q, scale))
    return K.dequant_idct(q, scale, interpret=False)


def spectral_decompress(c: Compressed) -> jax.Array:
    xb = _decompress_padded(c.q, c.scale, _interpret())
    return ref.unblockize(xb, c.n_elements, c.shape, c.dtype)


def spectral_compress_tree(state, eps: float = 1e-2,
                           policy=None):
    """Device stage of the hybrid checkpoint pipeline: lossy-compress every
    leaf ``policy(keystr)`` selects; other leaves pass through untouched.

    Returns the same tree structure with ``Compressed`` leaves where the
    policy fired — the hand-off then ships int8 coefficients + scales.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    new_leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if leaf is not None and policy is not None and policy(key):
            new_leaves.append(spectral_compress(leaf, eps))
        else:
            new_leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# In-graph variant (hybrid in-situ: runs *inside* the jitted train step, like
# NEKO's on-GPU lossy pass). Takes/returns plain arrays so it can live in a
# pjit'd computation; threshold selection happens in-graph too.
# ---------------------------------------------------------------------------

def compress_in_graph(x: jax.Array, eps: float = 1e-2,
                      interpret: bool | None = None):
    """Returns (q:int8 (nb,B), scale:f32 (nb,)) — ~4-8x fewer D2H bytes.

    jnp DCT+histogram (XLA fuses these fine) so the op can inline into a
    sharded train step without a pallas_call on non-TPU backends; on TPU the
    pallas path is used.
    """
    if interpret is None:
        interpret = _interpret()
    xb, _ = ref.blockize(x)
    xb = _pad_blocks(xb, K.HIST_TILE)
    if interpret:
        y = ref.dct_blocks(xb)
        _, energies = ref.energy_histogram(y)
        t = ref.threshold_from_histogram(energies, eps)
        return ref.quantize_blocks(y, t)
    y, _, energies = K.dct_hist(xb, interpret=False)
    t = ref.threshold_from_histogram(energies, eps)
    return K.threshold_quant(y, t, interpret=False)
