"""Logical-axis -> mesh-axis mapping (FSDP / TP / EP / SP).

The model substrate annotates every parameter dim with a *logical* axis name
(see models/params.py). This module turns those names into
``jax.sharding.PartitionSpec`` against a concrete mesh, with divisibility
fallbacks: a logical axis is only mapped onto a mesh axis when the dim size is
divisible by the mesh-axis size; otherwise the dim is replicated. That keeps a
single production mesh (16x16 or 2x16x16) valid for every assigned arch — the
9-head arch simply replicates its attention weights where the 128-head arch
tensor-parallelizes them (the roofline table then shows the cost, which is the
honest outcome).

Rule sets are small data, so per-arch overrides and hillclimb variants are
plain dicts (see configs/*.py and EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


# -- JAX version compat -------------------------------------------------------
# The production API surface (jax.shard_map / jax.set_mesh) landed after the
# 0.4.x line; these wrappers lower to jax.experimental.shard_map and the
# Mesh context manager on older releases so the same call sites run on both.

def shard_map(f, mesh: Mesh, in_specs, out_specs, *,
              axis_names=None, check_vma: bool = False):
    """Partially-manual shard_map: manual over ``axis_names`` only."""
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset() if axis_names is None
            else frozenset(mesh.axis_names) - frozenset(axis_names))
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def mesh_context(mesh: Mesh):
    """Ambient-mesh context manager across JAX versions."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh   # Mesh is itself a context manager on older releases


def abstract_mesh(sizes: Sequence[int], names: Sequence[str]):
    """Device-free AbstractMesh across the two constructor signatures."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, sizes)))   # shape_tuple form
    except TypeError:
        return AbstractMesh(tuple(sizes), tuple(names))  # legacy form


# Baseline rules: logical axis -> mesh axis (or tuple of mesh axes), None = replicate.
# FSDP shards the model dimension over 'data'; TP shards vocab/heads/mlp/expert
# over 'model'. 'pod' stays pure DP for params (no cross-pod param collectives
# on the slow DCI link).
DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "e_mlp": None,
    "layers": None,
    "lora": None,
    "state": None,
    "conv": None,
    None: None,
}

# Hillclimb variant: fully-sharded params over both axes (zero-1 style).
FSDP_TP_RULES = dict(DEFAULT_RULES)

# Variant for small models where TP is wasteful: everything FSDP over the
# flattened ('data','model') axes pair on the largest dim, batch over the
# whole mesh (pure data parallel + ZeRO-3). Kills both the model-axis
# compute redundancy (useful-flops ratio) and the Megatron activation
# all-reduces; collectives become per-layer weight all-gathers only.
PURE_DP_RULES = dict(
    DEFAULT_RULES,
    vocab=("data", "model"),
    embed=("data", "model"),
    heads=None,
    kv_heads=None,
    mlp=None,
    expert=None,
)

RULE_SETS = {
    "default": DEFAULT_RULES,
    "pure_dp": PURE_DP_RULES,
}


def batch_over_model(rules) -> bool:
    """pure_dp rules want activations batch-sharded over 'model' too."""
    return rules is PURE_DP_RULES or rules == PURE_DP_RULES


def _axes_sizes(mesh: Mesh) -> dict[str, int]:
    # Mesh.shape / AbstractMesh.shape are both axis-name -> size mappings,
    # so rule evaluation works without real devices (tests use AbstractMesh).
    return dict(mesh.shape)


def _resolve_dim(dim: int, logical: str | None, rules: Mapping, mesh_sizes: dict):
    """Map one logical dim to mesh axes, dropping axes that don't divide."""
    target = rules.get(logical, None)
    if target is None:
        return None
    if isinstance(target, str):
        target = (target,)
    kept = []
    prod = 1
    for ax in target:
        size = mesh_sizes.get(ax, 1)
        if dim % (prod * size) == 0:
            kept.append(ax)
            prod *= size
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(shape: Sequence[int], axes: Sequence[str | None], rules: Mapping,
             mesh: Mesh) -> P:
    sizes = _axes_sizes(mesh)
    used: set[str] = set()
    parts = []
    for dim, logical in zip(shape, axes):
        resolved = _resolve_dim(dim, logical, rules, sizes)
        # one mesh axis may appear at most once in a PartitionSpec
        if resolved is not None:
            flat = (resolved,) if isinstance(resolved, str) else resolved
            flat = tuple(a for a in flat if a not in used)
            if not flat:
                resolved = None
            else:
                used.update(flat)
                resolved = flat if len(flat) > 1 else flat[0]
        parts.append(resolved)
    return P(*parts)


def tree_partition_specs(abstract_tree: PyTree, axes_tree: PyTree, rules: Mapping,
                         mesh: Mesh) -> PyTree:
    """PartitionSpec pytree for a param tree (abstract or concrete)."""
    return jax.tree.map(
        lambda leaf, axes: spec_for(leaf.shape, axes, rules, mesh),
        abstract_tree,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(abstract_tree: PyTree, axes_tree: PyTree, rules: Mapping,
                   mesh: Mesh) -> PyTree:
    specs = tree_partition_specs(abstract_tree, axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Activation constraints. Inside jitted step functions we pin the key
# activation tensors; XLA propagates the rest.
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> tuple:
    """Mesh axes used for the batch dim: ('pod','data') when pod exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *parts):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except (ValueError, RuntimeError):
        return x


def batch_spec(mesh: Mesh, batch: int, extra_model: bool = False) -> P:
    """PartitionSpec for (batch, seq, ...) activations.

    When the per-(pod,data) batch still divides over 'model' and the arch policy
    asks for it (pure-DP small models), the batch dim may also take 'model'.
    """
    axes = list(dp_axes(mesh))
    sizes = _axes_sizes(mesh)
    prod = 1
    kept = []
    for a in axes:
        if batch % (prod * sizes[a]) == 0:
            kept.append(a)
            prod *= sizes[a]
    if extra_model and "model" in sizes and batch % (prod * sizes["model"]) == 0:
        kept.append("model")
    if not kept:
        return P()
    return P(tuple(kept) if len(kept) > 1 else kept[0])
