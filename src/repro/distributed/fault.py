"""Fault tolerance: failure detection, elastic re-mesh planning, stragglers.

The paper's checkpointing motivation ("limited walltimes and/or failures of
system components") is the *why*; this module is the *how* for a 1000+-node
posture:

  * HeartbeatTracker — per-host liveness from periodic beats; a host missing
    ``grace`` seconds is declared failed (in a real deployment the beat is a
    tiny all-reduce or a KV write; here it is a call, injected by tests).
  * StragglerMonitor — per-host step-time EWMA; hosts slower than
    ``factor`` x median are flagged. Mitigation policy (documented, and what
    the loop implements): flagged hosts get their *in-situ* p_i budget
    reduced first (in-situ work is the elastic slack on a node — exactly the
    paper's observation that in-situ tasks share node resources), and if
    still slow they are scheduled for replacement at the next checkpoint
    boundary.
  * plan_elastic_remesh — given the surviving host count, pick the largest
    (data, model) grid that (a) fits the survivors, (b) keeps 'model' a
    divisor of the old model axis (so TP shards merge/split cleanly), and
    return the shard remap plan. Restore is checkpoint-based: state is
    logically complete on disk (in-situ compressed), so resuming on the new
    mesh is read + re-place (serialization.read_state with new shardings).

Recovery invariant: checkpoint steps are atomic (manifest-last), so the
resumed step is always a step that fully finished.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Optional


class HeartbeatTracker:
    def __init__(self, hosts: list[int], grace_s: float = 30.0) -> None:
        self.grace_s = grace_s
        self.last_seen: dict[int, float] = {h: time.monotonic() for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def failed_hosts(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self.last_seen.items()
                      if now - t > self.grace_s)

    def alive_hosts(self, now: Optional[float] = None) -> list[int]:
        failed = set(self.failed_hosts(now))
        return sorted(h for h in self.last_seen if h not in failed)


class StragglerMonitor:
    """Step-time EWMA per host; flags hosts slower than factor x median."""

    def __init__(self, alpha: float = 0.2, factor: float = 1.5) -> None:
        self.alpha = alpha
        self.factor = factor
        self.ewma: dict[int, float] = {}

    def observe(self, host: int, step_s: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_s if prev is None
                           else (1 - self.alpha) * prev + self.alpha * step_s)

    def median(self) -> float:
        if not self.ewma:
            return 0.0
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2]

    def stragglers(self) -> list[int]:
        med = self.median()
        if med <= 0:
            return []
        return sorted(h for h, v in self.ewma.items()
                      if v > self.factor * med)

    def mitigation(self, host: int) -> str:
        """Policy: shed in-situ load first, then replace at ckpt boundary."""
        med = self.median()
        v = self.ewma.get(host, 0.0)
        if med <= 0 or v <= self.factor * med:
            return "none"
        if v <= 2 * self.factor * med:
            return "reduce_insitu_pi"      # free host cores for the app
        return "replace_at_checkpoint"

    def report(self) -> dict:
        return {"median_s": self.median(), "stragglers": self.stragglers(),
                "ewma": dict(self.ewma)}


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    dropped_hosts: list[int]
    # how each old TP shard index maps into the new model axis
    model_merge_factor: int

    @property
    def new_device_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def plan_elastic_remesh(old_shape: tuple, axis_names: tuple,
                        surviving_devices: int,
                        failed_hosts: Optional[list[int]] = None) -> RemeshPlan:
    """Largest (.., data', model') grid that fits the survivors.

    'model' may only *shrink by integer division* (TP shards merge cleanly:
    new shard j = concat of old shards j*f..j*f+f-1); 'data' absorbs the
    rest. The 'pod' axis, when present, only shrinks by whole pods.
    """
    sizes = dict(zip(axis_names, old_shape))
    old_model = sizes.get("model", 1)
    old_pod = sizes.get("pod", 1)
    best = None
    for pod in range(old_pod, 0, -1):
        for f in [1, 2, 4, 8, 16]:
            if old_model % f:
                continue
            model = old_model // f
            data = surviving_devices // (pod * model)
            if data < 1:
                continue
            n = pod * data * model
            if n <= surviving_devices and (best is None or n > best[0]):
                best = (n, pod, data, model, f)
    if best is None:
        raise ValueError("no valid re-mesh for the surviving devices")
    _, pod, data, model, f = best
    if "pod" in sizes:
        new_shape = (pod, data, model)
    else:
        new_shape = (data, model)
    return RemeshPlan(tuple(old_shape), new_shape, tuple(axis_names),
                      failed_hosts or [], f)
