"""Fault tolerance: failure detection, elastic re-mesh planning, stragglers.

The paper's checkpointing motivation ("limited walltimes and/or failures of
system components") is the *why*; this module is the *how* for a 1000+-node
posture:

  * HeartbeatTracker — per-host liveness from periodic beats; a host missing
    ``grace`` seconds is declared failed (in a real deployment the beat is a
    tiny all-reduce or a KV write; here it is a call, injected by tests).
    Takes a ``clock=`` callable (the Session's injected monotonic clock) so
    failure detection is deterministic under test-driven time.
  * StragglerMonitor — per-host step-time EWMA; hosts slower than
    ``factor`` x median are flagged. Mitigation policy (documented, and what
    the loop implements): flagged hosts get their *in-situ* p_i budget
    reduced first (in-situ work is the elastic slack on a node — exactly the
    paper's observation that in-situ tasks share node resources), and if
    still slow they are scheduled for replacement at the next checkpoint
    boundary.
  * FaultController — the live subsystem the ``fault`` Session preset
    instantiates: every firing beats the heartbeat and feeds the EWMA, and
    mitigation decisions are *applied* (shed in-situ load by widening every
    bound task's cadence; queue replace-at-checkpoint candidates) instead
    of just reported.
  * plan_elastic_remesh — given the surviving host count, pick the largest
    (data, model) grid that (a) fits the survivors, (b) keeps 'model' a
    divisor of the old model axis (so TP shards merge/split cleanly), and
    return the shard remap plan. Restore is checkpoint-based: state is
    logically complete on disk (in-situ compressed), so resuming on the new
    mesh is read + re-place (serialization.read_state with new shardings).

Recovery invariant: checkpoint steps are atomic (manifest-last), so the
resumed step is always a step that fully finished.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np


class HeartbeatTracker:
    """Per-host liveness from periodic beats.

    ``clock`` is any monotonic zero-arg callable (default
    ``time.monotonic``); ``last_seen`` is seeded from it at construction so
    a test-injected clock starting near 0 does not declare every host dead
    before its first beat. ``beat``/``failed_hosts``/``alive_hosts`` read
    the same clock when ``now`` is not given.
    """

    def __init__(self, hosts: list[int], grace_s: float = 30.0,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.grace_s = grace_s
        self._clock = clock if clock is not None else time.monotonic
        now = self._clock()
        self.last_seen: dict[int, float] = {h: now for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_seen[host] = self._clock() if now is None else now

    def failed_hosts(self, now: Optional[float] = None) -> list[int]:
        now = self._clock() if now is None else now
        return sorted(h for h, t in self.last_seen.items()
                      if now - t > self.grace_s)

    def alive_hosts(self, now: Optional[float] = None) -> list[int]:
        failed = set(self.failed_hosts(now))
        return sorted(h for h in self.last_seen if h not in failed)


class StragglerMonitor:
    """Step-time EWMA per host; flags hosts slower than factor x median."""

    def __init__(self, alpha: float = 0.2, factor: float = 1.5) -> None:
        self.alpha = alpha
        self.factor = factor
        self.ewma: dict[int, float] = {}

    def observe(self, host: int, step_s: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_s if prev is None
                           else (1 - self.alpha) * prev + self.alpha * step_s)

    def median(self) -> float:
        if not self.ewma:
            return 0.0
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2]

    def stragglers(self) -> list[int]:
        med = self.median()
        if med <= 0:
            return []
        return sorted(h for h, v in self.ewma.items()
                      if v > self.factor * med)

    def mitigation(self, host: int) -> str:
        """Policy: shed in-situ load first, then replace at ckpt boundary."""
        med = self.median()
        v = self.ewma.get(host, 0.0)
        if med <= 0 or v <= self.factor * med:
            return "none"
        if v <= 2 * self.factor * med:
            return "reduce_insitu_pi"      # free host cores for the app
        return "replace_at_checkpoint"

    def report(self) -> dict:
        return {"median_s": self.median(), "stragglers": self.stragglers(),
                "ewma": dict(self.ewma)}


class FaultController:
    """Liveness + straggler policy for one run, with mitigations applied live.

    The ``fault`` Session preset builds one of these per task. Each sink
    firing calls :meth:`ingest` with the hosts' beats/step-times; the
    controller drives a :class:`HeartbeatTracker` and a
    :class:`StragglerMonitor` on the session's injected monotonic clock and
    *applies* :meth:`StragglerMonitor.mitigation` transitions:

      reduce_insitu_pi       shed in-situ load first — the session widens
                             every bound task's effective firing cadence
                             (:meth:`~repro.core.session.Session.shed_insitu`)
      replace_at_checkpoint  the host joins ``replace_candidates``; the
                             operator (or the elastic-restore path) swaps it
                             out at the next checkpoint boundary

    A mitigation is applied once per *escalation* (none -> reduce ->
    replace), not per firing, so a persistently slow host does not widen
    cadences without bound on its own — sustained pressure is the
    time-budget ``Adaptive`` trigger's job.
    """

    def __init__(self, hosts: Sequence[int], *, grace_s: float = 30.0,
                 alpha: float = 0.2, factor: float = 1.5,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.hosts = list(hosts)
        self.grace_s = float(grace_s)
        self._clock = clock if clock is not None else time.monotonic
        self.heartbeats = HeartbeatTracker(self.hosts, self.grace_s,
                                           clock=self._clock)
        self.monitor = StragglerMonitor(alpha=alpha, factor=factor)
        self._session: Any = None
        self._own_task: Optional[str] = None
        self.mitigations: dict[int, str] = {}
        self.replace_candidates: set[int] = set()
        self.shed_events = 0
        self.widened: dict[str, int] = {}

    # -- session wiring -------------------------------------------------------

    def attach(self, session: Any, own_task: Optional[str] = None) -> None:
        """Adopt the session's clock and shedding surface (preset 'attach').

        Re-seeds the heartbeat tracker from the session clock so injected
        test clocks and ``time.monotonic`` behave identically.
        """
        self._session = session
        self._own_task = own_task
        self._clock = session.clock
        self.heartbeats = HeartbeatTracker(self.hosts, self.grace_s,
                                           clock=self._clock)

    def _shed(self) -> None:
        self.shed_events += 1
        if self._session is not None:
            exclude = (self._own_task,) if self._own_task else ()
            self.widened.update(self._session.shed_insitu(exclude=exclude))

    # -- ingest (the preset sink) --------------------------------------------

    @staticmethod
    def _beats_of(payload: Any) -> dict[int, Optional[float]]:
        """Normalize a health payload into {host: step_s-or-None}.

        Accepted forms: ``{"host": 0, "step_s": 0.12}`` (single host, time
        optional), ``{"hosts": {0: 0.12, 1: 0.3}}``, or a bare
        ``{host: step_s}`` mapping with integer keys.
        """
        if isinstance(payload, Mapping):
            if "host" in payload:
                step_s = payload.get("step_s")
                return {int(payload["host"]):
                        None if step_s is None else float(step_s)}
            if "hosts" in payload:
                return {int(h): None if v is None else float(v)
                        for h, v in dict(payload["hosts"]).items()}
            if payload and all(isinstance(k, int) for k in payload):
                return {int(h): None if v is None else float(v)
                        for h, v in payload.items()}
        raise ValueError(
            "fault payload must be {'host': h, 'step_s': s}, "
            "{'hosts': {h: s}}, or a {host: step_s} mapping; got "
            f"{type(payload).__name__}: {payload!r}")

    def ingest(self, step: int, payload: Any) -> dict:
        """One health firing: beat + observe, then evaluate/apply policy."""
        beats = self._beats_of(payload)
        now = self._clock()
        for host, step_s in beats.items():
            self.heartbeats.beat(host, now=now)
            if step_s is not None:
                self.monitor.observe(host, step_s)
        for host in sorted(self.monitor.ewma):
            decision = self.monitor.mitigation(host)
            prev = self.mitigations.get(host, "none")
            if decision == "none":
                self.mitigations.pop(host, None)
                continue
            self.mitigations[host] = decision
            if decision != prev:               # apply once per escalation
                self._shed()
                if decision == "replace_at_checkpoint":
                    self.replace_candidates.add(host)
        return {"step": step,
                "failed_hosts": self.heartbeats.failed_hosts(now=now),
                "stragglers": self.monitor.stragglers(),
                "mitigations": dict(self.mitigations)}

    # -- reporting ------------------------------------------------------------

    def failed_hosts(self) -> list[int]:
        return self.heartbeats.failed_hosts(now=self._clock())

    def report(self) -> dict:
        now = self._clock()
        return {"failed_hosts": self.heartbeats.failed_hosts(now=now),
                "alive_hosts": self.heartbeats.alive_hosts(now=now),
                "stragglers": self.monitor.stragglers(),
                "straggler_ewma": dict(self.monitor.ewma),
                "median_step_s": self.monitor.median(),
                "mitigations": dict(self.mitigations),
                "replace_at_checkpoint": sorted(self.replace_candidates),
                "shed_events": self.shed_events,
                "widened": dict(self.widened)}


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    dropped_hosts: list[int]
    # how each old TP shard index maps into the new model axis
    model_merge_factor: int

    @property
    def new_device_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n

    def shard_sources(self, new_index: int) -> range:
        """Old model-shard indices that merge into new shard ``new_index``."""
        f = self.model_merge_factor
        return range(new_index * f, (new_index + 1) * f)


def plan_elastic_remesh(old_shape: tuple, axis_names: tuple,
                        surviving_devices: int,
                        failed_hosts: Optional[list[int]] = None) -> RemeshPlan:
    """Largest (.., data', model') grid that fits the survivors.

    'model' may only *shrink by integer division* (TP shards merge cleanly:
    new shard j = concat of old shards j*f..j*f+f-1), for any divisor ``f``
    of the old model axis; 'data' absorbs the rest. The 'pod' axis, when
    present, only shrinks by whole pods. Ties are deterministic: at equal
    device count prefer keeping more pods, then the smallest merge factor
    (merging TP shards is the expensive move — it reshapes every
    tensor-parallel leaf — so it is chosen last).
    """
    sizes = dict(zip(axis_names, old_shape))
    old_model = sizes.get("model", 1)
    old_pod = sizes.get("pod", 1)
    factors = [f for f in range(1, old_model + 1) if old_model % f == 0]
    best = None           # maximize (device count, pods kept, -merge factor)
    for pod in range(old_pod, 0, -1):
        for f in factors:
            model = old_model // f
            data = surviving_devices // (pod * model)
            if data < 1:
                continue
            n = pod * data * model
            key = (n, pod, -f)
            if best is None or key > best[0]:
                best = (key, pod, data, model, f)
    if best is None:
        raise ValueError("no valid re-mesh for the surviving devices")
    _, pod, data, model, f = best
    if "pod" in sizes:
        new_shape = (pod, data, model)
    else:
        new_shape = (data, model)
    return RemeshPlan(tuple(old_shape), new_shape, tuple(axis_names),
                      failed_hosts or [], f)


def merge_model_shards(shards: Sequence[np.ndarray], merge_factor: int,
                       axis: int = 0) -> list[np.ndarray]:
    """Merge old TP shards into the shrunken model axis of a RemeshPlan.

    New shard ``j`` is the concatenation of old shards
    ``j*f .. j*f+f-1`` along ``axis`` (the dim the old mesh tensor-
    parallelized). v2 checkpoints store every leaf logically complete, so
    the packed-shard restore path never calls this — re-placement under the
    shrunken mesh's shardings *is* the merge; this is the explicit-buffer
    path for assembling host-side state from per-device buffers (e.g. a
    streaming replica that held only its own slices).
    """
    f = int(merge_factor)
    if f < 1:
        raise ValueError(f"merge_factor must be >= 1, got {merge_factor}")
    if len(shards) % f:
        raise ValueError(
            f"cannot merge {len(shards)} shards by factor {f}: the old "
            "model axis must be an integer multiple of the merge factor")
    return [np.concatenate([np.asarray(s) for s in shards[j * f:(j + 1) * f]],
                           axis=axis)
            for j in range(len(shards) // f)]


@dataclass(frozen=True)
class ElasticRestore:
    """What ``Session.restore(elastic=True)`` resolved: the remesh plan,
    the concrete surviving-device mesh, and the checkpoint step resumed."""
    plan: RemeshPlan
    mesh: Any
    step: int
