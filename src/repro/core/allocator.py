"""p_o/p_i resource allocator + online performance model.

Reproduces the paper's two allocation findings and packages its §V future
work (a performance model that *chooses* the in-situ configuration):

  * Table I / F1: with p_o + p_i = p_t fixed, the best asynchronous split
    puts the application and the in-situ task at roughly equal duration —
    and the optimal p_i grows with scale because the in-situ task scales
    worse than the application.
  * F6: when the task is cheap relative to the resources, SYNC wins (the
    async staging overhead is no longer amortized); ASYNC pays off for
    expensive or poorly-scaling tasks.

Both sides are modelled with Amdahl curves  t(p) = serial + parallel / p,
fitted online from telemetry observations (least squares in 1/p). The model
then answers: best split for ASYNC, and SYNC-vs-ASYNC mode choice given the
per-firing staging overhead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class AmdahlModel:
    """t(p) = serial + parallel/p, fitted from (p, t) observations."""
    serial: float = 0.0
    parallel: float = 1.0
    observations: list[tuple[int, float]] = field(default_factory=list)

    def observe(self, p: int, t: float) -> None:
        self.observations.append((int(p), float(t)))
        self._fit()

    def _fit(self) -> None:
        obs = self.observations
        if len(obs) == 1:
            p, t = obs[0]
            # single point: assume fully parallel (optimistic until contradicted)
            self.serial, self.parallel = 0.0, t * p
            return
        a = np.array([[1.0, 1.0 / p] for p, _ in obs])
        b = np.array([t for _, t in obs])
        (s, par), *_ = np.linalg.lstsq(a, b, rcond=None)
        self.serial = max(float(s), 0.0)
        self.parallel = max(float(par), 0.0)

    def predict(self, p: int) -> float:
        return self.serial + self.parallel / max(p, 1)


@dataclass
class Plan:
    mode: str            # 'sync' | 'async'
    p_app: int
    p_insitu: int
    predicted_total_s: float
    detail: dict = field(default_factory=dict)


class Allocator:
    """Chooses the in-situ mode and the p_o/p_i split for a workflow.

    ``handoff_s``: per-firing hand-off cost (device->host + enqueue) — the
    part of async that is *never* hidden (paper §III-A "small but unavoidable
    overhead").
    """

    def __init__(self, p_total: int, *, handoff_s: float = 0.0) -> None:
        self.p_total = p_total
        self.handoff_s = handoff_s
        self.app = AmdahlModel()
        self.task = AmdahlModel()

    # -- observations (fed from Telemetry aggregates) -----------------------------

    def observe_app(self, p_app: int, seconds_per_step: float) -> None:
        self.app.observe(p_app, seconds_per_step)

    def observe_task(self, p_insitu: int, seconds_per_firing: float) -> None:
        self.task.observe(p_insitu, seconds_per_firing)

    # -- planning -------------------------------------------------------------

    def plan(self, n_steps: int, every: int) -> Plan:
        """Best (mode, split) for a run of n_steps with a task every ``every``."""
        n_fire = max(1, n_steps // max(every, 1))
        # SYNC: all resources for both phases, serialized (Fig. 1a)
        t_sync = (n_steps * self.app.predict(self.p_total)
                  + n_fire * (self.task.predict(self.p_total) + self.handoff_s))
        best_async: Optional[Plan] = None
        for p_i in range(1, self.p_total):
            p_o = self.p_total - p_i
            app_total = n_steps * (self.app.predict(p_o)
                                   + self.handoff_s * n_fire / n_steps)
            task_total = n_fire * self.task.predict(p_i)
            # Fig. 1b: both sides run concurrently; the longer one dominates,
            # plus the non-overlapped first hand-off / last task tail.
            tail = self.task.predict(p_i)
            total = max(app_total, task_total) + min(app_total, task_total) * 0.0 + tail
            if best_async is None or total < best_async.predicted_total_s:
                best_async = Plan("async", p_o, p_i, total, {
                    "app_total_s": app_total, "task_total_s": task_total})
        assert best_async is not None
        if t_sync <= best_async.predicted_total_s:
            return Plan("sync", self.p_total, 0, t_sync,
                        {"async_alternative_s": best_async.predicted_total_s})
        best_async.detail["sync_alternative_s"] = t_sync
        return best_async

    def balance_quality(self, plan: Plan) -> float:
        """|app - task| / max(...): ~0 at the paper's optimum (Table I)."""
        if plan.mode != "async":
            return 1.0
        a = plan.detail["app_total_s"]
        t = plan.detail["task_total_s"]
        return abs(a - t) / max(a, t, 1e-12)
