"""StagingBuffer — the ADIOS2 "insituMPI" analog.

In the paper's asynchronous mode (Fig. 1b), the application transfers data to
the in-situ ranks via an ADIOS2 writer/reader pair and *only blocks for the
send*; both sides then proceed concurrently. Our TPU-host analog:

  producer (training loop):  put(step, payload)       # blocks only on hand-off
  consumers (p_i workers):   get() -> StagedItem      # FIFO, blocking

The ring is bounded (``capacity``) — a slow in-situ side eventually exerts
backpressure on the producer, which is precisely the paper's F3 regime (task
issued every 10 steps outgrows all spare cores and dominates). The time the
producer spends blocked on a full ring is recorded as ``staging/wait`` so the
benchmarks can attribute it, like the paper attributes ADIOS2 stalls.

Payloads are host numpy arrays (the device->host ``jax.device_get`` happens in
the engine *before* put, because that transfer is the part of the hand-off the
device genuinely serializes on).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.core.telemetry import Telemetry


@dataclass
class StagedItem:
    step: int
    name: str
    payload: Any                      # pytree of np.ndarray / bytes / metadata
    group: Any = None                 # _SyncGroup latch for sharded SYNC work
    shard: int = 0                    # shard index within the group
    enqueued_at: float = field(default_factory=time.perf_counter)


class Closed(Exception):
    """Raised by get() after close() once the ring has drained."""


_SENTINEL = object()   # close() wake-up marker (never a real item)


class StagingBuffer:
    def __init__(self, capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._q: "queue.Queue[StagedItem]" = queue.Queue(maxsize=capacity)
        self._closed = threading.Event()
        self._telemetry = telemetry
        self.puts = 0
        self.gets = 0

    # -- producer side --------------------------------------------------------

    def put(self, item: StagedItem, timeout: Optional[float] = None) -> None:
        if self._closed.is_set():
            raise Closed("staging buffer is closed")
        t0 = time.perf_counter()
        self._q.put(item, timeout=timeout)
        t1 = time.perf_counter()
        self.puts += 1
        if self._telemetry is not None and t1 - t0 > 1e-5:
            self._telemetry.record("staging/wait", t0, t1, step=item.step)

    def try_put(self, item: StagedItem) -> bool:
        """Non-blocking variant (drop-on-full policies, e.g. telemetry tasks)."""
        if self._closed.is_set():
            raise Closed("staging buffer is closed")
        try:
            self._q.put_nowait(item)
            self.puts += 1
            return True
        except queue.Full:
            return False

    # -- consumer side ---------------------------------------------------------

    def get(self, timeout: float = 0.1) -> StagedItem:
        """Blocking pop; raises Closed when the buffer is closed *and* empty."""
        while True:
            try:
                item = self._q.get(timeout=timeout)
                if item is _SENTINEL:
                    # propagate the wake-up to any sibling consumer
                    try:
                        self._q.put_nowait(_SENTINEL)
                    except queue.Full:
                        pass
                    raise Closed
                self.gets += 1
                return item
            except queue.Empty:
                if self._closed.is_set():
                    raise Closed
                continue

    # -- lifecycle --------------------------------------------------------------

    def close(self) -> None:
        """Close and wake blocked consumers immediately (sentinel)."""
        self._closed.set()
        try:
            self._q.put_nowait(_SENTINEL)
        except queue.Full:
            pass

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def __len__(self) -> int:
        return self._q.qsize()
