"""StagingBuffer — the ADIOS2 "insituMPI" analog — and the pending-transfer
token of the two-phase hand-off.

In the paper's asynchronous mode (Fig. 1b), the application transfers data to
the in-situ ranks via an ADIOS2 writer/reader pair and *only blocks for the
send*; both sides then proceed concurrently. Our TPU-host analog:

  producer (training loop):  put(step, payload)       # blocks only on hand-off
  consumers (p_i workers):   get() -> StagedItem      # FIFO, blocking

The ring is bounded (``capacity``) — a slow in-situ side eventually exerts
backpressure on the producer, which is precisely the paper's F3 regime (task
issued every 10 steps outgrows all spare cores and dominates). The time the
producer spends blocked on a full ring is recorded as ``staging/wait`` so the
benchmarks can attribute it, like the paper attributes ADIOS2 stalls.

Since the two-phase hand-off, the payload a producer stages is usually a
``PendingHandoff`` token: the loop thread only *dispatches* the device->host
copies (``copy_to_host_async``) and enqueues the token; the consumer side
materializes to numpy. The ring's bounded capacity then double-buffers the
transfers — step N+1's compute overlaps step N's D2H drain.

Wake-ups are condition-variable driven: a consumer blocked in ``get`` is
notified the instant an item is put or the buffer closes — there is no
poll/timeout loop burning wake-ups on an idle ring.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from queue import Empty, Full
from typing import Any, Callable, Optional

from repro.core.telemetry import Telemetry


@dataclass
class StagedItem:
    step: int
    name: str
    payload: Any                      # pytree / PendingHandoff / bytes / meta
    group: Any = None                 # _SyncGroup latch for sharded SYNC work
    shard: int = 0                    # shard index within the group
    enqueued_at: float = field(default_factory=time.perf_counter)


class Closed(Exception):
    """Raised by get() after close() once the ring has drained."""


class PendingHandoff:
    """A dispatched-but-not-yet-materialized device->host transfer.

    Phase 1 (producer/loop thread): the runtime starts the D2H copy for every
    array leaf (``copy_to_host_async``) and wraps the still-device payload in
    this token — that dispatch is the only hand-off cost on the critical path.
    Phase 2 (consumer/worker thread): ``materialize()`` runs the task's
    hand-off function (default: numpy-materialize every leaf), paying the
    transfer wait off the loop. Idempotent and thread-safe: the first caller
    materializes, later callers get the cached result.

    JAX arrays are immutable, so the token pins the exact values that were
    live at dispatch time — but buffer *donation* by the app's next jitted
    step deletes originals out from under a deferred token, which is why the
    runtime's dispatch phase snapshots jax leaves with a device-side copy
    first (``PipelineTask.snapshot``).
    """

    __slots__ = ("payload", "_materialize_fn", "_lock", "_done", "_result")

    def __init__(self, payload: Any,
                 materialize_fn: Callable[[Any], Any]) -> None:
        self.payload = payload
        self._materialize_fn = materialize_fn
        self._lock = threading.Lock()
        self._done = False
        self._result: Any = None

    def materialize(self) -> Any:
        with self._lock:
            if not self._done:
                self._result = self._materialize_fn(self.payload)
                self._done = True
                self.payload = None          # drop the device refs promptly
        return self._result

    @property
    def materialized(self) -> bool:
        return self._done


class StagingBuffer:
    def __init__(self, capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._items: deque[StagedItem] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        self._telemetry = telemetry
        self.puts = 0
        self.gets = 0

    # -- producer side --------------------------------------------------------

    def put(self, item: StagedItem, timeout: Optional[float] = None) -> None:
        t0 = time.perf_counter()
        waited = False
        with self._not_full:
            if self._closed:
                raise Closed("staging buffer is closed")
            while len(self._items) >= self.capacity:
                waited = True
                if not self._not_full.wait(timeout):
                    raise Full
                if self._closed:
                    raise Closed("staging buffer is closed")
            self._items.append(item)
            self.puts += 1
            self._not_empty.notify()
        if self._telemetry is not None and waited:
            t1 = time.perf_counter()
            if t1 - t0 > 1e-5:
                self._telemetry.record("staging/wait", t0, t1, step=item.step)

    def try_put(self, item: StagedItem) -> bool:
        """Non-blocking variant (drop-on-full policies, e.g. telemetry tasks)."""
        with self._not_full:
            if self._closed:
                raise Closed("staging buffer is closed")
            if len(self._items) >= self.capacity:
                return False
            self._items.append(item)
            self.puts += 1
            self._not_empty.notify()
            return True

    # -- consumer side ---------------------------------------------------------

    def get(self, timeout: Optional[float] = None) -> StagedItem:
        """Blocking pop; raises Closed when the buffer is closed *and* empty.

        Consumers are woken immediately by put()/close() — no polling. A
        ``timeout`` bounds the wait (raises ``queue.Empty`` on expiry with
        the buffer still open).
        """
        with self._not_empty:
            while not self._items:
                if self._closed:
                    raise Closed
                if not self._not_empty.wait(timeout):
                    if self._closed:
                        raise Closed
                    raise Empty
            item = self._items.popleft()
            self.gets += 1
            self._not_full.notify()
            return item

    # -- lifecycle --------------------------------------------------------------

    def close(self) -> None:
        """Close and wake every blocked producer/consumer immediately."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
