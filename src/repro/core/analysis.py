"""In-situ analytics tasks — the framework's "image generation".

The paper's first in-situ task renders images from the live simulation state
(ParaView Catalyst) instead of writing 8-26 GB VTK files per step. The ML
analog renders *small summaries of the live training state* instead of
dumping tensors: histograms, norm sheets, spectral energy profiles, and a
low-res "heatmap image" of weight matrices. Each artifact is O(KB) where the
raw state is O(GB) — the same I/O-avoidance argument.

These run on host CPU over numpy (which releases the GIL in its inner loops),
so async workers genuinely overlap with the device step. ``work`` is a knob
(spectral profile depth / histogram passes) so benchmarks can scale the task
cost the way the paper scales image frequency (F3) and resolution.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

PyTree = Any


@dataclass
class Artifact:
    """One rendered summary (the "image"). Tiny by construction."""
    step: int
    name: str
    stats: dict[str, float] = field(default_factory=dict)
    tables: dict[str, np.ndarray] = field(default_factory=dict)

    def nbytes(self) -> int:
        return sum(t.nbytes for t in self.tables.values()) + 16 * len(self.stats)


def tensor_summary(name: str, arr: np.ndarray, step: int, *,
                   bins: int = 64, work: int = 1,
                   image_px: int = 64) -> Artifact:
    """Histogram + norms + spectral profile + low-res heatmap for one tensor."""
    a = np.asarray(arr, dtype=np.float32).reshape(-1)
    art = Artifact(step, name)
    art.stats["l2"] = float(np.linalg.norm(a))
    art.stats["linf"] = float(np.max(np.abs(a))) if a.size else 0.0
    art.stats["mean"] = float(a.mean()) if a.size else 0.0
    art.stats["std"] = float(a.std()) if a.size else 0.0
    art.stats["frac_zero"] = float(np.mean(a == 0)) if a.size else 0.0
    hist, edges = np.histogram(a, bins=bins)
    art.tables["hist"] = hist.astype(np.int64)
    art.tables["hist_edges"] = edges.astype(np.float32)
    # spectral energy profile: rFFT power in log-spaced bands; ``work`` repeats
    # the transform on shifted copies (cost knob, like image supersampling)
    n = min(a.size, 1 << 16)
    if n >= 16:
        prof = np.zeros(32, np.float32)
        for w in range(max(1, work)):
            seg = a[w * 17 % max(1, a.size - n) if a.size > n else 0:][:n]
            p = np.abs(np.fft.rfft(seg)) ** 2
            idx = np.minimum(
                (np.log1p(np.arange(p.size)) / math.log1p(p.size) * 31).astype(int),
                31)
            prof += np.bincount(idx, weights=p, minlength=32)[:32].astype(np.float32)
        art.tables["spectrum"] = prof / max(1, work)
    # the "image": a low-res mean-pooled heatmap of the 2D-folded tensor
    side = int(math.sqrt(a.size))
    if side >= image_px:
        m = a[: side * side].reshape(side, side)
        f = side // image_px
        img = m[: f * image_px, : f * image_px].reshape(
            image_px, f, image_px, f).mean(axis=(1, 3))
        art.tables["image"] = img.astype(np.float32)
    return art


def summarize_tree(tree_of_np: Mapping[str, np.ndarray], step: int, *,
                   work: int = 1) -> list[Artifact]:
    return [tensor_summary(k, v, step, work=work)
            for k, v in sorted(tree_of_np.items())]


def gradient_health(grads: Mapping[str, np.ndarray], step: int) -> Artifact:
    """Single roll-up artifact: global grad norm, per-tensor norm sheet, NaN flags."""
    art = Artifact(step, "grad_health")
    sq, names, norms = 0.0, [], []
    any_nan = False
    for k, v in sorted(grads.items()):
        a = np.asarray(v, np.float32)
        n2 = float(np.sum(a * a))
        sq += n2
        names.append(k)
        norms.append(math.sqrt(n2))
        any_nan |= bool(np.isnan(a).any())
    art.stats["global_norm"] = math.sqrt(sq)
    art.stats["any_nan"] = float(any_nan)
    art.tables["norm_sheet"] = np.asarray(norms, np.float32)
    return art
