"""One transport layer for every sink: the ``Sink``/``Source`` protocol.

Every terminal pipeline stage in this tree used to be an ad-hoc
``sink(step, payload)`` closure writing wherever it pleased — the
checkpoint manager's atomic directory commit, four preset closures in
``repro.core.session``, the ``SnapshotStore`` publish path. That left no
seam where a network transport, replication, or a SENSEI/ISAAC-style live
consumer could plug in. The openPMD/ADIOS2 transition argument (PAPERS.md)
is exactly this refactor at cluster scale: replace file-based staging with
*streaming pipelines* between producer and consumer processes, behind one
declarative transport description.

This module is that seam. A :class:`Sink` is the uniform terminal:

    open() -> write_frame(Frame) ... -> flush() -> close()

Every frame carries *step + stream + seq + codec* metadata, and payloads
ride the existing v2 chunk-parallel framing from :mod:`repro.core.codecs`
(arrays are framed leaves; trees keep their structure in a JSON skeleton).
Three backends share the wire/frame format:

  ``FileSink``    one atomically-published file per frame
                  (write tmp -> fsync -> rename -> fsync dir — the same
                  protocol the checkpoint/snapshot writers use; the shared
                  :func:`atomic_write_bytes` is hoisted here).
  ``MemorySink``  frames in a list (in-process probes, tests).
  ``StreamSink``  length-prefixed crc-checked frames over a TCP socket —
                  in-situ across nodes. Sends are failure-aware: a broken
                  or timed-out socket raises the runtime's
                  ``TransientError``, so the PR-7 retry/backoff/degrade
                  path covers network transports, and the bounded staging
                  ring upstream means a slow consumer triggers the
                  block/drop/adapt backpressure policies instead of
                  stalling the train loop.

The consumer side mirrors it: ``MemorySink.frames`` / ``FileSource`` /
``StreamSource`` yield the same :class:`Frame` objects, and
:func:`unpack_payload` decodes them with the shared codec registry.

``StreamSource`` additionally exposes a *steering channel* back to the
producer: :meth:`StreamSource.send_control` ships a length-prefixed
control frame upstream; the producer's ``Session`` polls
``StreamSink.poll_control`` between emits and retunes live tasks
(cadence, lossy threshold) mid-run — in-situ made steerable, the ISAAC
pattern.

Plan options declare transports as URLs::

    "file:///var/run/artifacts"   FileSink rooted at that directory
    "memory://"                   MemorySink
    "tcp://host:port"             StreamSink to a listening StreamSource

Wire format (one frame)::

    u32 body_len | body
    body: TMAGIC | u8 version | u8 kind | u16 stream_len | u8 codec_len
          | u32 seq | i64 step | u32 payload_len | u32 crc32
          | stream | codec | payload

``crc32`` covers the whole body except itself; ``seq`` increments per
stream on the writing sink, so a reader detects lost frames (a producer
that reconnected after dropping writes) as a typed :class:`StreamGapError`
naming the stream and step rather than silently skipping data.
"""
from __future__ import annotations

import dataclasses
import json
import os
import select
import socket
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional, Sequence

import numpy as np

from repro.core import codecs

TMAGIC = b"RPTF"
_VERSION = 1

KIND_DATA = 0
KIND_CONTROL = 1
KIND_BYE = 2
_KIND_NAMES = {KIND_DATA: "data", KIND_CONTROL: "control", KIND_BYE: "bye"}

# body: version kind stream_len codec_len seq step payload_len crc
_HEADER = "<BBHBIqII"
_HEADER_SIZE = 4 + struct.calcsize(_HEADER)
_MAX_FRAME = 1 << 31            # sanity bound on a declared body length

# payload codecs (Frame.codec): how Frame.payload decodes
CODEC_TREE = "tree"             # pack_payload/unpack_payload pytree framing
CODEC_JSON = "json"             # plain JSON bytes (control frames)
CODEC_RAW = "raw"               # opaque bytes (e.g. snapshot-chain frames)
CODEC_FILE = "file"             # pack_file/unpack_file (path, bytes) pairs


# ---------------------------------------------------------------------------
# typed errors — every one names the stream/step it can know
# ---------------------------------------------------------------------------

class TransportError(RuntimeError):
    """Base for transport-layer failures."""


class FrameCorruptError(TransportError):
    """A frame failed structural validation (magic/crc/truncation). Names
    the stream and step when the header survived well enough to read them."""

    def __init__(self, reason: str, *, stream: Optional[str] = None,
                 step: Optional[int] = None) -> None:
        at = (f"stream {stream!r}" if stream is not None else "stream ?")
        at += f", step {step}" if step is not None else ", step ?"
        super().__init__(f"transport frame ({at}): {reason}")
        self.stream = stream
        self.step = step


class StreamGapError(TransportError):
    """Per-stream frame seqs are contiguous by construction; a gap means
    frames were lost (e.g. a producer reconnected after dropped writes)."""

    def __init__(self, stream: str, step: int, expected: int,
                 got: int) -> None:
        super().__init__(
            f"stream {stream!r}, step {step}: frame seq gap — expected "
            f"{expected}, got {got} ({got - expected} frame(s) lost)")
        self.stream = stream
        self.step = step
        self.expected = expected
        self.got = got


def _transient(msg: str) -> Exception:
    """A network failure the runtime should retry (lazy import: runtime
    imports this module at top level, so the reverse edge must be lazy)."""
    from repro.core.runtime import TransientError
    return TransientError(msg)


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Frame:
    """One transport frame: step + stream + codec metadata, opaque payload."""
    stream: str
    step: int
    seq: int
    codec: str
    payload: bytes
    kind: int = KIND_DATA

    @property
    def kind_name(self) -> str:
        return _KIND_NAMES.get(self.kind, f"kind{self.kind}")


def pack_frame(frame: Frame) -> bytes:
    """Frame -> wire bytes (length prefix + crc-covered body)."""
    sb = frame.stream.encode()
    cb = frame.codec.encode()
    if len(sb) > 0xFFFF or len(cb) > 0xFF:
        raise ValueError("stream/codec name too long for the frame header")
    prefix = struct.pack("<BBHBIqI", _VERSION, frame.kind, len(sb), len(cb),
                         frame.seq, frame.step, len(frame.payload))
    crc = zlib.crc32(prefix + sb + cb + frame.payload)
    body = (TMAGIC + prefix + struct.pack("<I", crc) + sb + cb
            + frame.payload)
    return struct.pack("<I", len(body)) + body


def parse_body(body: bytes) -> Frame:
    """Wire body (past the length prefix) -> Frame; raises
    :class:`FrameCorruptError` naming stream/step where readable."""
    if len(body) < _HEADER_SIZE:
        raise FrameCorruptError(
            f"truncated frame header ({len(body)} bytes)")
    if body[:4] != TMAGIC:
        raise FrameCorruptError("bad frame magic")
    version, kind, slen, clen, seq, step, plen, crc = struct.unpack_from(
        _HEADER, body, 4)
    if version != _VERSION:
        raise FrameCorruptError(f"unsupported frame version {version}")
    # best-effort stream/step for the error message even when the crc fails:
    # the reader deserves to know *which* stream broke
    stream = codec = None
    if len(body) >= _HEADER_SIZE + slen + clen:
        stream = body[_HEADER_SIZE:_HEADER_SIZE + slen].decode(
            errors="replace")
        codec = body[_HEADER_SIZE + slen:_HEADER_SIZE + slen + clen].decode(
            errors="replace")
    if len(body) != _HEADER_SIZE + slen + clen + plen:
        raise FrameCorruptError(
            f"truncated frame body ({len(body)} of "
            f"{_HEADER_SIZE + slen + clen + plen} bytes)",
            stream=stream, step=step)
    if zlib.crc32(body[4:_HEADER_SIZE - 4] + body[_HEADER_SIZE:]) != crc:
        raise FrameCorruptError("frame crc mismatch (bit flip or tear)",
                                stream=stream, step=step)
    payload = body[_HEADER_SIZE + slen + clen:]
    return Frame(stream, step, seq, codec, payload, kind=kind)


# ---------------------------------------------------------------------------
# payload packing: pytrees over the v2 chunk-parallel codec framing
# ---------------------------------------------------------------------------

def pack_payload(obj: Any, *, codec: str = "zlib",
                 parallel: bool = True) -> bytes:
    """Pack a pytree payload into one self-describing byte string.

    The tree *structure* (dicts, lists, scalars, dataclass field names)
    becomes a JSON skeleton; every array leaf is framed by the shared
    chunk-parallel :func:`repro.core.codecs.encode` (so big leaves
    compress with the same v2 layout checkpoints use), and raw
    ``bytes`` leaves ship verbatim. Tuples flatten to lists and
    dataclasses to ``{"__dataclass__": name, "fields": {...}}`` — the
    consumer gets plain data, which is the point of a wire format.
    """
    blobs: list[bytes] = []
    pool = codecs.codec_pool() if parallel else None

    def strip(x: Any) -> Any:
        if x is None or isinstance(x, (bool, int, float, str)):
            return x
        if isinstance(x, np.generic):
            return x.item()
        if isinstance(x, (bytes, bytearray, memoryview)):
            blobs.append(bytes(x))
            return {"__bytes__": len(blobs) - 1}
        if hasattr(x, "dtype") and hasattr(x, "shape"):
            blobs.append(codecs.encode(np.asarray(x), codec, pool=pool)[0])
            return {"__tensor__": len(blobs) - 1}
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            return {"__dataclass__": type(x).__name__,
                    "fields": {f.name: strip(getattr(x, f.name))
                               for f in dataclasses.fields(x)}}
        if isinstance(x, dict):
            return {str(k): strip(v) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return [strip(v) for v in x]
        raise TypeError(
            f"cannot pack payload leaf of type {type(x).__name__} "
            "(supported: scalars, str, bytes, arrays, dict/list/tuple, "
            "dataclasses)")

    skeleton = json.dumps(strip(obj)).encode()
    parts = [struct.pack("<II", len(skeleton), len(blobs)), skeleton,
             struct.pack(f"<{len(blobs)}q", *(len(b) for b in blobs))]
    parts.extend(blobs)
    return b"".join(parts)


def unpack_payload(data: bytes, *, parallel: bool = True) -> Any:
    """Inverse of :func:`pack_payload` (array leaves decode bit-exactly)."""
    jlen, nblobs = struct.unpack_from("<II", data, 0)
    off = 8
    skeleton = json.loads(bytes(data[off:off + jlen]).decode())
    off += jlen
    sizes = struct.unpack_from(f"<{nblobs}q", data, off)
    off += 8 * nblobs
    blobs: list[bytes] = []
    view = memoryview(data)
    for size in sizes:
        blobs.append(bytes(view[off:off + size]))
        off += size
    pool = codecs.codec_pool() if parallel else None

    def build(x: Any) -> Any:
        if isinstance(x, dict):
            if "__tensor__" in x and len(x) == 1:
                return codecs.decode(blobs[x["__tensor__"]], pool=pool)
            if "__bytes__" in x and len(x) == 1:
                return blobs[x["__bytes__"]]
            if "__dataclass__" in x and "fields" in x:
                return {"__dataclass__": x["__dataclass__"],
                        "fields": build(x["fields"])}
            return {k: build(v) for k, v in x.items()}
        if isinstance(x, list):
            return [build(v) for v in x]
        return x

    return build(skeleton)


def pack_file(relpath: str, data: bytes) -> bytes:
    """(relative path, file bytes) -> CODEC_FILE payload (no base64 bloat)."""
    pb = relpath.encode()
    return struct.pack("<H", len(pb)) + pb + bytes(data)


def unpack_file(payload: bytes) -> tuple[str, bytes]:
    (plen,) = struct.unpack_from("<H", payload, 0)
    return payload[2:2 + plen].decode(), bytes(payload[2 + plen:])


# ---------------------------------------------------------------------------
# atomic file publish — the one tmp -> fsync -> rename implementation
# ---------------------------------------------------------------------------

def atomic_write_bytes(path: str, data: bytes, *,
                       fsync_dir: bool = True) -> None:
    """Crash-safe single-file publish: write a same-directory tmp, fsync,
    rename over ``path``, then fsync the directory — a reader can never
    observe a torn file. (Shared by ``FileSink``, the ``SnapshotStore``
    frame writer, and anything else that publishes one file at a time.)"""
    d = os.path.dirname(path) or "."
    tmp = os.path.join(d, f".tmp_{os.path.basename(path)}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync_dir:
        dfd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)


# ---------------------------------------------------------------------------
# the Sink protocol + local backends
# ---------------------------------------------------------------------------

class Sink:
    """Uniform terminal stage: ``open / write_frame / flush / close``.

    ``write(step, payload)`` is the convenience layer every pipeline uses:
    it packs the payload (``CODEC_TREE`` by default), assigns the
    per-stream seq, and hands the frame to the backend's ``write_frame``.
    Sinks are callable — ``sink(step, payload)`` == ``sink.write(...)`` —
    so a ``Sink`` drops in anywhere a legacy sink callable was accepted.
    """

    def __init__(self, *, stream: str = "default",
                 payload_codec: str = "zlib") -> None:
        self.stream = stream
        self.payload_codec = payload_codec
        self._seq: dict[str, int] = {}
        self._seq_lock = threading.Lock()
        self.frames_written = 0
        self.bytes_written = 0
        self.closed = False

    # -- backend interface ----------------------------------------------------

    def open(self) -> "Sink":
        return self

    def write_frame(self, frame: Frame) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.closed = True

    # -- convenience layer ----------------------------------------------------

    def _next_seq(self, stream: str) -> int:
        with self._seq_lock:
            seq = self._seq.get(stream, 0)
            self._seq[stream] = seq + 1
            return seq

    def _rollback_seq(self, stream: str, seq: int) -> None:
        # a failed write must not burn the seq, or the retry (same frame,
        # next attempt) would open a gap the reader rejects
        with self._seq_lock:
            if self._seq.get(stream, 0) == seq + 1:
                self._seq[stream] = seq

    def write(self, step: int, payload: Any, *,
              stream: Optional[str] = None, codec: Optional[str] = None,
              kind: int = KIND_DATA) -> dict:
        """Pack + send one payload; returns a small record (the runtime
        stores it in ``results``). ``codec`` overrides the payload framing:
        ``CODEC_RAW`` ships ``payload`` bytes verbatim, ``CODEC_FILE``
        expects the :func:`pack_file` layout, anything else packs the
        pytree through :func:`pack_payload`."""
        stream = stream if stream is not None else self.stream
        if codec == CODEC_RAW or codec == CODEC_FILE:
            body, codec_name = bytes(payload), codec
        elif codec == CODEC_JSON:
            body, codec_name = json.dumps(payload).encode(), CODEC_JSON
        else:
            body = pack_payload(payload, codec=self.payload_codec)
            codec_name = CODEC_TREE
        seq = self._next_seq(stream)
        frame = Frame(stream, step, seq, codec_name, body, kind=kind)
        try:
            self.write_frame(frame)
        except BaseException:
            self._rollback_seq(stream, seq)
            raise
        self.frames_written += 1
        self.bytes_written += len(body)
        return {"stream": stream, "step": step, "seq": seq,
                "bytes": len(body), "sink": type(self).__name__}

    def __call__(self, step: int, payload: Any) -> Any:
        # a Sink drops in anywhere a legacy sink callable was expected
        return self.write(step, payload)

    def poll_control(self) -> list[dict]:
        """Steering messages received from a consumer (stream transports
        only); local backends have no back-channel."""
        return []

    def __enter__(self) -> "Sink":
        return self.open()

    def __exit__(self, *exc) -> None:
        self.close()


class CallableSink(Sink):
    """Compatibility shim: a legacy ``sink(step, payload)`` callable worn
    as a :class:`Sink`. ``write`` forwards and returns the callable's
    result unchanged, so registered pipelines keep their exact semantics."""

    def __init__(self, fn: Callable[[int, Any], Any],
                 *, stream: str = "default") -> None:
        super().__init__(stream=stream)
        self.fn = fn

    def write(self, step: int, payload: Any, **_kw) -> Any:
        result = self.fn(step, payload)
        self.frames_written += 1
        return result

    def write_frame(self, frame: Frame) -> None:  # pragma: no cover
        raise TypeError("CallableSink carries a legacy callable; use write()")


def as_sink(obj: Any) -> Sink:
    """Normalize a terminal stage: Sink objects pass through, callables get
    the :class:`CallableSink` shim."""
    if isinstance(obj, Sink):
        return obj
    if callable(obj):
        return CallableSink(obj)
    raise TypeError(
        f"sink must be a transport.Sink or a callable, got "
        f"{type(obj).__name__}")


class MemorySink(Sink):
    """Frames in a list — in-process probes and tests."""

    def __init__(self, *, stream: str = "default",
                 payload_codec: str = "zlib") -> None:
        super().__init__(stream=stream, payload_codec=payload_codec)
        self.frames: list[Frame] = []
        self._lock = threading.Lock()

    def write_frame(self, frame: Frame) -> None:
        if self.closed:
            raise TransportError("memory sink is closed")
        with self._lock:
            self.frames.append(frame)

    def payloads(self) -> list[tuple[str, int, Any]]:
        """Decoded (stream, step, payload) triples of the data frames."""
        out = []
        for f in self.frames:
            if f.kind != KIND_DATA:
                continue
            out.append((f.stream, f.step, decode_frame_payload(f)))
        return out


class FileSink(Sink):
    """One atomically-published file per frame: ``<dir>/<stream>/
    frame_<seq>.tfr`` via :func:`atomic_write_bytes` — the file-based
    staging baseline every streaming benchmark compares against."""

    def __init__(self, directory: str, *, stream: str = "default",
                 payload_codec: str = "zlib", fsync: bool = True) -> None:
        super().__init__(stream=stream, payload_codec=payload_codec)
        self.directory = directory
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)

    def write_frame(self, frame: Frame) -> None:
        if self.closed:
            raise TransportError("file sink is closed")
        d = os.path.join(self.directory, frame.stream)
        os.makedirs(d, exist_ok=True)
        atomic_write_bytes(
            os.path.join(d, f"frame_{frame.seq:08d}.tfr"),
            pack_frame(frame), fsync_dir=self.fsync)


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

def decode_frame_payload(frame: Frame) -> Any:
    """Decode one frame's payload by its declared codec (shared registry
    path for arrays via :func:`unpack_payload`)."""
    if frame.codec == CODEC_TREE:
        return unpack_payload(frame.payload)
    if frame.codec == CODEC_JSON:
        return json.loads(frame.payload.decode())
    if frame.codec == CODEC_FILE:
        return unpack_file(frame.payload)
    return frame.payload               # CODEC_RAW and unknown: opaque bytes


class Source:
    """Uniform reader: iterate :class:`Frame` objects in publish order."""

    def frames(self) -> Iterator[Frame]:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self) -> "Source":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class FileSource(Source):
    """Read a ``FileSink`` directory back, seq order, crc-validated."""

    def __init__(self, directory: str, *,
                 stream: Optional[str] = None) -> None:
        self.directory = directory
        self.stream = stream

    def _stream_dirs(self) -> list[str]:
        if self.stream is not None:
            return [self.stream]
        if not os.path.isdir(self.directory):
            return []
        return sorted(n for n in os.listdir(self.directory)
                      if os.path.isdir(os.path.join(self.directory, n)))

    def frames(self) -> Iterator[Frame]:
        for stream in self._stream_dirs():
            d = os.path.join(self.directory, stream)
            if not os.path.isdir(d):
                continue
            expect = None
            for name in sorted(os.listdir(d)):
                if not (name.startswith("frame_") and name.endswith(".tfr")):
                    continue
                with open(os.path.join(d, name), "rb") as f:
                    wire = f.read()
                if len(wire) < 4:
                    raise FrameCorruptError(
                        f"truncated frame file {name}", stream=stream)
                (blen,) = struct.unpack_from("<I", wire, 0)
                if len(wire) - 4 != blen:
                    raise FrameCorruptError(
                        f"frame file {name} length mismatch "
                        f"({len(wire) - 4} != {blen})", stream=stream)
                frame = parse_body(wire[4:])
                if expect is not None and frame.seq != expect:
                    raise StreamGapError(frame.stream, frame.step, expect,
                                         frame.seq)
                expect = frame.seq + 1
                yield frame


# ---------------------------------------------------------------------------
# the streaming backend: TCP, length-prefixed, crc-checked, steerable
# ---------------------------------------------------------------------------

def _read_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes; b'' on clean EOF at a boundary; raises
    FrameCorruptError on EOF mid-read (a torn frame)."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return b""
            raise FrameCorruptError(
                f"connection dropped mid-frame ({len(buf)} of {n} bytes)")
        buf.extend(chunk)
    return bytes(buf)


def _recv_wire_frame(sock: socket.socket) -> Optional[Frame]:
    """One length-prefixed frame off a socket; None on clean EOF."""
    head = _read_exact(sock, 4)
    if not head:
        return None
    (blen,) = struct.unpack("<I", head)
    if blen < _HEADER_SIZE or blen > _MAX_FRAME:
        raise FrameCorruptError(f"implausible frame length {blen}")
    return parse_body(_read_exact(sock, blen))


class StreamSink(Sink):
    """Length-prefixed crc-checked frames over a TCP socket.

    Failure semantics are what lets the runtime's PR-7 machinery cover the
    network: a connect/send failure (or timeout — a wedged consumer) closes
    the socket and raises :class:`~repro.core.runtime.TransientError`, so
    the task retries with backoff (reconnecting on the next attempt) and
    degrades to counted drops if the consumer stays gone — the train loop
    never crashes and, with the ``drop``/``adapt`` backpressure policies,
    never stalls. Frame seqs are assigned per stream and rolled back on a
    failed send, so a retry reuses the seq and the reader sees a contiguous
    stream; frames lost to degradation surface on the consumer as a typed
    :class:`StreamGapError`.

    The socket is bidirectional: :meth:`poll_control` drains steering
    frames the consumer pushed back (non-blocking), which
    ``Session.poll_steering`` applies to live tasks.
    """

    def __init__(self, host: str, port: int, *, stream: str = "default",
                 payload_codec: str = "zlib", connect_timeout_s: float = 5.0,
                 send_timeout_s: float = 10.0) -> None:
        super().__init__(stream=stream, payload_codec=payload_codec)
        self.host = host
        self.port = port
        self.connect_timeout_s = connect_timeout_s
        self.send_timeout_s = send_timeout_s
        self.reconnects = 0
        self._sock: Optional[socket.socket] = None
        self._io_lock = threading.Lock()

    @classmethod
    def over_socket(cls, sock: socket.socket, *, stream: str = "default",
                    payload_codec: str = "zlib") -> "StreamSink":
        """Wrap an already-connected socket (tests: socketpair)."""
        sink = cls("", -1, stream=stream, payload_codec=payload_codec)
        sink._sock = sock
        return sink

    # -- connection management ------------------------------------------------

    def _connect_locked(self) -> socket.socket:
        if self._sock is not None:
            return self._sock
        if self.port < 0:
            raise _transient("stream sink socket was dropped "
                             "(socket-wrapped sink cannot reconnect)")
        try:
            sock = socket.create_connection((self.host, self.port),
                                            timeout=self.connect_timeout_s)
        except OSError as e:
            raise _transient(
                f"stream sink cannot reach {self.host}:{self.port}: "
                f"{e}") from e
        sock.settimeout(self.send_timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        self.reconnects += 1
        return sock

    def _drop_locked(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def drop_connection(self) -> None:
        """Sever the connection (fault drills: the next write must
        reconnect or raise TransientError into the retry path)."""
        with self._io_lock:
            self._drop_locked()

    def open(self) -> "StreamSink":
        with self._io_lock:
            self._connect_locked()
        return self

    # -- frame IO -------------------------------------------------------------

    def write_frame(self, frame: Frame) -> None:
        if self.closed:
            raise TransportError("stream sink is closed")
        wire = pack_frame(frame)
        with self._io_lock:
            sock = self._connect_locked()
            try:
                sock.sendall(wire)
            except OSError as e:
                # a torn send poisons the connection; drop it so the retry
                # reconnects and the reader's parser starts clean
                self._drop_locked()
                raise _transient(
                    f"stream sink send to {self.host}:{self.port} failed "
                    f"(stream {frame.stream!r}, step {frame.step}): "
                    f"{e}") from e

    def poll_control(self) -> list[dict]:
        """Drain steering frames the consumer sent back; non-blocking —
        an idle or absent back-channel costs one select(0)."""
        out: list[dict] = []
        with self._io_lock:
            sock = self._sock
            if sock is None:
                return out
            while True:
                try:
                    r, _, _ = select.select([sock], [], [], 0)
                except (OSError, ValueError):
                    break
                if not r:
                    break
                try:
                    frame = _recv_wire_frame(sock)
                except (TransportError, OSError):
                    self._drop_locked()
                    break
                if frame is None:         # consumer went away
                    self._drop_locked()
                    break
                if frame.kind == KIND_CONTROL:
                    try:
                        out.append(json.loads(frame.payload.decode()))
                    except ValueError:
                        continue
        return out

    def flush(self) -> None:
        pass                              # sendall already drained userspace

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        with self._io_lock:
            if self._sock is not None:
                try:
                    self._sock.sendall(pack_frame(
                        Frame(self.stream, -1, 0, CODEC_JSON, b"{}",
                              kind=KIND_BYE)))
                except OSError:
                    pass
                self._drop_locked()


class StreamSource(Source):
    """The consumer side: accept producer connections, yield frames.

    Listens on ``host:port`` (the producer's ``StreamSink`` connects in);
    multiple producers — one per transport-declared task — multiplex via
    ``select``, each connection with its own parser state, so a torn frame
    on one connection cannot desynchronize another. Per-stream seq
    continuity is enforced across connections: a reconnecting producer
    that lost frames surfaces as :class:`StreamGapError` naming the
    stream/step (pass ``check_gaps=False`` to tail best-effort streams).

    :meth:`send_control` pushes a steering message back up every live
    connection — the producer's session polls and applies it mid-run.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 check_gaps: bool = True, listen: bool = True) -> None:
        self.check_gaps = check_gaps
        self._listener: Optional[socket.socket] = None
        self._conns: list[socket.socket] = []
        self._expect: dict[str, int] = {}
        self._lock = threading.Lock()
        self.frames_read = 0
        self.connections_accepted = 0
        self.port = port
        if listen:
            lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            lst.bind((host, port))
            lst.listen(16)
            self._listener = lst
            self.port = lst.getsockname()[1]

    @classmethod
    def over_socket(cls, sock: socket.socket, *,
                    check_gaps: bool = True) -> "StreamSource":
        """Wrap an already-connected socket (tests: socketpair)."""
        src = cls(listen=False, check_gaps=check_gaps)
        src._conns.append(sock)
        src.connections_accepted = 1
        return src

    @property
    def address(self) -> str:
        return f"tcp://127.0.0.1:{self.port}"

    def _check_seq(self, frame: Frame) -> None:
        expect = self._expect.get(frame.stream)
        if expect is not None and frame.seq != expect:
            self._expect[frame.stream] = frame.seq + 1
            if self.check_gaps:
                raise StreamGapError(frame.stream, frame.step, expect,
                                     frame.seq)
            return
        self._expect[frame.stream] = frame.seq + 1

    def recv_frame(self, timeout: Optional[float] = None
                   ) -> Optional[Frame]:
        """Next data frame from any connection; None when ``timeout``
        expires with no data frame. New connections are accepted and
        BYE/EOF drained *within* the timeout budget — an accept never eats
        the caller's whole wait."""
        import time as _time
        deadline = (None if timeout is None
                    else _time.monotonic() + timeout)
        with self._lock:
            while True:
                socks = ([self._listener] if self._listener else []) + \
                    list(self._conns)
                if not socks:
                    return None
                if deadline is None:
                    remaining = None
                else:
                    remaining = deadline - _time.monotonic()
                    if remaining < 0:
                        return None
                try:
                    r, _, _ = select.select(socks, [], [], remaining)
                except OSError:
                    return None
                if not r:
                    return None
                for sock in r:
                    if sock is self._listener:
                        conn, _ = sock.accept()
                        conn.setsockopt(socket.IPPROTO_TCP,
                                        socket.TCP_NODELAY, 1)
                        self._conns.append(conn)
                        self.connections_accepted += 1
                        continue
                    try:
                        frame = _recv_wire_frame(sock)
                    except TransportError:
                        self._drop(sock)
                        raise
                    except OSError as e:
                        self._drop(sock)
                        raise FrameCorruptError(
                            f"connection read failed: {e}") from e
                    if frame is None or frame.kind == KIND_BYE:
                        self._drop(sock)
                        continue
                    if frame.kind != KIND_DATA:
                        continue
                    self._check_seq(frame)
                    self.frames_read += 1
                    return frame

    def frames(self, *, idle_timeout_s: float = 5.0,
               max_frames: Optional[int] = None,
               start_grace_s: Optional[float] = None) -> Iterator[Frame]:
        """Yield frames until ``idle_timeout_s`` passes with no traffic and
        no live connections (a drained stream), or ``max_frames`` arrive.
        ``start_grace_s`` extends the wait for the *first* connection
        (default: ``idle_timeout_s``) — a producer with a long warm-up
        (jit compile) connects late, but once it has come and gone the
        drain exit stays prompt."""
        n = 0
        import time as _time
        started = _time.monotonic()
        idle_since = started
        grace = idle_timeout_s if start_grace_s is None else start_grace_s
        while max_frames is None or n < max_frames:
            frame = self.recv_frame(timeout=0.2)
            if frame is None:
                now = _time.monotonic()
                if (not self._conns and self.connections_accepted == 0
                        and now - started <= grace):
                    continue
                if (not self._conns
                        and now - idle_since > idle_timeout_s):
                    return
                if self._conns:
                    idle_since = now
                continue
            idle_since = _time.monotonic()
            n += 1
            yield frame

    def send_control(self, message: dict) -> int:
        """Push one steering message up every live connection; returns the
        number of producers it reached."""
        wire = pack_frame(Frame("control", -1, 0, CODEC_JSON,
                                json.dumps(message).encode(),
                                kind=KIND_CONTROL))
        sent = 0
        with self._lock:
            for sock in list(self._conns):
                try:
                    sock.sendall(wire)
                    sent += 1
                except OSError:
                    self._drop(sock)
        return sent

    def _drop(self, sock: socket.socket) -> None:
        if sock in self._conns:
            self._conns.remove(sock)
        try:
            sock.close()
        except OSError:
            pass

    @property
    def connections(self) -> int:
        return len(self._conns)

    def close(self) -> None:
        with self._lock:
            for sock in self._conns:
                try:
                    sock.close()
                except OSError:
                    pass
            self._conns.clear()
            if self._listener is not None:
                try:
                    self._listener.close()
                except OSError:
                    pass
                self._listener = None


# ---------------------------------------------------------------------------
# URL scheme: how plans declare transports
# ---------------------------------------------------------------------------

def parse_url(url: str) -> tuple[str, str]:
    """'scheme://rest' -> (scheme, rest); raises ValueError on junk."""
    if "://" not in url:
        raise ValueError(
            f"transport URL {url!r} needs a scheme "
            "(file://dir | memory:// | tcp://host:port)")
    scheme, rest = url.split("://", 1)
    return scheme, rest


def connect(url: str, *, stream: str = "default",
            payload_codec: str = "zlib") -> Sink:
    """Build the Sink a transport URL names.

    ``file:///path/to/dir`` -> :class:`FileSink`, ``memory://`` ->
    :class:`MemorySink`, ``tcp://host:port`` -> :class:`StreamSink`.
    """
    scheme, rest = parse_url(url)
    if scheme == "file":
        if not rest:
            raise ValueError(f"file transport needs a directory: {url!r}")
        return FileSink(rest, stream=stream, payload_codec=payload_codec)
    if scheme == "memory":
        return MemorySink(stream=stream, payload_codec=payload_codec)
    if scheme == "tcp":
        host, _, port = rest.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(
                f"tcp transport needs host:port, got {url!r}")
        return StreamSink(host, int(port), stream=stream,
                          payload_codec=payload_codec)
    raise ValueError(f"unknown transport scheme {scheme!r} in {url!r} "
                     "(known: file, memory, tcp)")


def send_directory(sink: Sink, step: int, directory: str, *,
                   prefix: str = "", stream: Optional[str] = None) -> int:
    """Replicate a committed directory through a sink, one ``CODEC_FILE``
    frame per file, ``manifest.json`` last (so a consumer materializing
    files in arrival order reproduces the publish-manifest-last crash
    protocol). Returns the number of frames sent."""
    names = []
    for root, _, files in os.walk(directory):
        for name in files:
            full = os.path.join(root, name)
            names.append(os.path.relpath(full, directory))
    # manifest last: its arrival certifies the rest of the step's files
    names.sort(key=lambda n: (os.path.basename(n) == "manifest.json", n))
    for rel in names:
        with open(os.path.join(directory, rel), "rb") as f:
            data = f.read()
        sink.write(step, pack_file(os.path.join(prefix, rel), data),
                   stream=stream, codec=CODEC_FILE)
    return len(names)


def materialize_file(frame: Frame, root: str) -> str:
    """Write one ``CODEC_FILE`` frame under ``root`` (path-sanitized,
    atomic publish); returns the absolute path written."""
    rel, data = unpack_file(frame.payload)
    rel = os.path.normpath(rel)
    if rel.startswith("..") or os.path.isabs(rel):
        raise TransportError(
            f"refusing to materialize path {rel!r} outside {root!r}")
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path) or root, exist_ok=True)
    atomic_write_bytes(path, data)
    return path
