"""Spectral lossy codec API: error-bounded pytree compression.

Wraps the kernels (Pallas on TPU, interpret/jnp on CPU) with:
  * pytree walking (compress a whole checkpoint state in one call)
  * the lossy -> lossless two-stage pipeline of the paper's hybrid mode
    (device kernel produces dense int8 q + per-block scales; the host lossless
    codec then removes the zero runs — exactly NEKO's lossy-on-GPU +
    Bzip2-on-host split)
  * error-bound accounting: relative-L2 <= eps (threshold) + sqrt(B)/254
    (int8 quantization); tests enforce the combined bound.

A policy decides which leaves may be lossy: by default only optimizer
*moments* (noise-dominated statistics — the ML analog of the paper's
"keep the energetic motions" physics argument) are lossy; weights stay
lossless. Override per-call.
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs
from repro.kernels import ops, ref

PyTree = Any

LOSSY_MAGIC = b"RPLY"


@dataclass(frozen=True)
class LossyStats:
    raw_bytes: int
    stored_bytes: int
    kept_fraction: float
    rel_l2_error: Optional[float] = None   # only when measure=True

    @property
    def ratio(self) -> float:
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.stored_bytes) / self.raw_bytes


def error_bound(eps: float) -> float:
    return ref.error_bound(eps)


# ---------------------------------------------------------------------------
# single tensor: device lossy stage -> host lossless stage -> framed bytes
# ---------------------------------------------------------------------------

def _lossy_header(dtype, n_elements: int, shape: tuple,
                  qlen: int, slen: int) -> bytes:
    dt = jnp.dtype(dtype).name.encode()   # name token: handles bf16
    return LOSSY_MAGIC + struct.pack("<B", len(dt)) + dt + struct.pack(
        "<qB", n_elements, len(shape)) + struct.pack(
        f"<{len(shape)}q", *shape) + struct.pack("<qq", qlen, slen)


def _raw_bytes(dtype, shape: tuple) -> int:
    return (int(np.prod(shape)) if shape else 1) \
        * np.dtype(jnp.dtype(dtype)).itemsize


def frame_compressed(c: ref.Compressed, lossless: str = "zlib",
                     pool=None) -> tuple[bytes, LossyStats]:
    """Host lossless stage: pack a device-produced Compressed into bytes.

    ``pool`` fans the lossless chunks of a large coefficient buffer out
    across the shared codec executor (see ``codecs.codec_pool``).
    """
    q = np.asarray(c.q)
    scale = np.asarray(c.scale)
    q_blob, _ = codecs.encode(q, lossless, pool=pool)
    s_blob, _ = codecs.encode(scale, lossless, pool=pool)
    shape = tuple(int(d) for d in c.shape)
    header = _lossy_header(c.dtype, c.n_elements, shape,
                           len(q_blob), len(s_blob))
    blob = header + q_blob + s_blob
    return blob, LossyStats(_raw_bytes(c.dtype, shape), len(blob),
                            float(np.mean(q != 0)))


def _frame_chunked_q(chunks, lossless: str, pool=None) -> tuple[bytes, float]:
    """Streamed host lossless stage for device-chunked int8 coefficients.

    Every chunk's D2H copy is started up front, then each chunk is
    losslessly compressed as soon as it lands on the host — the framing
    never synchronises on one monolithic coefficient buffer. The frame is
    byte-identical to ``codecs.encode(concat(chunks))`` because the device
    chunks are cut at the codec's own chunk boundary.

    Returns ``(frame bytes, kept fraction)``.
    """
    _, comp, _ = codecs.compressor(lossless)
    for ch in chunks:
        if hasattr(ch, "copy_to_host_async"):
            ch.copy_to_host_async()
    use_pool = pool is not None and len(chunks) > 1
    nonzero = total = 0
    pending = []
    for ch in chunks:
        a = np.asarray(ch)            # waits for *this* chunk only
        nonzero += int(np.count_nonzero(a))
        total += a.size
        view = codecs._byte_view(a)
        pending.append(pool.submit(comp, view) if use_pool else comp(view))
    payloads = [p.result() for p in pending] if use_pool else pending
    n_blocks = sum(int(ch.shape[0]) for ch in chunks)
    blob = codecs.assemble_frame(lossless, np.int8, (n_blocks, ref.BLOCK),
                                 n_blocks * ref.BLOCK, codecs.DEFAULT_CHUNK,
                                 payloads)
    return blob, nonzero / max(total, 1)


def compress_tensor(x: jax.Array | np.ndarray, eps: float = 1e-2,
                    lossless: str = "zlib",
                    measure: bool = False, pool=None,
                    stream: bool | None = None) -> tuple[bytes, LossyStats]:
    """Device lossy stage + host lossless stage for one tensor.

    ``stream`` (default: auto — multi-chunk payloads without ``measure``)
    uses the fused quantize+chunking kernel path: the int8 coefficients
    leave the device pre-split at codec chunk boundaries and are framed
    chunk-by-chunk, overlapping D2H with lossless packing. Output bytes are
    identical either way.
    """
    x = jnp.asarray(x)
    if stream is None:
        stream = not measure and x.size > codecs.DEFAULT_CHUNK
    if stream and not measure:
        chunks, scale, n = ops.spectral_compress_chunked(
            x, eps, chunk_blocks=codecs.DEFAULT_CHUNK // ref.BLOCK)
        q_blob, kept = _frame_chunked_q(chunks, lossless, pool)
        s_blob, _ = codecs.encode(np.asarray(scale), lossless, pool=pool)
        shape = tuple(int(d) for d in x.shape)
        header = _lossy_header(x.dtype, n, shape, len(q_blob), len(s_blob))
        blob = header + q_blob + s_blob
        return blob, LossyStats(_raw_bytes(x.dtype, shape), len(blob),
                                float(kept))
    c = ops.spectral_compress(x, eps)                # device lossy stage
    blob, st = frame_compressed(c, lossless, pool)   # host lossless stage
    if measure:
        st = LossyStats(st.raw_bytes, st.stored_bytes, st.kept_fraction,
                        ref.rel_l2_error(x, ops.spectral_decompress(c)))
    return blob, st


def decompress_tensor(blob: bytes, pool=None) -> jax.Array:
    if blob[:4] != LOSSY_MAGIC:
        raise ValueError("bad lossy frame magic")
    off = 4
    (dtlen,) = struct.unpack_from("<B", blob, off)
    off += 1
    name = blob[off:off + dtlen].decode()
    try:
        dtype = np.dtype(name)
    except TypeError:
        import ml_dtypes
        dtype = np.dtype(getattr(ml_dtypes, name))
    off += dtlen
    n_elements, ndim = struct.unpack_from("<qB", blob, off)
    off += 9
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    qlen, slen = struct.unpack_from("<qq", blob, off)
    off += 16
    q = jnp.asarray(codecs.decode(blob[off:off + qlen], pool=pool))
    scale = jnp.asarray(codecs.decode(blob[off + qlen:off + qlen + slen],
                                      pool=pool))
    c = ref.Compressed(q, scale, n_elements, tuple(shape), jnp.dtype(dtype))
    return ops.spectral_decompress(c)


# ---------------------------------------------------------------------------
# pytree policy + walking
# ---------------------------------------------------------------------------

def moments_only_policy(path: tuple, leaf) -> bool:
    """Default: lossy for optimizer moment statistics, lossless for weights."""
    keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    return any(tok in keys for tok in ("mu", "nu", "m1", "m2", "moment"))


def compress_tree(tree: PyTree, eps: float = 1e-2, lossless: str = "zlib",
                  policy: Callable[[tuple, Any], bool] = moments_only_policy,
                  ) -> tuple[dict[str, bytes], dict[str, LossyStats | codecs.CompressionStats]]:
    """Returns ({path: framed blob}, {path: stats}). Lossless leaves use codecs."""
    blobs: dict[str, bytes] = {}
    stats: dict[str, Any] = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if policy(path, leaf):
            blob, st = compress_tensor(leaf, eps, lossless)
        else:
            blob, st = codecs.encode(arr, lossless)
        blobs[key] = blob
        stats[key] = st
    return blobs, stats


def decompress_blob(blob: bytes, pool=None) -> np.ndarray | jax.Array:
    if blob[:4] == LOSSY_MAGIC:
        return decompress_tensor(blob, pool)
    return codecs.decode(blob, pool=pool)


class SpectralLossyCodec:
    """Registry adapter: the device lossy stage + host lossless stage as one
    ``repro.core.compression`` Codec. Roundtrip error is relative-L2 bounded
    by ``error_bound()`` (threshold + int8 quantization terms)."""

    lossy = True

    def __init__(self, name: str = "spectral", eps: float = 1e-2,
                 lossless: str = "zlib") -> None:
        self.name = name
        self.eps = eps
        self.lossless = lossless

    def encode(self, arr) -> bytes:
        return compress_tensor(arr, self.eps, self.lossless)[0]

    def decode(self, blob: bytes) -> np.ndarray:
        return np.asarray(decompress_tensor(blob))

    def error_bound(self) -> float:
        return error_bound(self.eps)


from repro.core import compression as _compression  # noqa: E402

_compression.register(SpectralLossyCodec())
_compression.register(SpectralLossyCodec("spectral-coarse", eps=1e-1))


def restore_tree(template: PyTree, blobs: dict[str, bytes]) -> PyTree:
    """Rebuild a pytree (same structure as template) from framed blobs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = decompress_blob(blobs[key])
        arr = jnp.asarray(arr)
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype).reshape(leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
