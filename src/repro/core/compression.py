"""The unified codec registry: one ``Codec`` protocol for the whole tree.

Compression exists in four places in this codebase — the stdlib lossless
framing (``core/codecs``), the error-bounded spectral lossy codec
(``core/lossy`` over the Pallas kernels in ``kernels/spectral_lossy``), and
the int8 error-feedback wire quantizer (``optim/grad_compress``). Before
this registry each consumer imported its codec module directly; now the
checkpoint pipeline, benchmarks, and serving snapshots look codecs up by
name:

    from repro.core import compression
    codec = compression.get("zlib")          # lossless framing
    codec = compression.get("spectral")      # eps-bounded lossy
    blob = codec.encode(arr); out = codec.decode(blob)

A ``Codec`` is any object with ``name``, ``lossy``, ``encode(ndarray) ->
bytes`` and ``decode(bytes) -> ndarray``; lossy codecs additionally expose
``error_bound() -> float`` (relative-L2). Provider modules register at
import time; ``get``/``available`` lazily import the built-in providers so
callers never have to know where a codec lives.
"""
from __future__ import annotations

import importlib
from typing import Optional, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Codec(Protocol):
    name: str
    lossy: bool

    def encode(self, arr: np.ndarray) -> bytes: ...

    def decode(self, blob: bytes) -> np.ndarray: ...


_REGISTRY: dict[str, Codec] = {}

# modules that register codecs at import time (kept lazy: importing the
# registry must not drag in jax/kernels until a codec is actually needed)
_PROVIDERS = ("repro.core.codecs", "repro.core.delta", "repro.core.lossy",
              "repro.optim.grad_compress")
_providers_loaded = False


def register(codec: Codec, *, replace: bool = False) -> Codec:
    """Add a codec to the registry (provider modules call this on import)."""
    if not replace and codec.name in _REGISTRY:
        raise ValueError(f"codec {codec.name!r} already registered")
    _REGISTRY[codec.name] = codec
    return codec


def _ensure_providers() -> None:
    global _providers_loaded
    if _providers_loaded:
        return
    _providers_loaded = True
    for mod in _PROVIDERS:
        importlib.import_module(mod)


def get(name: str) -> Codec:
    _ensure_providers()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {available()}") from None


def available(*, lossy: Optional[bool] = None) -> list[str]:
    """Registered codec names, optionally filtered by losslessness."""
    _ensure_providers()
    return sorted(n for n, c in _REGISTRY.items()
                  if lossy is None or c.lossy == lossy)
