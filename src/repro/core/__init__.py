"""The paper's contribution: in-situ task placement for accelerator loops."""
from repro.core.insitu import (InSituEngine, InSituMode, InSituTask,
                               run_workflow)
from repro.core.runtime import (FanoutStage, PipelineRuntime, PipelineTask,
                                Placement, Stage, TaskResult, run_pipeline,
                                split_payload)
from repro.core.staging import PendingHandoff, StagedItem, StagingBuffer
from repro.core.telemetry import Telemetry

__all__ = ["InSituEngine", "InSituMode", "InSituTask", "run_workflow",
           "FanoutStage", "PipelineRuntime", "PipelineTask", "Placement",
           "Stage", "TaskResult", "run_pipeline", "split_payload",
           "PendingHandoff", "StagedItem", "StagingBuffer", "Telemetry"]
