"""The paper's contribution: in-situ task placement for accelerator loops.

New code should use the declarative API (``repro.insitu``, implemented in
``repro.core.session``); ``InSituEngine``/``run_workflow``/``run_pipeline``
are deprecation shims over it.
"""
from repro.core.insitu import (InSituEngine, InSituMode, InSituTask,
                               run_workflow)
from repro.core.runtime import (FanoutStage, PipelineRuntime, PipelineTask,
                                Placement, Stage, TaskResult, TransientError,
                                run_pipeline, split_payload)
from repro.core.session import (Adaptive, Every, InSituPlan, InSituTaskError,
                                Interval, PlanError, Session, StreamSpec,
                                TaskSpec, When, preset_names, register_preset)
from repro.core.staging import PendingHandoff, StagedItem, StagingBuffer
from repro.core.telemetry import Telemetry
from repro.core.transport import (CallableSink, FileSink, FileSource, Frame,
                                  FrameCorruptError, MemorySink, Sink, Source,
                                  StreamGapError, StreamSink, StreamSource,
                                  TransportError, as_sink, connect)

__all__ = ["InSituEngine", "InSituMode", "InSituTask", "run_workflow",
           "FanoutStage", "PipelineRuntime", "PipelineTask", "Placement",
           "Stage", "TaskResult", "TransientError", "run_pipeline",
           "split_payload",
           "Adaptive", "Every", "InSituPlan", "InSituTaskError", "Interval",
           "PlanError", "Session", "StreamSpec", "TaskSpec", "When",
           "preset_names", "register_preset",
           "PendingHandoff", "StagedItem", "StagingBuffer", "Telemetry",
           "CallableSink", "FileSink", "FileSource", "Frame",
           "FrameCorruptError", "MemorySink", "Sink", "Source",
           "StreamGapError", "StreamSink", "StreamSource", "TransportError",
           "as_sink", "connect"]
