"""The paper's contribution: in-situ task placement for accelerator loops."""
from repro.core.insitu import (InSituEngine, InSituMode, InSituTask,
                               run_workflow)
from repro.core.staging import StagedItem, StagingBuffer
from repro.core.telemetry import Telemetry

__all__ = ["InSituEngine", "InSituMode", "InSituTask", "run_workflow",
           "StagedItem", "StagingBuffer", "Telemetry"]
