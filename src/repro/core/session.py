"""The declarative in-situ API: ``InSituPlan`` + ``Session``.

The paper's central claim is that in-situ tasks should be *declared
against* a running application, not hand-wired into it (SENSEI's generic
interface; openPMD/ADIOS2's declarative pipeline descriptions). This module
is that surface for the whole tree — every workflow (training analytics,
checkpointing, serving snapshots, benchmark probes) is one *plan*:

  streams   named payload sources the application emits
            (``grads``, ``train_state``, ``kv_pages``, ...)
  triggers  when a task fires: ``Every(n)`` steps, ``When(predicate)``,
            ``Interval(seconds)`` of wall clock, or ``Adaptive(n)``
            (backpressure- and, with ``budget_s=``, wall-clock-widened
            every-N) — replacing scattered ``every=`` ints
  tasks     what runs: an explicit ``device_stage -> handoff ->
            host_stages -> sink`` chain, or a registered *preset*
            (``checkpoint``, ``grad_health``, ``spectra``,
            ``serve_snapshot``, ``fault``)

A plan is validated at construction (errors name the offending
stream/task) and is loadable from a plain dict — and therefore from
TOML/JSON — so launchers, examples, and benchmarks all build workflows the
same way::

    plan = InSituPlan.from_dict({
        "streams": ["grads", "train_state"],
        "tasks": {
            "grad_health": {"stream": "grads", "preset": "grad_health",
                            "every": 10},
            "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                           "every": 50,
                           "options": {"directory": "/tmp/ckpt"}},
        },
    })
    with Session(plan) as session:
        for step in range(n_steps):
            state = device_step(state)
            session.emit("grads", step, lambda: summarize(state))
            session.emit("train_state", step, lambda: state)
    print(session.report())

``Session`` owns ONE shared :class:`~repro.core.runtime.PipelineRuntime`
(the paper's single p_o/p_i split), exposes :meth:`Session.emit` as the
*only* producer call, folds :class:`~repro.checkpoint.CheckpointManager`
in as a declared task on its stream (save/restore/retention unchanged),
and merges telemetry, task results, errors, and checkpoint statistics into
one :meth:`Session.report`.

The legacy entry points (``InSituEngine``/``run_workflow`` in
``core/insitu.py``, ``run_pipeline`` in ``core/runtime.py``) are thin
deprecation shims over a ``Session``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence, Union

from repro.core import transport
from repro.core.runtime import (BACKPRESSURE_POLICIES, PipelineRuntime,
                                PipelineTask, Placement, Stage,
                                default_handoff)
from repro.core.telemetry import Telemetry

PyTree = Any


class PlanError(ValueError):
    """A plan failed validation; the message names the stream/task at fault."""


class InSituTaskError(RuntimeError):
    """A task raised during the run; re-raised by ``finish(raise_on_error=True)``.

    Carries the declarative context (``stream``, ``task``, ``step``) so a
    failure in an async worker is attributable without digging through
    ``session.errors``; the original exception is chained as ``__cause__``.
    """

    def __init__(self, task: str, stream: str, step: int,
                 original: BaseException) -> None:
        super().__init__(
            f"in-situ task {task!r} (stream {stream!r}) failed at step "
            f"{step}: {type(original).__name__}: {original}")
        self.task = task
        self.stream = stream
        self.step = step


# ---------------------------------------------------------------------------
# Triggers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Every:
    """Fire on every ``n``-th step (``step % n == 0``) — the paper's
    "image every 50 / every 10" cadence. ``n`` must be >= 1."""
    n: int = 1

    def to_dict(self) -> dict:
        return {"every": self.n}


@dataclass(frozen=True)
class Adaptive:
    """Backpressure-adaptive every-N: starts at ``n``; under sustained
    staging-ring pressure the runtime doubles the *effective* period (up to
    ``max_every``) instead of stalling the producer — the paper's F3
    mitigation as a trigger.

    ``budget_s`` adds the wall-clock flavor: when the loop-blocking cost of
    a firing (copy dispatch + blocking hand-off + sync in-situ work, as
    measured by the runtime's telemetry spans) stays over the budget for
    ``after`` consecutive firings, the effective period widens too — the
    straggler policy's "shed in-situ load before replacing the host" knob.
    """
    n: int = 1
    max_every: int = 64
    after: int = 2            # consecutive over-budget/full-ring firings
    budget_s: Optional[float] = None

    def to_dict(self) -> dict:
        d = {"kind": "adaptive", "n": self.n,
             "max_every": self.max_every, "after": self.after}
        if self.budget_s is not None:
            d["budget_s"] = self.budget_s
        return {"trigger": d}


@dataclass(frozen=True)
class When:
    """Fire when ``predicate(step)`` is true — e.g. loss spikes, phase
    boundaries. Session-gated; not dict-serializable (a predicate is code)."""
    predicate: Callable[[int], bool]

    def to_dict(self) -> dict:
        raise PlanError("When(predicate) triggers are code, not data — "
                        "they cannot round-trip through a plan dict")


@dataclass(frozen=True)
class Interval:
    """Fire at most once per ``seconds`` of wall clock (first emit always
    fires) — the "checkpoint every 10 minutes" cadence, step-rate
    independent. Reads the session's monotonic clock (``Session(...,
    clock=...)``), so trigger semantics are testable without sleeping;
    ``Every``/``Adaptive`` are step-counted and never consult it."""
    seconds: float

    def to_dict(self) -> dict:
        return {"trigger": {"kind": "interval", "seconds": self.seconds}}


Trigger = Union[Every, Adaptive, When, Interval]


def _trigger_from_dict(name: str, spec: Mapping[str, Any]) -> Trigger:
    kind = spec.get("kind")
    if kind == "every":
        return Every(int(spec.get("n", 1)))
    if kind == "adaptive":
        budget = spec.get("budget_s")
        return Adaptive(int(spec.get("n", 1)),
                        max_every=int(spec.get("max_every", 64)),
                        after=int(spec.get("after", 2)),
                        budget_s=None if budget is None else float(budget))
    if kind == "interval":
        return Interval(float(spec["seconds"]))
    raise PlanError(f"task {name!r}: unknown trigger kind {kind!r} "
                    "(expected 'every' | 'adaptive' | 'interval')")


# ---------------------------------------------------------------------------
# Streams and task bindings
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StreamSpec:
    """One named payload stream the application will ``emit`` into."""
    name: str
    description: str = ""


@dataclass
class TaskSpec:
    """One declared in-situ task bound to a stream.

    Exactly one of ``preset`` or ``sink`` must be given:

    ``preset``        name of a registered workflow preset (``checkpoint``,
                      ``grad_health``, ``spectra``, ``serve_snapshot``);
                      ``options`` parameterize it.
    ``sink``          explicit terminal consumer ``sink(step, payload)``;
                      ``host_stages`` / ``device_stage`` / ``handoff``
                      complete the chain exactly as on
                      :class:`~repro.core.runtime.PipelineTask`.

    ``trigger``       when the task fires (default ``Every(1)``).
    ``placement``     SYNC / ASYNC / HYBRID scheduling policy.
    ``backpressure``  'block' | 'drop' | 'adapt' ring-full policy
                      (an ``Adaptive`` trigger implies 'adapt').
    ``shards``        split each firing into N independent sub-items.
    ``pipelined``     two-phase hand-off (dispatch on the loop,
                      materialize on the pool); ``False`` restores the
                      blocking hand-off.
    ``snapshot``      donation-proof device-side copy at dispatch.
    ``retries``       transient-sink-failure retry count (None = runtime
                      default); exhausted retries degrade the task instead
                      of raising (see ``PipelineTask.retries``).
    ``retry_backoff_s``  base of the capped exponential retry backoff.
    """
    name: str
    stream: str
    trigger: Trigger = field(default_factory=Every)
    placement: Placement = Placement.ASYNC
    preset: Optional[str] = None
    options: dict = field(default_factory=dict)
    sink: Optional[Callable[[int, Any], Any]] = None
    host_stages: Sequence[Stage] = ()
    device_stage: Optional[Callable[[int, Any], Any]] = None
    handoff: Callable[[Any], Any] = default_handoff
    backpressure: Optional[str] = None
    shards: int = 1
    pipelined: bool = True
    snapshot: bool = True
    retries: Optional[int] = None
    retry_backoff_s: Optional[float] = None

    def resolved_backpressure(self) -> str:
        if self.backpressure is not None:
            return self.backpressure
        return "adapt" if isinstance(self.trigger, Adaptive) else "block"

    def to_dict(self) -> dict:
        """Declarative dict form; only preset tasks are data (callables
        are code and raise :class:`PlanError`)."""
        if self.preset is None:
            raise PlanError(
                f"task {self.name!r}: explicit sink/stage chains are code — "
                "only preset tasks round-trip through a plan dict")
        d: dict[str, Any] = {"stream": self.stream, "preset": self.preset,
                             "placement": self.placement.value}
        d.update(self.trigger.to_dict())
        if self.options:
            d["options"] = dict(self.options)
        if self.backpressure is not None:
            d["backpressure"] = self.backpressure
        if self.shards != 1:
            d["shards"] = self.shards
        if not self.pipelined:
            d["pipelined"] = False
        if not self.snapshot:
            d["snapshot"] = False
        if self.retries is not None:
            d["retries"] = self.retries
        if self.retry_backoff_s is not None:
            d["retry_backoff_s"] = self.retry_backoff_s
        return d


def _task_from_dict(name: str, spec: Mapping[str, Any]) -> TaskSpec:
    spec = dict(spec)
    if "every" in spec and "trigger" in spec:
        raise PlanError(
            f"task {name!r}: conflicting triggers — give either "
            "'every' or 'trigger', not both")
    if "trigger" in spec:
        trigger = _trigger_from_dict(name, spec.pop("trigger"))
    else:
        trigger = Every(int(spec.pop("every", 1)))
    placement = spec.pop("placement", "async")
    if not isinstance(placement, Placement):
        try:
            placement = Placement(placement)
        except ValueError:
            raise PlanError(
                f"task {name!r}: unknown placement {placement!r} "
                f"(expected one of {[p.value for p in Placement]})") from None
    known = {"stream", "preset", "options", "backpressure", "shards",
             "pipelined", "snapshot", "retries", "retry_backoff_s"}
    unknown = set(spec) - known
    if unknown:
        raise PlanError(f"task {name!r}: unknown field(s) {sorted(unknown)}")
    if "stream" not in spec:
        raise PlanError(f"task {name!r}: missing required field 'stream'")
    retries = spec.get("retries")
    backoff = spec.get("retry_backoff_s")
    return TaskSpec(name=name, stream=spec["stream"], trigger=trigger,
                    placement=placement, preset=spec.get("preset"),
                    options=dict(spec.get("options", {})),
                    backpressure=spec.get("backpressure"),
                    shards=int(spec.get("shards", 1)),
                    pipelined=bool(spec.get("pipelined", True)),
                    snapshot=bool(spec.get("snapshot", True)),
                    retries=None if retries is None else int(retries),
                    retry_backoff_s=None if backoff is None
                    else float(backoff))


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# A preset maps (TaskSpec) -> chain pieces for the shared runtime:
#   {"sink": ..., "host_stages": ..., "device_stage": ..., "handoff": ...}
# The 'checkpoint' preset is special-cased by Session (it folds a whole
# CheckpointManager — save/restore/retention — into the plan).
_PRESETS: dict[str, Callable[[TaskSpec], dict]] = {}


def register_preset(name: str):
    """Register a workflow preset usable as ``TaskSpec(preset=name)``.

    The decorated factory takes the :class:`TaskSpec` and returns the chain
    pieces (``sink`` required; ``host_stages``/``device_stage``/``handoff``
    optional; a ``report`` zero-arg callable is merged into the task's
    entry of :meth:`Session.report`; a ``store`` object is exposed through
    :meth:`Session.snapshot_store`). Presets keep plans declarative: a dict
    plan can name them without shipping code.
    """
    def deco(factory: Callable[[TaskSpec], dict]):
        _PRESETS[name] = factory
        return factory
    return deco


def preset_names() -> list[str]:
    """Registered preset names (plus the Session-built-in 'checkpoint')."""
    return sorted(set(_PRESETS) | {"checkpoint"})


class _PresetSink(transport.Sink):
    """The one terminal every preset shares (this used to be four
    near-identical ``def sink(step, payload)`` closures): run the preset's
    *transform* — its whole identity — and, when the plan declared a
    transport (``options={"to": "tcp://…"}``), forward the result through
    it. The transform's return value stays the task's result in
    ``runtime.results``, so semantics match the old closures exactly.

    A forward failure over a stream transport raises the runtime's
    ``TransientError`` out of ``write`` — the task retries (re-running the
    transform, so transforms must tolerate replay; all four presets do)
    and degrades if the consumer stays gone, which is precisely the PR-7
    contract extended to the network.
    """

    def __init__(self, spec: TaskSpec, transform: Callable[[int, Any], Any],
                 forward_to: Optional[transport.Sink] = None) -> None:
        super().__init__(stream=spec.stream)
        self.transform = transform
        self.forward_to = forward_to

    def write(self, step: int, payload: Any, **_kw) -> Any:
        result = self.transform(step, payload)
        if self.forward_to is not None:
            self.forward_to.write(
                step, result if result is not None else payload)
        self.frames_written += 1
        return result

    def write_frame(self, frame: transport.Frame) -> None:  # pragma: no cover
        raise TypeError("_PresetSink is driven through write()")

    def close(self) -> None:
        if self.forward_to is not None:
            self.forward_to.close()
        super().close()


def _terminal_pieces(spec: TaskSpec, transform: Callable[[int, Any], Any],
                     *, known_options: Sequence[str] = (),
                     forward: bool = True, **extra: Any) -> dict:
    """Build a preset's chain pieces around the shared terminal.

    Validates ``spec.options`` against ``known_options`` (every preset
    accepts ``to`` — the plan-declared transport URL), connects the
    transport once, and wires it either into the sink's forward path
    (``forward=True``) or just hands it back for the preset to own
    (``forward=False`` — serve_snapshot attaches it as the store mirror
    instead). The transport rides the pieces dict under ``"transport"`` so
    the session can poll its steering back-channel and close it.
    """
    known = set(known_options) | {"to"}
    unknown = set(spec.options) - known
    if unknown:
        # a silently-ignored option would change semantics without a
        # diagnostic (the removed sample_elems taught us that)
        raise PlanError(
            f"task {spec.name!r}: unknown {spec.preset} option(s) "
            f"{sorted(unknown)} (known: {sorted(known)})")
    url = spec.options.get("to")
    tsink = (transport.connect(str(url), stream=spec.stream)
             if url else None)
    pieces: dict[str, Any] = {
        "sink": _PresetSink(spec, transform,
                            forward_to=tsink if forward else None),
        "transport": tsink,
    }
    pieces.update(extra)
    return pieces


@register_preset("grad_health")
def _grad_health_preset(spec: TaskSpec) -> dict:
    """Gradient-health roll-up artifact (global norm, norm sheet, NaN flags).
    Options: ``to`` (transport URL streaming each artifact to a consumer)."""
    from repro.core import analysis

    def transform(step: int, payload: Any):
        return analysis.gradient_health(payload, step)

    return _terminal_pieces(spec, transform)


@register_preset("spectra")
def _spectra_preset(spec: TaskSpec) -> dict:
    """Per-tensor spectral/histogram/heatmap artifacts (the paper's
    "image generation" analog). Options: ``work`` (cost knob, default 1),
    ``to`` (transport URL streaming each artifact to a consumer)."""
    from repro.core import analysis
    work = int(spec.options.get("work", 1))

    def transform(step: int, payload: Any):
        if isinstance(payload, Mapping):
            return analysis.summarize_tree(payload, step, work=work)
        return analysis.tensor_summary(spec.stream, payload, step, work=work)

    return _terminal_pieces(spec, transform, known_options=("work",))


@register_preset("serve_snapshot")
def _serve_snapshot_preset(spec: TaskSpec) -> dict:
    """Delta-encoded serving-state snapshots through a versioned
    :class:`~repro.serving.snapshot.SnapshotStore`.

    Each firing publishes the payload as one frame of the stream's
    base+delta chain: every ``base_every``-th publish is a self-contained
    base, the rest are per-chunk XOR/COPY deltas against the previous
    snapshot, and a payload carrying an unchanged ``version`` hint (see
    ``ServingEngine.snapshot_payload``) short-circuits to a no-op frame.
    The sink result is the :class:`~repro.serving.snapshot.SnapshotRecord`
    for the frame; :meth:`Session.report` merges the store's delta-ratio /
    chain-depth statistics into the task's entry.

    Options: ``codec`` (inner lossless codec, default 'zlib'),
    ``base_every`` (chain cadence, default 8), ``directory`` (persist
    frames crash-safely on disk; default in-memory), ``keep_chains``
    (retention — default 2, bounding a long-running serving loop's
    frame accumulation; None keeps everything), ``to`` (transport URL —
    attached as the store's *mirror*, streaming every raw chain frame to
    a remote replica that rebuilds a bit-identical chain via
    ``SnapshotStore.ingest``)."""
    from repro.serving.snapshot import SnapshotStore

    keep = spec.options.get("keep_chains", 2)
    store = SnapshotStore(
        spec.options.get("directory"),
        base_every=int(spec.options.get("base_every", 8)),
        codec=str(spec.options.get("codec", "zlib")),
        keep_chains=None if keep is None else int(keep))
    stream = spec.stream

    def transform(step: int, payload: Any):
        version = None
        tree = payload
        hints = None
        if (isinstance(payload, Mapping) and "cache" in payload
                and "version" in payload):
            version = int(payload["version"])
            tree = payload["cache"]
            # paged engines ship per-leaf chunk sizes so delta chunks
            # align to KV pages (untouched pages -> zero-payload COPY)
            hints = payload.get("chunk_hints")
        return store.publish(stream, step, tree, version=version,
                             chunk_hints=hints)

    # forward=False: the chain frames themselves are the wire product —
    # the store mirrors every written frame's raw bytes, which is what
    # makes the replica's restore bit-identical (a re-encoded
    # SnapshotRecord forward would carry only the record, not the chain)
    pieces = _terminal_pieces(
        spec, transform, forward=False,
        known_options=("codec", "base_every", "directory", "keep_chains"),
        report=lambda: store.stats(stream), store=store)
    if pieces["transport"] is not None:
        store.set_mirror(pieces["transport"])
    return pieces


@register_preset("fault")
def _fault_preset(spec: TaskSpec) -> dict:
    """Failure-aware run: heartbeats + straggler EWMA + live mitigation.

    Each firing feeds a :class:`~repro.distributed.fault.FaultController`
    with the emitted health payload (``{"host": h, "step_s": s}``,
    ``{"hosts": {h: s}}``, or a bare ``{host: step_s}`` mapping). The
    controller runs on the session's injected monotonic clock (``attach``),
    declares hosts missing ``grace_s`` seconds of beats failed, and applies
    :meth:`StragglerMonitor.mitigation` live — shedding in-situ load first
    (``Session.shed_insitu`` widens every other task's cadence) before
    flagging a host for replacement at the next checkpoint boundary.
    :meth:`Session.report` carries the controller's state under ``fault``.

    Options: ``hosts`` (required — the participating host ids), ``grace_s``
    (heartbeat grace, default 30), ``alpha`` (EWMA smoothing, default 0.2),
    ``factor`` (straggler threshold x median, default 1.5), ``to``
    (transport URL streaming each ingest report — a live health feed for a
    remote dashboard).
    """
    from repro.distributed.fault import FaultController

    hosts = spec.options.get("hosts")
    if not hosts:
        raise PlanError(
            f"task {spec.name!r}: fault preset requires "
            "options={'hosts': [...]} (the participating host ids)")
    ctrl = FaultController(
        [int(h) for h in hosts],
        grace_s=float(spec.options.get("grace_s", 30.0)),
        alpha=float(spec.options.get("alpha", 0.2)),
        factor=float(spec.options.get("factor", 1.5)))

    def transform(step: int, payload: Any):
        return ctrl.ingest(step, payload)

    return _terminal_pieces(
        spec, transform,
        known_options=("hosts", "grace_s", "alpha", "factor"),
        report=ctrl.report, controller=ctrl,
        attach=lambda session: ctrl.attach(session, spec.name))


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclass
class InSituPlan:
    """A validated, declarative description of every in-situ workflow.

    ``streams``           the payload streams the application will emit
                          (names or :class:`StreamSpec`).
    ``tasks``             the :class:`TaskSpec` bindings.
    ``workers``           p_i — worker threads of the shared runtime pool.
    ``staging_capacity``  bounded staging-ring depth (double-buffering /
                          backpressure horizon).

    Construction validates the whole plan and raises :class:`PlanError`
    naming the offending stream/task: unknown stream, duplicate task name,
    ``every < 1``, unknown preset, preset+sink conflicts, more than one
    checkpoint task, bad backpressure policy.
    """
    streams: Sequence[Union[str, StreamSpec]] = ()
    tasks: Sequence[TaskSpec] = ()
    workers: int = 2
    staging_capacity: int = 4

    def __post_init__(self) -> None:
        specs = [s if isinstance(s, StreamSpec) else StreamSpec(str(s))
                 for s in self.streams]
        names = [s.name for s in specs]
        for n in names:
            if names.count(n) > 1:
                raise PlanError(f"duplicate stream {n!r} in plan")
            if not n:
                raise PlanError("stream names must be non-empty")
        self.streams = tuple(specs)
        self.tasks = tuple(self.tasks)
        if self.workers < 1:
            raise PlanError(f"workers must be >= 1, got {self.workers}")
        if self.staging_capacity < 1:
            raise PlanError(
                f"staging_capacity must be >= 1, got {self.staging_capacity}")
        stream_names = set(names)
        seen: set[str] = set()
        n_ckpt = 0
        for t in self.tasks:
            if not t.name:
                raise PlanError("task names must be non-empty")
            if t.name in seen:
                raise PlanError(f"duplicate task {t.name!r} in plan")
            seen.add(t.name)
            if t.stream not in stream_names:
                raise PlanError(
                    f"task {t.name!r} binds unknown stream {t.stream!r} "
                    f"(declared streams: {sorted(stream_names)})")
            if isinstance(t.trigger, (Every, Adaptive)) and t.trigger.n < 1:
                raise PlanError(
                    f"task {t.name!r}: trigger period must be >= 1, "
                    f"got every={t.trigger.n}")
            if isinstance(t.trigger, Interval) and t.trigger.seconds <= 0:
                raise PlanError(
                    f"task {t.name!r}: Interval seconds must be > 0, "
                    f"got {t.trigger.seconds}")
            if (isinstance(t.trigger, Adaptive)
                    and t.trigger.budget_s is not None
                    and t.trigger.budget_s <= 0):
                raise PlanError(
                    f"task {t.name!r}: Adaptive budget_s must be > 0, "
                    f"got {t.trigger.budget_s}")
            if t.retries is not None and t.retries < 0:
                raise PlanError(
                    f"task {t.name!r}: retries must be >= 0, got {t.retries}")
            if t.retry_backoff_s is not None and t.retry_backoff_s < 0:
                raise PlanError(
                    f"task {t.name!r}: retry_backoff_s must be >= 0, "
                    f"got {t.retry_backoff_s}")
            if (isinstance(t.trigger, Adaptive) and t.backpressure is not None
                    and t.backpressure != "adapt"):
                raise PlanError(
                    f"task {t.name!r}: conflicting triggers — Adaptive "
                    f"requires backpressure 'adapt', got {t.backpressure!r}")
            if t.resolved_backpressure() not in BACKPRESSURE_POLICIES:
                raise PlanError(
                    f"task {t.name!r}: backpressure must be one of "
                    f"{BACKPRESSURE_POLICIES}, got {t.backpressure!r}")
            if t.preset is not None and t.sink is not None:
                raise PlanError(
                    f"task {t.name!r}: give either a preset or an explicit "
                    "sink chain, not both")
            if t.preset is None and t.sink is None:
                raise PlanError(
                    f"task {t.name!r}: needs a preset or a sink")
            if t.preset == "checkpoint":
                n_ckpt += 1
                if n_ckpt > 1:
                    raise PlanError(
                        f"task {t.name!r}: a plan may declare at most one "
                        "checkpoint task")
                if not t.options.get("directory"):
                    raise PlanError(
                        f"task {t.name!r}: checkpoint preset requires "
                        "options={'directory': ...}")
                # the manager owns its pipeline's scheduling knobs; accept
                # only what is actually wired through rather than letting
                # declared-but-ignored fields validate
                if t.backpressure is not None:
                    raise PlanError(
                        f"task {t.name!r}: the checkpoint preset does not "
                        "take a backpressure policy (the manager's "
                        "pipeline uses 'block')")
                if isinstance(t.trigger, Adaptive):
                    raise PlanError(
                        f"task {t.name!r}: the checkpoint preset gates "
                        "saves session-side, so an Adaptive trigger would "
                        "never widen — use Every/When/Interval")
                if t.shards != 1 or not t.pipelined or not t.snapshot:
                    raise PlanError(
                        f"task {t.name!r}: checkpoint preset does not "
                        "accept shards/pipelined/snapshot overrides")
            elif t.preset is not None and t.preset not in _PRESETS:
                raise PlanError(
                    f"task {t.name!r}: unknown preset {t.preset!r} "
                    f"(registered: {preset_names()})")
            if t.shards < 1:
                raise PlanError(
                    f"task {t.name!r}: shards must be >= 1, got {t.shards}")

    # -- dict round-trip ------------------------------------------------------

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "InSituPlan":
        """Build a plan from its plain-dict (TOML/JSON-loadable) form."""
        known = {"streams", "tasks", "workers", "staging_capacity"}
        unknown = set(d) - known
        if unknown:
            raise PlanError(f"unknown plan field(s) {sorted(unknown)}")
        tasks_in = d.get("tasks", {})
        if isinstance(tasks_in, Mapping):
            items = list(tasks_in.items())
        else:
            items = []
            for spec in tasks_in:
                spec = dict(spec)
                if "name" not in spec:
                    raise PlanError("list-form tasks need a 'name' field")
                items.append((spec.pop("name"), spec))
        tasks = [_task_from_dict(name, spec) for name, spec in items]
        return cls(streams=list(d.get("streams", [])), tasks=tasks,
                   workers=int(d.get("workers", 2)),
                   staging_capacity=int(d.get("staging_capacity", 4)))

    def to_dict(self) -> dict:
        """Plain-dict form (inverse of :meth:`from_dict`). Only declarative
        content survives; explicit callable chains raise :class:`PlanError`."""
        return {
            "streams": [s.name for s in self.streams],
            "tasks": {t.name: t.to_dict() for t in self.tasks},
            "workers": self.workers,
            "staging_capacity": self.staging_capacity,
        }


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------

def _memoized(fn: Callable[[], Any]) -> Callable[[], Any]:
    """Evaluate-once wrapper for emit providers: several tasks firing on
    one stream share a single payload materialization. No lock — the
    runtime evaluates providers synchronously on the emitting thread."""
    sentinel = object()
    cache: list = [sentinel]

    def wrapper():
        if cache[0] is sentinel:
            cache[0] = fn()
        return cache[0]

    return wrapper


class _Binding:
    """One task wired into the live runtime (session-internal)."""

    __slots__ = ("spec", "source", "session_gated", "last_fire_t", "mgr")

    def __init__(self, spec: TaskSpec, source: str, session_gated: bool,
                 mgr: Any = None) -> None:
        self.spec = spec
        self.source = source
        self.session_gated = session_gated
        self.last_fire_t: Optional[float] = None
        self.mgr = mgr

    def due(self, step: int, now: float) -> bool:
        """Session-side gate. Every/Adaptive are runtime-gated (so the
        'adapt' policy can widen the effective period); When/Interval are
        evaluated here."""
        trig = self.spec.trigger
        if isinstance(trig, When):
            return bool(trig.predicate(step))
        if isinstance(trig, Interval):
            if (self.last_fire_t is None
                    or now - self.last_fire_t >= trig.seconds):
                self.last_fire_t = now
                return True
            return False
        return True          # Every/Adaptive: the runtime gates on its every


class Session:
    """A live in-situ session: one plan bound to one shared runtime.

    Use as a context manager; the application's only obligations are to
    ``emit(stream, step, payload)`` (payload may be a zero-arg callable —
    it is then only evaluated if some task actually fires) and to exit the
    context (or call :meth:`finish`)::

        with Session(plan) as session:
            for step in range(n):
                ...device step...
                session.emit("grads", step, lambda: grads)

    The session owns placement, triggers, backpressure, checkpointing, and
    reporting; nothing else in the application knows how tasks run.
    """

    def __init__(self, plan: Union[InSituPlan, Mapping[str, Any]], *,
                 telemetry: Optional[Telemetry] = None,
                 runtime: Optional[PipelineRuntime] = None,
                 raise_on_error: bool = False,
                 clock: Optional[Callable[[], float]] = None) -> None:
        if isinstance(plan, Mapping):
            plan = InSituPlan.from_dict(plan)
        self.plan = plan
        # the injected monotonic clock gates wall-clock (Interval) triggers;
        # tests drive it by hand instead of sleeping (Every/Adaptive are
        # step-counted and never read it)
        self._clock = clock if clock is not None else time.monotonic
        self._owns_runtime = runtime is None
        if runtime is None:
            runtime = PipelineRuntime(
                workers=plan.workers, staging_capacity=plan.staging_capacity,
                telemetry=telemetry)
        elif telemetry is not None and telemetry is not runtime.telemetry:
            raise ValueError("pass either a telemetry or a runtime (whose "
                             "telemetry is used), not two different objects")
        self.runtime = runtime
        self.checkpoint = None            # CheckpointManager, if declared
        self._raise_on_error = raise_on_error
        self._finished = False
        self._strict_streams = True       # legacy wrappers relax this
        self._task_stream: dict[str, str] = {}
        self._reporters: dict[str, Callable[[], Mapping[str, Any]]] = {}
        self._stores: dict[str, Any] = {}
        self._controllers: dict[str, Any] = {}
        self._transports: dict[str, transport.Sink] = {}
        self._steering: list[dict] = []   # applied steering commands
        self._steering_rejected = 0       # invalid commands refused
        self._ckpt_meta: Optional[dict] = None
        self._remesh = None               # ElasticRestore after elastic load
        self._by_stream: dict[str, list[_Binding]] = {
            s.name: [] for s in plan.streams}
        for spec in plan.tasks:
            self._bind(spec)

    # -- wiring ---------------------------------------------------------------

    def _bind(self, spec: TaskSpec) -> None:
        self._task_stream[spec.name] = spec.stream
        if spec.preset == "checkpoint":
            self._bind_checkpoint(spec)
            return
        if spec.preset is not None:
            pieces = _PRESETS[spec.preset](spec)
        else:
            pieces = {"sink": spec.sink, "host_stages": spec.host_stages,
                      "device_stage": spec.device_stage,
                      "handoff": spec.handoff}
        if pieces.get("report") is not None:
            self._reporters[spec.name] = pieces["report"]
        if pieces.get("store") is not None:
            self._stores[spec.name] = pieces["store"]
        if pieces.get("controller") is not None:
            self._controllers[spec.name] = pieces["controller"]
        if pieces.get("transport") is not None:
            # declared via options={"to": url}; the session polls its
            # steering back-channel and closes it at finish
            self._transports[spec.name] = pieces["transport"]
        session_gated = isinstance(spec.trigger, (When, Interval))
        every = (spec.trigger.n
                 if isinstance(spec.trigger, (Every, Adaptive)) else 1)
        adapt = (spec.trigger if isinstance(spec.trigger, Adaptive)
                 else Adaptive())
        extra: dict[str, Any] = {}
        if spec.retries is not None:
            extra["retries"] = spec.retries
        if spec.retry_backoff_s is not None:
            extra["retry_backoff_s"] = spec.retry_backoff_s
        task = PipelineTask(
            name=spec.name,
            source=f"{spec.stream}::{spec.name}",
            sink=pieces["sink"],
            host_stages=tuple(pieces.get("host_stages") or ()),
            device_stage=pieces.get("device_stage"),
            handoff=pieces.get("handoff") or default_handoff,
            pipelined=spec.pipelined,
            snapshot=spec.snapshot,
            placement=spec.placement,
            every=every,
            shards=spec.shards,
            backpressure=spec.resolved_backpressure(),
            adapt_after=adapt.after,
            adapt_max_every=adapt.max_every,
            budget_s=adapt.budget_s,
            **extra,
        )
        self.runtime.register(task)
        self._by_stream[spec.stream].append(
            _Binding(spec, task.source, session_gated))
        if pieces.get("attach") is not None:
            # presets that need the live session (clock adoption, shedding
            # surface) get it only after their task is registered
            pieces["attach"](self)

    def _bind_checkpoint(self, spec: TaskSpec) -> None:
        """Fold a CheckpointManager into the session as a declared task.

        Save/restore/retention semantics are the manager's, unchanged; the
        manager registers its pipeline into the *shared* runtime, so
        checkpoint writes and analytics draw from the same worker pool."""
        from repro.checkpoint import CheckpointConfig, CheckpointManager
        opts = dict(spec.options)
        every = (spec.trigger.n
                 if isinstance(spec.trigger, (Every, Adaptive)) else 1)
        cfg = CheckpointConfig(
            directory=opts.pop("directory"), mode=spec.placement,
            every=every, **opts)
        mgr = CheckpointManager(cfg, runtime=self.runtime)
        self.checkpoint = mgr
        if mgr._mirror is not None:
            # a mirror-replicating checkpoint task exposes the same
            # steering back-channel as any other transport-bound task
            self._transports[spec.name] = mgr._mirror
        self._by_stream[spec.stream].append(
            _Binding(spec, "ckpt_state", True, mgr=mgr))

    # -- producer side --------------------------------------------------------

    def emit(self, stream: str, step: int, payload: Any) -> None:
        """Offer one step's payload on a stream — the only producer call.

        ``payload`` may be the value itself or a zero-arg callable; a
        callable is evaluated at most once per emit — even when several
        bound tasks fire at the same step — and only if at least one task
        actually fires (lazy providers, exactly like the legacy engine's
        providers dict).
        """
        if self._transports:
            # the consumer's steering back-channel: a select(0) per
            # transport when idle, so polling every emit is cheap
            self.poll_steering()
        bindings = self._by_stream.get(stream)
        provider = (_memoized(payload) if callable(payload)
                    else (lambda: payload))
        if bindings is None:
            if not self._strict_streams:
                # legacy providers-dict contract: the loop offers every
                # source, tasks pick; an unmatched source is a no-op
                self.runtime.submit(step, {stream: provider})
                return
            raise PlanError(
                f"emit on unknown stream {stream!r} (declared: "
                f"{sorted(self._by_stream)})")
        now = self._clock()
        providers: dict[str, Callable[[], Any]] = {}
        for b in bindings:
            if b.session_gated and not b.due(step, now):
                continue
            if b.mgr is not None:
                # checkpoint: session-gated; the manager's registered
                # pipeline (every=1) does the save through the shared pool
                if isinstance(b.spec.trigger, (Every, Adaptive)):
                    if step % b.spec.trigger.n:
                        continue
                b.mgr.save(step, provider(), meta=self._ckpt_meta)
                continue
            providers[b.source] = provider
        if providers:
            self.runtime.submit(step, providers)

    # -- steering (the consumer's back-channel) -------------------------------

    def _binding(self, task: str) -> Optional[_Binding]:
        for b_list in self._by_stream.values():
            for b in b_list:
                if b.spec.name == task:
                    return b
        return None

    def poll_steering(self) -> list[dict]:
        """Drain steering messages from every transport back-channel and
        apply them to the live run — the ISAAC pattern: an in-situ
        consumer retunes the producer mid-run.

        A message is a JSON dict naming a task and the knobs to set::

            {"task": "analytics", "every": 20}       # firing cadence
            {"task": "ckpt", "lossy_eps": 0.05}      # lossy threshold

        ``every`` retunes any bound task (checkpoint tasks via their
        session-side trigger, everything else via the runtime's effective
        period — overriding adapt-widened values too); ``lossy_eps``
        retunes the checkpoint codec's error bound for every *subsequent*
        save. Unknown knobs are recorded as ignored, never fatal — a
        newer dashboard must not crash an older trainer. Applied commands
        accumulate in ``report()["steering"]``.
        """
        applied = []
        for via, tsink in self._transports.items():
            for msg in tsink.poll_control():
                if not isinstance(msg, dict):
                    continue
                rec = self._apply_steering(via, msg)
                self._steering.append(rec)
                applied.append(rec)
        return applied

    def _apply_steering(self, via: str, msg: dict) -> dict:
        """Validate-then-apply one steering message.

        Three buckets per command: ``applied`` (took effect), ``rejected``
        (named a known knob with an invalid value — ``every <= 0``,
        non-finite/negative ``lossy_eps``, an unknown task name — these
        must never touch cadence state), and ``ignored`` (unknown knob, or
        a knob with nothing bound to retune — a newer dashboard must not
        crash an older trainer). Rejections are counted into
        ``report()["steering"]["steering_rejected"]``.
        """
        import math

        task = str(msg.get("task", via))
        rec: dict[str, Any] = {"via": via, "task": task,
                               "applied": {}, "rejected": {}, "ignored": {}}
        binding = self._binding(task)

        def reject(key, val, why):
            rec["rejected"][key] = f"{val!r} ({why})"
            self._steering_rejected += 1

        for key, val in msg.items():
            if key == "task":
                continue
            if key == "every":
                try:
                    n = int(val)
                except (ValueError, TypeError) as e:
                    reject(key, val, e)
                    continue
                if n < 1:
                    reject(key, val, f"every must be >= 1, got {n}")
                    continue
                try:
                    if binding is not None and binding.mgr is not None:
                        # checkpoint saves are session-gated on the
                        # trigger, not the runtime period
                        binding.spec.trigger = Every(n)
                    else:
                        self.runtime.set_every(task, n)
                    rec["applied"]["every"] = n
                except (ValueError, KeyError) as e:
                    # unknown task name: the runtime refused to retune
                    reject(key, val, e)
            elif key == "lossy_eps":
                try:
                    eps = float(val)
                except (ValueError, TypeError) as e:
                    reject(key, val, e)
                    continue
                # NaN fails the isfinite check, not the comparison —
                # ``nan <= 0`` is False, so a plain ``<= 0`` guard would
                # wave NaN straight into the codec's error bound
                if not math.isfinite(eps) or eps <= 0:
                    reject(key, val, "lossy_eps must be finite and > 0")
                    continue
                if self.checkpoint is None:
                    rec["ignored"][key] = eps     # valid, nothing to retune
                    continue
                self.checkpoint.cfg.lossy_eps = eps
                rec["applied"]["lossy_eps"] = eps
            else:
                rec["ignored"][key] = val
        return rec

    def transport_of(self, task: str) -> Optional[transport.Sink]:
        """The transport sink a task declared via ``options={"to": ...}``
        (None when the task has no transport)."""
        return self._transports.get(task)

    def step_span(self, step: int):
        """Span context for the application's device step (``step/compute``)
        so device/in-situ attribution in :meth:`report` is exact."""
        return self.runtime.telemetry.span("step/compute", step=step)

    def run(self, n_steps: int,
            app_step: Callable[[int], Mapping[str, Any]],
            finish: bool = True) -> Telemetry:
        """Drive ``n_steps`` of an application against this session.

        ``app_step(step)`` runs one device step inside a ``step/compute``
        span and returns ``{stream: payload-or-provider}``; every entry is
        emitted. The canonical workflow driver — the legacy
        ``run_pipeline``/``run_workflow`` are shims over it.
        """
        for step in range(n_steps):
            with self.step_span(step):
                payloads = app_step(step)
            for stream, payload in payloads.items():
                self.emit(stream, step, payload)
        if finish:
            self.finish()
        return self.telemetry

    # -- state ----------------------------------------------------------------

    @property
    def streams(self) -> frozenset:
        """The stream names this session accepts emits on — drivers with
        optional workloads gate their emits on membership here (a custom
        plan may declare only a subset of the default streams)."""
        return frozenset(self._by_stream)

    @property
    def clock(self) -> Callable[[], float]:
        """The session's monotonic clock (injected or ``time.monotonic``);
        Interval triggers and the fault subsystem read the same source."""
        return self._clock

    @property
    def telemetry(self) -> Telemetry:
        return self.runtime.telemetry

    @property
    def results(self):
        """All TaskResults so far (checkpoint reports land here too)."""
        return self.runtime.results

    def errors(self) -> list[tuple[str, int, BaseException]]:
        """Captured task failures as (task, step, exception)."""
        return list(self.runtime.errors)

    def snapshot_store(self, task: str) -> Any:
        """The SnapshotStore behind a ``serve_snapshot`` task (for restore
        / chain inspection); raises ``PlanError`` for other tasks."""
        if task not in self._stores:
            raise PlanError(
                f"task {task!r} has no snapshot store (declared stores: "
                f"{sorted(self._stores)})")
        return self._stores[task]

    def fault_controller(self, task: Optional[str] = None) -> Any:
        """The FaultController behind a ``fault`` task. ``task=None`` picks
        the only one; raises :class:`PlanError` when the plan declares none
        (or several, without naming which)."""
        if task is None:
            if len(self._controllers) != 1:
                raise PlanError(
                    "plan declares "
                    f"{len(self._controllers)} fault controller(s) — name "
                    f"the task (declared: {sorted(self._controllers)})")
            return next(iter(self._controllers.values()))
        if task not in self._controllers:
            raise PlanError(
                f"task {task!r} has no fault controller (declared: "
                f"{sorted(self._controllers)})")
        return self._controllers[task]

    def shed_insitu(self, exclude: Sequence[str] = ()) -> dict[str, int]:
        """Shed in-situ load: double every bound task's effective firing
        period (the paper's "reduce p_i on contended nodes" mitigation).

        Returns ``{task: new_effective_every}`` for the tasks that actually
        widened (tasks at their cap don't). The checkpoint task is never
        shed — its saves are session-gated, so a widened runtime period
        would silently drop them — and ``exclude`` skips more (the fault
        task excludes itself so mitigation doesn't starve its own
        heartbeats).
        """
        skip = set(exclude)
        if self.checkpoint is not None:
            skip.add("checkpoint")
        widened: dict[str, int] = {}
        for task in self.runtime.tasks:
            if task.name in skip:
                continue
            if self.runtime.widen_every(task.name):
                widened[task.name] = self.runtime.effective_every(task.name)
                self.runtime.telemetry.count(f"fault/shed/{task.name}")
        return widened

    def stream_of(self, task: str) -> Optional[str]:
        """The stream a task is bound to (None for tasks the plan doesn't
        know, e.g. registered directly on a wrapped runtime)."""
        if task == "checkpoint" and task not in self._task_stream:
            for b_list in self._by_stream.values():
                for b in b_list:
                    if b.mgr is not None:
                        return b.spec.stream
        return self._task_stream.get(task)

    # -- checkpoint passthrough ----------------------------------------------

    def set_checkpoint_meta(self, meta: Optional[Mapping[str, Any]] = None,
                            *, mesh: Any = None) -> None:
        """Attach run metadata to every subsequent checkpoint save.

        ``mesh`` records the device-mesh geometry under ``meta["mesh"]``
        (``{"shape": [...], "axes": [...]}``) — what
        ``restore(elastic=True)`` reads back to plan the remesh when the
        caller doesn't pass ``old_shape``/``axis_names`` explicitly.
        """
        m = dict(meta) if meta else {}
        if mesh is not None:
            m["mesh"] = {"shape": [int(s) for s in mesh.devices.shape],
                         "axes": [str(a) for a in mesh.axis_names]}
        self._ckpt_meta = m or None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None, *,
                elastic: bool = False,
                devices: Optional[Sequence[Any]] = None,
                old_shape: Optional[Sequence[int]] = None,
                axis_names: Optional[Sequence[str]] = None,
                make_shardings: Optional[Callable[[Any], PyTree]] = None,
                ) -> tuple[int, PyTree]:
        """Restore from the plan's checkpoint task.

        ``elastic=True`` is the failure-recovery path: compute the largest
        mesh that fits the surviving ``devices`` (default: all visible
        devices) via :func:`~repro.distributed.fault.plan_elastic_remesh`,
        then read the v2 packed-shard checkpoint re-placed under that
        shrunken mesh — TP shards merge by the plan's
        ``model_merge_factor`` implicitly, because v2 leaves are stored
        logically complete and re-placement under the new shardings *is*
        the merge. No full blocking restore onto the old grid happens.

        The old mesh geometry comes from ``old_shape``/``axis_names`` or,
        by default, from the checkpoint's recorded meta (saves made after
        :meth:`set_checkpoint_meta`\\ ``(mesh=...)``). ``make_shardings``
        maps the new mesh to the restore shardings (falling back to any
        explicit ``shardings``/host placement). The resolved plan, mesh,
        and step are kept on :attr:`remesh`.
        """
        if self.checkpoint is None:
            raise PlanError("plan declares no checkpoint task to restore from")
        if not elastic:
            return self.checkpoint.restore(template, step, shardings)
        import jax
        import numpy as np
        from repro.distributed.fault import (ElasticRestore,
                                             plan_elastic_remesh)
        devs = list(devices) if devices is not None else list(jax.devices())
        if old_shape is None or axis_names is None:
            meta = self.checkpoint.read_meta(step) or {}
            mesh_meta = meta.get("mesh")
            if not mesh_meta:
                raise PlanError(
                    "elastic restore needs the old mesh geometry — pass "
                    "old_shape/axis_names, or save checkpoints after "
                    "Session.set_checkpoint_meta(mesh=...)")
            if old_shape is None:
                old_shape = tuple(mesh_meta["shape"])
            if axis_names is None:
                axis_names = tuple(mesh_meta["axes"])
        plan = plan_elastic_remesh(tuple(old_shape), tuple(axis_names),
                                   len(devs))
        mesh = jax.sharding.Mesh(
            np.asarray(devs[:plan.new_device_count],
                       dtype=object).reshape(plan.new_shape),
            plan.axis_names)
        if make_shardings is not None:
            shardings = make_shardings(mesh)
        step, state = self.checkpoint.restore(template, step, shardings)
        self._remesh = ElasticRestore(plan=plan, mesh=mesh, step=step)
        return step, state

    @property
    def remesh(self):
        """The :class:`~repro.distributed.fault.ElasticRestore` resolved by
        the last ``restore(elastic=True)`` (None before)."""
        return self._remesh

    def latest_checkpoint_step(self) -> Optional[int]:
        if self.checkpoint is None:
            return None
        return self.checkpoint.latest_step()

    # -- lifecycle ------------------------------------------------------------

    def wait_idle(self, timeout: float = 600.0) -> bool:
        """Block until every enqueued async firing has finished."""
        return self.runtime.wait_idle(timeout=timeout)

    def finish(self, timeout: float = 600.0,
               raise_on_error: Optional[bool] = None) -> None:
        """Drain the ring, join the pool (the non-overlapped tail), and —
        with ``raise_on_error=True`` — re-raise the first task failure as
        :class:`InSituTaskError` with stream/task/step context instead of
        leaving it silently in :meth:`errors`.

        ``raise_on_error=None`` uses the session's constructor default.
        Idempotent: later calls only re-check the error state.
        """
        if not self._finished:
            self._finished = True
            self.runtime.wait_idle(timeout=timeout)
            if self._owns_runtime:
                self.runtime.drain(timeout=timeout)
            # transports not owned by a task sink (snapshot mirrors,
            # checkpoint replication) close here; Sink.close is idempotent
            for tsink in self._transports.values():
                try:
                    tsink.close()
                except Exception:  # noqa: BLE001 - teardown must not raise
                    pass
            if self.checkpoint is not None:
                self.checkpoint.finish()
        raise_ = (self._raise_on_error if raise_on_error is None
                  else raise_on_error)
        if raise_ and self.runtime.errors:
            task, step, exc = self.runtime.errors[0]
            stream = self.stream_of(task) or "?"
            raise InSituTaskError(task, stream, step, exc) from exc

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight application exception with a task error
        self.finish(raise_on_error=False if exc_type is not None else None)

    # -- reporting ------------------------------------------------------------

    def report(self) -> dict[str, Any]:
        """One merged report: telemetry overlap attribution, task results,
        errors, backpressure state, and checkpoint statistics."""
        rep = self.runtime.report()
        def _runtime_name(t: TaskSpec) -> str:
            # the checkpoint manager registers its pipeline under its own
            # historical task name, whatever the plan called the binding
            return "checkpoint" if t.preset == "checkpoint" else t.name

        rep["tasks"] = {
            t.name: {"stream": t.stream,
                     "results": sum(1 for r in self.runtime.results
                                    if r.task == _runtime_name(t)),
                     "errors": sum(1 for (n, _, _) in self.runtime.errors
                                   if n == _runtime_name(t))}
            for t in self.plan.tasks}
        for name, reporter in self._reporters.items():
            # preset-contributed stats (e.g. serve_snapshot's delta ratio
            # and chain depth) ride the task's entry
            if name in rep["tasks"]:
                rep["tasks"][name].update(dict(reporter()))
        for name, entry in rep["tasks"].items():
            if name in rep.get("degraded", {}):
                entry["degraded"] = dict(rep["degraded"][name])
        for name, tsink in self._transports.items():
            stats = {"sink": type(tsink).__name__,
                     "frames": tsink.frames_written,
                     "bytes": tsink.bytes_written}
            if isinstance(tsink, transport.StreamSink):
                stats["reconnects"] = tsink.reconnects
            rep["tasks"].setdefault(name, {})["transport"] = stats
        if self._steering or self._steering_rejected:
            rep["steering"] = {
                "commands": [dict(s) for s in self._steering],
                "steering_rejected": self._steering_rejected,
            }
        if self._controllers:
            # failed hosts / straggler EWMA / applied mitigations, flat when
            # the plan declares one fault task (the common case)
            if len(self._controllers) == 1:
                rep["fault"] = next(iter(self._controllers.values())).report()
            else:
                rep["fault"] = {n: c.report()
                                for n, c in self._controllers.items()}
        rep["errors"] = [
            {"task": n, "stream": self.stream_of(n) or "?", "step": s,
             "error": f"{type(e).__name__}: {e}"}
            for (n, s, e) in self.runtime.errors]
        if self.checkpoint is not None:
            reports = list(self.checkpoint.reports)
            rep["checkpoint"] = {
                "saves": len(reports),
                "raw_bytes": sum(r.raw_bytes for r in reports),
                "stored_bytes": sum(r.stored_bytes for r in reports),
                "last_step": reports[-1].step if reports else None,
                "kept_steps": self.checkpoint.list_steps(),
            }
        return rep

    # -- legacy adapter --------------------------------------------------------

    @classmethod
    def over_runtime(cls, runtime: PipelineRuntime) -> "Session":
        """Wrap an already-wired :class:`PipelineRuntime` (legacy path).

        Streams mirror the registered tasks' ``source`` keys and gating is
        purely runtime-side; ``emit``/``run``/``report``/``finish`` behave
        identically. This is how the deprecation shims
        (``run_pipeline``/``InSituEngine``) ride on a Session.
        """
        sess = cls(InSituPlan(), runtime=runtime)
        sess._owns_runtime = True        # the shim transfers ownership
        sess._strict_streams = False
        for t in runtime.tasks:
            sess._task_stream.setdefault(t.name, t.source)
            sess._by_stream.setdefault(t.source, []).append(
                _Binding(TaskSpec(name=t.name, stream=t.source,
                                  sink=t.sink), t.source, False))
        return sess
