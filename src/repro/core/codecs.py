"""Host-side lossless codecs + chunked tensor framing (the paper's Table II
layer).

The paper evaluates Bzip2 / LZ4 / LZ4HC / ZLIB / ZSTD on raw floating-point
simulation output (Table II) and finds plain lossless compression removes only
1.5-10 % — which is exactly why the lossy+lossless *hybrid* pipeline exists.
We reproduce that comparison on training-state tensors (bf16/f32 weights,
moments) in ``benchmarks/tab2_codecs.py``.

Framing (v2): every compressed tensor is self-describing —
  MAGIC | version | codec id | dtype | ndim | shape | raw nbytes
        | chunk size | n_chunks | per-chunk compressed sizes | payloads
Each chunk is an *independently* compressed ``memoryview`` slice of the
array's buffer (stream codecs over the view — the raw bytes are never
copied into an intermediate ``tobytes()`` string, and the final frame is
assembled with a single ``join``). Independent chunks are what make the
codec chunk-parallel: encode and decode both fan chunks out across a thread
pool (stdlib codecs release the GIL, so this is real parallelism), and a
decoder can stream-decode without out-of-band metadata. v1 frames (single
stream, pre-chunking) still decode — old checkpoints restore unchanged.

All stdlib codecs (zlib/bz2/lzma) release the GIL during (de)compression, so
async in-situ workers genuinely overlap with the host-side training loop —
this is what makes the in-process analog of the paper's MPMD split honest.
"""
from __future__ import annotations

import bz2
import lzma
import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

MAGIC = b"RPRC"
_VERSION = 2
_V1 = 1
DEFAULT_CHUNK = 1 << 20        # 1 MiB raw bytes per independently-coded chunk


def _stream(factory) -> Callable[[bytes], bytes]:
    """One-shot wrapper over a compressobj factory; accepts any buffer
    (memoryview slices included) without copying it to bytes first."""
    def comp(data):
        c = factory()
        head = c.compress(data)
        tail = c.flush()
        return head + tail if head else tail
    return comp


# codec registry: name -> (id, compress, decompress); both sides take
# bytes-like buffers (bytes, memoryview) — never force a copy on the caller.
_COMPRESSORS: dict[str, tuple[int, Callable[[bytes], bytes],
                              Callable[[bytes], bytes]]] = {
    "none": (0, lambda b: b, lambda b: b),
    "zlib": (1, _stream(lambda: zlib.compressobj(6)), zlib.decompress),
    "zlib1": (2, _stream(lambda: zlib.compressobj(1)), zlib.decompress),
    "zlib9": (3, _stream(lambda: zlib.compressobj(9)), zlib.decompress),
    "bz2": (4, _stream(lambda: bz2.BZ2Compressor(9)), bz2.decompress),
    "lzma": (5, _stream(lambda: lzma.LZMACompressor(preset=1)),
             lzma.decompress),
}

try:  # optional, mirrors the paper's ZSTD/LZ4 rows when available
    import zstandard  # type: ignore

    _COMPRESSORS["zstd"] = (
        6,
        lambda b: zstandard.ZstdCompressor(level=3).compress(bytes(b)),
        lambda b: zstandard.ZstdDecompressor().decompress(bytes(b)),
    )
except ImportError:
    pass

try:
    import lz4.frame  # type: ignore

    _COMPRESSORS["lz4"] = (7, lambda b: lz4.frame.compress(bytes(b)),
                           lambda b: lz4.frame.decompress(bytes(b)))
except ImportError:
    pass

_BY_ID = {cid: (name, c, d) for name, (cid, c, d) in _COMPRESSORS.items()}


def available() -> list[str]:
    return sorted(_COMPRESSORS)


def compressor(codec: str) -> tuple[int, Callable[[bytes], bytes],
                                    Callable[[bytes], bytes]]:
    """(codec id, compress, decompress) for ``codec`` — the hook streamed
    framing paths use to compress chunk buffers they produced themselves
    (e.g. device-sliced int8 chunks) while keeping the frame format
    identical to ``encode``."""
    if codec not in _COMPRESSORS:
        raise KeyError(f"unknown codec {codec!r}; available: {available()}")
    return _COMPRESSORS[codec]


# ---------------------------------------------------------------------------
# shared chunk pool: one lazily-created executor the checkpoint encode stage
# and restore path fan chunk (de)compression out on. GIL-released stdlib
# codecs make this real parallelism without forking the process.
# ---------------------------------------------------------------------------

_pool: Optional[ThreadPoolExecutor] = None
_pool_lock = threading.Lock()


def codec_pool() -> ThreadPoolExecutor:
    """Process-wide chunk-compression pool (lazily created)."""
    global _pool
    with _pool_lock:
        if _pool is None:
            _pool = ThreadPoolExecutor(
                max_workers=max(2, os.cpu_count() or 2),
                thread_name_prefix="codec")
        return _pool


@dataclass(frozen=True)
class CompressionStats:
    codec: str
    raw_bytes: int
    compressed_bytes: int

    @property
    def ratio(self) -> float:
        """Paper Eq. (1): CR = (original - compressed) / original."""
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.compressed_bytes) / self.raw_bytes


def _dtype_token(dtype: np.dtype) -> bytes:
    """Self-describing dtype token. Extension dtypes (ml_dtypes bfloat16)
    have a void ``.str`` ('<V2' — not invertible), so they are recorded by
    name instead; the delta framing shares these helpers."""
    dt = np.dtype(dtype)
    if dt.kind == "V" and dt.names is None:
        return dt.name.encode()
    return dt.str.encode()


def _dtype_from_token(token: str) -> np.dtype:
    try:
        return np.dtype(token)
    except TypeError:
        import ml_dtypes  # noqa: F401  registers bfloat16 et al. by name
        return np.dtype(token)


def _byte_view(arr: np.ndarray) -> memoryview:
    """Zero-copy byte view of a contiguous array (no ``tobytes()``).

    Goes through a uint8 view rather than ``memoryview(...).cast`` because
    extension dtypes (ml_dtypes bfloat16) have no buffer-protocol format.
    """
    return memoryview(arr.reshape(-1).view(np.uint8))


def _chunk_views(arr: np.ndarray, chunk_bytes: int) -> list[memoryview]:
    if arr.nbytes == 0:
        return []
    mv = _byte_view(arr)
    return [mv[off:off + chunk_bytes]
            for off in range(0, len(mv), chunk_bytes)]


def assemble_frame(codec: str, dtype, shape, raw_nbytes: int,
                   chunk_bytes: int, payloads: list[bytes]) -> bytes:
    """Assemble a v2 frame from already-compressed chunk payloads.

    Byte-identical to ``encode()`` of the same logical array — streamed
    producers (per-chunk D2H + compress) share the exact frame layout."""
    cid, _, _ = compressor(codec)
    dt = _dtype_token(np.dtype(dtype))
    ndim = len(shape)
    parts = [
        MAGIC,
        struct.pack("<BBB", _VERSION, cid, len(dt)), dt,
        struct.pack("<B", ndim),
        struct.pack(f"<{ndim}q", *shape),
        struct.pack("<qqI", raw_nbytes, int(chunk_bytes), len(payloads)),
        struct.pack(f"<{len(payloads)}I", *(len(p) for p in payloads)),
        *payloads,
    ]
    return b"".join(parts)


def encode(arr: np.ndarray, codec: str = "zlib", *,
           chunk_bytes: int = DEFAULT_CHUNK,
           pool: Optional[ThreadPoolExecutor] = None
           ) -> tuple[bytes, CompressionStats]:
    """Frame + losslessly compress one ndarray, chunk by chunk.

    ``pool`` (e.g. ``codec_pool()``) compresses the chunks of a multi-chunk
    array concurrently; the frame layout is identical either way.
    """
    _, comp, _ = compressor(codec)
    arr = np.ascontiguousarray(arr)
    views = _chunk_views(arr, int(chunk_bytes))
    if pool is not None and len(views) > 1:
        payloads = list(pool.map(comp, views))
    else:
        payloads = [comp(v) for v in views]
    blob = assemble_frame(codec, arr.dtype, arr.shape, arr.nbytes,
                          int(chunk_bytes), payloads)
    return blob, CompressionStats(codec, arr.nbytes, len(blob))


def decode(blob: bytes, *,
           pool: Optional[ThreadPoolExecutor] = None) -> np.ndarray:
    """Decode a framed tensor (v2 chunked, or a legacy v1 single-stream).

    v2 chunks are independent, so ``pool`` fans the decompression out; each
    chunk lands at its offset in one preallocated buffer (no concat copy).
    """
    if bytes(blob[:4]) != MAGIC:
        raise ValueError("bad frame magic")
    view = memoryview(blob)
    version, cid, dtlen = struct.unpack_from("<BBB", blob, 4)
    off = 7
    dtype = _dtype_from_token(bytes(view[off:off + dtlen]).decode())
    off += dtlen
    (ndim,) = struct.unpack_from("<B", blob, off)
    off += 1
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    _, _, decomp = _BY_ID[cid]
    if version == _V1:
        # legacy single-stream frame: payload is one compressed run of the
        # whole raw buffer (old checkpoints restore through this path).
        (raw_nbytes,) = struct.unpack_from("<q", blob, off)
        off += 8
        raw = decomp(view[off:])
        if len(raw) != raw_nbytes:
            raise ValueError(
                f"frame length mismatch: {len(raw)} != {raw_nbytes}")
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    if version != _VERSION:
        raise ValueError(f"unsupported frame version {version}")
    raw_nbytes, chunk_bytes, n_chunks = struct.unpack_from("<qqI", blob, off)
    off += 20
    if chunk_bytes < 1 or raw_nbytes < 0:
        raise ValueError("corrupt chunk header")
    want_chunks = -(-raw_nbytes // chunk_bytes)   # ceil; 0 for empty arrays
    if n_chunks != want_chunks:
        # v1 raised on a short payload; the chunk table must cover the raw
        # buffer exactly or the tail would silently decode as zeros.
        raise ValueError(
            f"chunk table mismatch: {n_chunks} chunks cannot cover "
            f"{raw_nbytes} raw bytes at {chunk_bytes} per chunk")
    sizes = struct.unpack_from(f"<{n_chunks}I", blob, off)
    off += 4 * n_chunks
    out = bytearray(raw_nbytes)

    jobs = []
    in_off = off
    for i, size in enumerate(sizes):
        jobs.append((in_off, size, i * chunk_bytes))
        in_off += size

    def _one(job: tuple[int, int, int]) -> None:
        src, size, dst = job
        raw = decomp(view[src:src + size])
        want = min(chunk_bytes, raw_nbytes - dst)
        if len(raw) != want:
            raise ValueError(f"chunk length mismatch: {len(raw)} != {want}")
        out[dst:dst + len(raw)] = raw

    if pool is not None and len(jobs) > 1:
        list(pool.map(_one, jobs))
    else:
        for job in jobs:
            _one(job)
    if raw_nbytes == 0:
        return np.empty(shape, dtype=dtype)
    return np.frombuffer(out, dtype=dtype).reshape(shape)


def compression_ratio(arr: np.ndarray, codec: str) -> CompressionStats:
    """Measure-only path (paper Table II): no framing overhead included."""
    _, comp, _ = _COMPRESSORS[codec]
    arr = np.ascontiguousarray(arr)
    return CompressionStats(codec, arr.nbytes, len(comp(_byte_view(arr))))


# ---------------------------------------------------------------------------
# registry adapter: every framed lossless codec is a repro.core.compression
# Codec (exact roundtrip, self-describing frame).
# ---------------------------------------------------------------------------

from repro.core import compression as _compression  # noqa: E402


class FramedLosslessCodec:
    lossy = False

    def __init__(self, name: str) -> None:
        self.name = name

    def encode(self, arr: np.ndarray) -> bytes:
        return encode(arr, self.name)[0]

    def decode(self, blob: bytes) -> np.ndarray:
        return decode(blob)


for _name in list(_COMPRESSORS):
    _compression.register(FramedLosslessCodec(_name))
