"""Host-side lossless codecs + tensor framing (the paper's Table II layer).

The paper evaluates Bzip2 / LZ4 / LZ4HC / ZLIB / ZSTD on raw floating-point
simulation output (Table II) and finds plain lossless compression removes only
1.5-10 % — which is exactly why the lossy+lossless *hybrid* pipeline exists.
We reproduce that comparison on training-state tensors (bf16/f32 weights,
moments) in ``benchmarks/tab2_codecs.py``.

Framing: every compressed tensor is self-describing —
  MAGIC | version | codec id | dtype | ndim | shape | raw nbytes | payload
so a checkpoint shard can be decoded without out-of-band metadata (the
restart path depends only on the manifest listing file names).

All stdlib codecs (zlib/bz2/lzma) release the GIL during (de)compression, so
async in-situ workers genuinely overlap with the host-side training loop —
this is what makes the in-process analog of the paper's MPMD split honest.
"""
from __future__ import annotations

import bz2
import lzma
import struct
import zlib
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

MAGIC = b"RPRC"
_VERSION = 1

# codec registry: name -> (id, compress, decompress)
_COMPRESSORS: dict[str, tuple[int, Callable[[bytes], bytes],
                              Callable[[bytes], bytes]]] = {
    "none": (0, lambda b: b, lambda b: b),
    "zlib": (1, lambda b: zlib.compress(b, 6), zlib.decompress),
    "zlib1": (2, lambda b: zlib.compress(b, 1), zlib.decompress),
    "zlib9": (3, lambda b: zlib.compress(b, 9), zlib.decompress),
    "bz2": (4, lambda b: bz2.compress(b, 9), bz2.decompress),
    "lzma": (5, lambda b: lzma.compress(b, preset=1), lzma.decompress),
}

try:  # optional, mirrors the paper's ZSTD/LZ4 rows when available
    import zstandard  # type: ignore

    _COMPRESSORS["zstd"] = (
        6,
        lambda b: zstandard.ZstdCompressor(level=3).compress(b),
        lambda b: zstandard.ZstdDecompressor().decompress(b),
    )
except ImportError:
    pass

try:
    import lz4.frame  # type: ignore

    _COMPRESSORS["lz4"] = (7, lz4.frame.compress, lz4.frame.decompress)
except ImportError:
    pass

_BY_ID = {cid: (name, c, d) for name, (cid, c, d) in _COMPRESSORS.items()}


def available() -> list[str]:
    return sorted(_COMPRESSORS)


@dataclass(frozen=True)
class CompressionStats:
    codec: str
    raw_bytes: int
    compressed_bytes: int

    @property
    def ratio(self) -> float:
        """Paper Eq. (1): CR = (original - compressed) / original."""
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.compressed_bytes) / self.raw_bytes


def _dtype_token(dtype: np.dtype) -> bytes:
    return np.dtype(dtype).str.encode()


def encode(arr: np.ndarray, codec: str = "zlib") -> tuple[bytes, CompressionStats]:
    """Frame + losslessly compress one ndarray."""
    if codec not in _COMPRESSORS:
        raise KeyError(f"unknown codec {codec!r}; available: {available()}")
    cid, comp, _ = _COMPRESSORS[codec]
    arr = np.ascontiguousarray(arr)
    raw = arr.tobytes()
    payload = comp(raw)
    dt = _dtype_token(arr.dtype)
    header = MAGIC + struct.pack(
        "<BBB", _VERSION, cid, len(dt)) + dt + struct.pack(
        "<B", arr.ndim) + struct.pack(f"<{arr.ndim}q", *arr.shape) + struct.pack(
        "<q", len(raw))
    blob = header + payload
    return blob, CompressionStats(codec, len(raw), len(blob))


def decode(blob: bytes) -> np.ndarray:
    if blob[:4] != MAGIC:
        raise ValueError("bad frame magic")
    off = 4
    version, cid, dtlen = struct.unpack_from("<BBB", blob, off)
    off += 3
    if version != _VERSION:
        raise ValueError(f"unsupported frame version {version}")
    dtype = np.dtype(blob[off:off + dtlen].decode())
    off += dtlen
    (ndim,) = struct.unpack_from("<B", blob, off)
    off += 1
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    (raw_nbytes,) = struct.unpack_from("<q", blob, off)
    off += 8
    _, _, decomp = _BY_ID[cid]
    raw = decomp(blob[off:])
    if len(raw) != raw_nbytes:
        raise ValueError(f"frame length mismatch: {len(raw)} != {raw_nbytes}")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


def compression_ratio(arr: np.ndarray, codec: str) -> CompressionStats:
    """Measure-only path (paper Table II): no framing overhead included."""
    _, comp, _ = _COMPRESSORS[codec]
    raw = arr.tobytes()
    return CompressionStats(codec, len(raw), len(comp(raw)))


# ---------------------------------------------------------------------------
# registry adapter: every framed lossless codec is a repro.core.compression
# Codec (exact roundtrip, self-describing frame).
# ---------------------------------------------------------------------------

from repro.core import compression as _compression  # noqa: E402


class FramedLosslessCodec:
    lossy = False

    def __init__(self, name: str) -> None:
        self.name = name

    def encode(self, arr: np.ndarray) -> bytes:
        return encode(arr, self.name)[0]

    def decode(self, blob: bytes) -> np.ndarray:
        return decode(blob)


for _name in list(_COMPRESSORS):
    _compression.register(FramedLosslessCodec(_name))
