"""InSituEngine — compatibility shim over ``repro.core.runtime``.

Fig. 1 of the paper, mapped to a JAX device loop (see runtime.py for the
authoritative semantics — SYNC/ASYNC/HYBRID are scheduling policies of one
shared worker-pool scheduler):

  SYNC   (Fig. 1a): the loop *blocks*: device->host hand-off, then the task
         runs inline on the loop thread — the GPU stall the paper's NSight
         timelines show. Sharded sync firings ride the shared pool behind a
         latch.
  ASYNC  (Fig. 1b): the loop blocks only for the hand-off (ADIOS2-send
         analog); p_i pool workers consume the bounded staging ring
         concurrently with subsequent device steps. A slow in-situ side
         eventually exerts backpressure (F3).
  HYBRID (Fig. 1c): a deeply-coupled device stage shrinks the payload; the
         hand-off moves the small residue; host stages run async.

The MPMD resource split p_o + p_i = p_t becomes a host-thread split: the
training loop plus data pipeline hold p_o threads, the runtime pool owns
p_i workers. Host codecs and numpy release the GIL, so the overlap is real
in-process.

This module keeps the original task-list API (``InSituTask`` with a single
``fn``); each task lowers to a single-sink ``PipelineTask``. New code
should declare pipelines against ``repro.core.runtime`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.runtime import (Placement, PipelineRuntime, PipelineTask,
                                TaskResult, run_pipeline, split_payload)
from repro.core.telemetry import Telemetry

PyTree = Any

# The paper's three placements; kept under the historical name.
InSituMode = Placement


@dataclass
class InSituTask:
    """One in-situ task bound to a payload source (legacy single-fn form).

    ``source``   key into the providers dict the loop passes to on_step();
                 the provider is only called on steps where the task fires.
    ``fn``       host-side work: fn(step, payload) -> result. For HYBRID
                 tasks the payload is the *device-reduced* representation.
    ``every``    fire period in steps (paper: image every 50 / every 10).
    ``shards``   split each firing's payload into N independent sub-items —
                 the paper's internally-parallel in-situ tasks.
    """
    name: str
    source: str
    fn: Callable[[int, Any], Any]
    mode: InSituMode = InSituMode.ASYNC
    every: int = 1
    shards: int = 1

    def fires(self, step: int) -> bool:
        return step % self.every == 0

    def split(self, payload: Any) -> list:
        return split_payload(payload, self.shards)

    def to_pipeline(self) -> PipelineTask:
        """Lower to the runtime's declarative form: the fn is the sink."""
        return PipelineTask(self.name, self.source, sink=self.fn,
                            placement=self.mode, every=self.every,
                            shards=self.shards)


class InSituEngine:
    """Thin shim: owns a PipelineRuntime; the loop calls on_step()/finish()."""

    def __init__(self, tasks: list[InSituTask], *, p_i: int = 2,
                 staging_capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.tasks = list(tasks)
        self.p_i = p_i
        self.runtime = PipelineRuntime(
            [t.to_pipeline() for t in self.tasks], workers=p_i,
            staging_capacity=staging_capacity, telemetry=telemetry)

    # the engine's public state is the runtime's state
    @property
    def telemetry(self) -> Telemetry:
        return self.runtime.telemetry

    @property
    def staging(self):
        return self.runtime.staging

    @property
    def results(self) -> list[TaskResult]:
        return self.runtime.results

    @property
    def errors(self) -> list[tuple[str, int, BaseException]]:
        return self.runtime.errors

    def on_step(self, step: int,
                providers: dict[str, Callable[[], Any]]) -> None:
        """Called once per training step, after the step is dispatched."""
        self.runtime.submit(step, providers)

    def finish(self, timeout: float = 600.0) -> None:
        """Drain the ring and join workers (the paper's non-overlapped tail)."""
        self.runtime.drain(timeout=timeout)

    def report(self) -> dict[str, Any]:
        return self.runtime.report()


def run_workflow(n_steps: int,
                 app_step: Callable[[int], dict[str, Callable[[], Any]]],
                 engine: InSituEngine,
                 block_each_step: bool = True) -> Telemetry:
    """Run ``n_steps`` of the application with the in-situ engine attached."""
    return run_pipeline(n_steps, app_step, engine.runtime)
