"""InSituEngine — deprecation shim over ``repro.core.session``.

Fig. 1 of the paper, mapped to a JAX device loop (see session.py for the
declarative API and runtime.py for the scheduling semantics — SYNC/ASYNC/
HYBRID are policies of one shared worker-pool scheduler):

  SYNC   (Fig. 1a): the loop *blocks*: device->host hand-off, then the task
         runs inline on the loop thread — the GPU stall the paper's NSight
         timelines show.
  ASYNC  (Fig. 1b): the loop blocks only for the hand-off (ADIOS2-send
         analog); p_i pool workers consume the bounded staging ring
         concurrently with subsequent device steps.
  HYBRID (Fig. 1c): a deeply-coupled device stage shrinks the payload; the
         hand-off moves the small residue; host stages run async.

This module keeps the original task-list API (``InSituTask`` with a single
``fn``); each engine is now a thin wrapper around a
:class:`~repro.core.session.Session` built from the equivalent
:class:`~repro.core.session.InSituPlan` — every task source becomes a
stream, every ``every=`` int becomes an ``Every`` trigger. New code should
declare an ``InSituPlan`` and drive a ``Session`` directly
(``repro.insitu``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.runtime import (Placement, PipelineTask, TaskResult,
                                split_payload)
from repro.core.session import Every, InSituPlan, Session, TaskSpec
from repro.core.telemetry import Telemetry

PyTree = Any

# The paper's three placements; kept under the historical name.
InSituMode = Placement


@dataclass
class InSituTask:
    """One in-situ task bound to a payload source (legacy single-fn form).

    ``source``   key into the providers dict the loop passes to on_step();
                 the provider is only called on steps where the task fires.
    ``fn``       host-side work: fn(step, payload) -> result. For HYBRID
                 tasks the payload is the *device-reduced* representation.
    ``every``    fire period in steps (paper: image every 50 / every 10).
    ``shards``   split each firing's payload into N independent sub-items —
                 the paper's internally-parallel in-situ tasks.
    """
    name: str
    source: str
    fn: Callable[[int, Any], Any]
    mode: InSituMode = InSituMode.ASYNC
    every: int = 1
    shards: int = 1

    def fires(self, step: int) -> bool:
        return step % self.every == 0

    def split(self, payload: Any) -> list:
        return split_payload(payload, self.shards)

    def to_spec(self) -> TaskSpec:
        """Lower to the declarative form: source -> stream, fn -> sink."""
        return TaskSpec(name=self.name, stream=self.source,
                        trigger=Every(self.every), placement=self.mode,
                        sink=self.fn, shards=self.shards)

    def to_pipeline(self) -> PipelineTask:
        """Legacy lowering straight to the runtime (kept for callers that
        wire a PipelineRuntime themselves)."""
        return PipelineTask(self.name, self.source, sink=self.fn,
                            placement=self.mode, every=self.every,
                            shards=self.shards)


class InSituEngine:
    """Thin shim: a Session built from the task list; on_step()/finish()."""

    def __init__(self, tasks: list[InSituTask], *, p_i: int = 2,
                 staging_capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.tasks = list(tasks)
        self.p_i = p_i
        streams = list(dict.fromkeys(t.source for t in self.tasks))
        self.session = Session(
            InSituPlan(streams=streams,
                       tasks=[t.to_spec() for t in self.tasks],
                       workers=p_i, staging_capacity=staging_capacity),
            telemetry=telemetry)
        self.session._strict_streams = False   # legacy providers-dict contract
        self.runtime = self.session.runtime

    # the engine's public state is the session's state
    @property
    def telemetry(self) -> Telemetry:
        return self.session.telemetry

    @property
    def staging(self):
        return self.runtime.staging

    @property
    def results(self) -> list[TaskResult]:
        return self.runtime.results

    @property
    def errors(self) -> list[tuple[str, int, BaseException]]:
        return self.runtime.errors

    def on_step(self, step: int,
                providers: dict[str, Callable[[], Any]]) -> None:
        """Called once per training step, after the step is dispatched.

        Providers for sources no task declared are ignored (the legacy
        contract: the loop offers everything, tasks pick)."""
        for source, provider in providers.items():
            self.session.emit(source, step, provider)

    def finish(self, timeout: float = 600.0) -> None:
        """Drain the ring and join workers (the paper's non-overlapped tail)."""
        self.session.finish(timeout=timeout, raise_on_error=False)

    def report(self) -> dict[str, Any]:
        return self.session.report()


def run_workflow(n_steps: int,
                 app_step: Callable[[int], dict[str, Callable[[], Any]]],
                 engine: InSituEngine,
                 block_each_step: bool = True) -> Telemetry:
    """Run ``n_steps`` of the application with the in-situ engine attached.

    Deprecation shim: drives the engine's Session exactly like
    ``Session.run``, keeping the legacy providers-dict contract.
    """
    tm = engine.telemetry
    for step in range(n_steps):
        with tm.span("step/compute", step=step):
            providers = app_step(step)
        engine.on_step(step, providers)
    engine.finish()
    return tm
