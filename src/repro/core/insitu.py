"""InSituEngine — the paper's three in-situ modes as a training-loop runtime.

Fig. 1 of the paper, mapped to a JAX device loop:

  SYNC   (Fig. 1a): the loop *blocks*: device->host hand-off, then the task
         runs inline on the loop thread. The device sits idle meanwhile —
         exactly the GPU stall the paper's NSight timelines show.
  ASYNC  (Fig. 1b): the loop blocks only for the hand-off (ADIOS2-send
         analog), then enqueues the payload on the bounded StagingBuffer;
         p_i dedicated worker threads consume it concurrently with
         subsequent device steps. A slow in-situ side eventually exerts
         backpressure (F3).
  HYBRID (Fig. 1c): a deeply-coupled device stage (the Pallas spectral lossy
         kernel, compiled *into the train step* like NEKO's on-GPU lossy
         pass) shrinks the payload ~50x; the hand-off moves the small
         residue; the lossless stage runs async on the host.

The MPMD resource split p_o + p_i = p_t becomes a host-thread split: the
training loop plus data pipeline hold p_o threads, the engine owns p_i
workers. Host codecs and numpy release the GIL, so the overlap is real
in-process (measured, not assumed — telemetry records every phase).
"""
from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.staging import Closed, StagedItem, StagingBuffer
from repro.core.telemetry import Telemetry

PyTree = Any


class InSituMode(enum.Enum):
    SYNC = "sync"
    ASYNC = "async"
    HYBRID = "hybrid"


@dataclass
class InSituTask:
    """One in-situ task bound to a payload source.

    ``source``   key into the providers dict the loop passes to on_step();
                 the provider is only called on steps where the task fires
                 (lazy: no device_get cost otherwise).
    ``fn``       host-side work: fn(step, payload) -> result. For HYBRID
                 tasks the payload is the *device-reduced* representation.
    ``every``    fire period in steps (paper: image every 50 / every 10).
    ``shards``   split each firing's payload into N independent sub-items
                 (np.array_split on the leading axis) — models the paper's
                 internally-parallel in-situ tasks (image generation over
                 p_i ranks): async shards spread over the workers; sync
                 shards run on a transient pool of p_i threads while the
                 loop blocks (the "GPUs wait for the CPU ranks" case).
    """
    name: str
    source: str
    fn: Callable[[int, Any], Any]
    mode: InSituMode = InSituMode.ASYNC
    every: int = 1
    shards: int = 1

    def fires(self, step: int) -> bool:
        return step % self.every == 0

    def split(self, payload: Any) -> list:
        if self.shards <= 1:
            return [payload]
        if isinstance(payload, np.ndarray):
            return np.array_split(payload, self.shards)
        return [payload]  # non-array payloads: no split


@dataclass
class TaskResult:
    task: str
    step: int
    result: Any
    worker: str
    duration_s: float


class InSituEngine:
    """Owns the staging ring + p_i workers; the loop calls on_step()/finish()."""

    def __init__(self, tasks: list[InSituTask], *, p_i: int = 2,
                 staging_capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.tasks = list(tasks)
        self.p_i = p_i
        self.telemetry = telemetry or Telemetry()
        self.staging = StagingBuffer(staging_capacity, self.telemetry)
        self.results: list[TaskResult] = []
        self.errors: list[tuple[str, int, BaseException]] = []
        self._lock = threading.Lock()
        self._by_name = {t.name: t for t in self.tasks}
        self._workers: list[threading.Thread] = []
        needs_workers = any(t.mode in (InSituMode.ASYNC, InSituMode.HYBRID)
                            for t in self.tasks)
        if needs_workers:
            for i in range(p_i):
                th = threading.Thread(target=self._worker_loop,
                                      name=f"insitu-{i}", daemon=True)
                th.start()
                self._workers.append(th)

    # -- worker side -----------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            try:
                item = self.staging.get()
            except Closed:
                return
            task = self._by_name[item.name]
            t0 = time.perf_counter()
            try:
                with self.telemetry.span(f"insitu-async/{task.name}",
                                         step=item.step):
                    res = task.fn(item.step, item.payload)
                dt = time.perf_counter() - t0
                with self._lock:
                    self.results.append(TaskResult(
                        task.name, item.step, res,
                        threading.current_thread().name, dt))
            except BaseException as e:  # noqa: BLE001 - keep workers alive
                with self._lock:
                    self.errors.append((task.name, item.step, e))

    # -- loop side ---------------------------------------------------------------

    def _handoff(self, step: int, task: InSituTask,
                 providers: dict[str, Callable[[], Any]]) -> Any:
        """Device->host transfer: the only part async mode blocks on."""
        with self.telemetry.span("step/handoff", step=step, task=task.name):
            payload = providers[task.source]()
            payload = jax.tree.map(
                lambda x: np.asarray(x) if hasattr(x, "dtype") else x, payload)
        return payload

    def on_step(self, step: int,
                providers: dict[str, Callable[[], Any]]) -> None:
        """Called once per training step, after the step is dispatched."""
        for task in self.tasks:
            if not task.fires(step) or task.source not in providers:
                continue
            payload = self._handoff(step, task, providers)
            pieces = task.split(payload)
            if task.mode is InSituMode.SYNC:
                t0 = time.perf_counter()
                with self.telemetry.span(f"insitu-sync/{task.name}", step=step):
                    if len(pieces) > 1:
                        # internally-parallel sync task on p_i threads
                        import concurrent.futures as cf
                        with cf.ThreadPoolExecutor(self.p_i) as pool:
                            res = list(pool.map(
                                lambda pc: task.fn(step, pc), pieces))
                    else:
                        res = task.fn(step, pieces[0])
                with self._lock:
                    self.results.append(TaskResult(
                        task.name, step, res,
                        threading.current_thread().name,
                        time.perf_counter() - t0))
            else:  # ASYNC and the host half of HYBRID queue identically
                for pc in pieces:
                    self.staging.put(StagedItem(step, task.name, pc))

    def finish(self, timeout: float = 600.0) -> None:
        """Drain the ring and join workers (the paper's non-overlapped tail)."""
        with self.telemetry.span("insitu/drain"):
            self.staging.close()
            for th in self._workers:
                th.join(timeout=timeout)

    # -- reporting ------------------------------------------------------------------

    def report(self) -> dict[str, Any]:
        rep: dict[str, Any] = dict(self.telemetry.step_overlap_report())
        rep["n_results"] = len(self.results)
        rep["n_errors"] = len(self.errors)
        rep["staging_puts"] = self.staging.puts
        return rep


# ---------------------------------------------------------------------------
# Workflow driver: app loop + engine, used by examples/benchmarks/tests.
# ---------------------------------------------------------------------------

def run_workflow(n_steps: int,
                 app_step: Callable[[int], dict[str, Callable[[], Any]]],
                 engine: InSituEngine,
                 block_each_step: bool = True) -> Telemetry:
    """Run ``n_steps`` of the application with the in-situ engine attached.

    ``app_step(step)`` dispatches one device step and returns the providers
    dict (lazy payload getters). With ``block_each_step`` the loop waits for
    the device result inside a ``step/compute`` span (measurement mode, used
    by benchmarks so device/in-situ attribution is exact).
    """
    tm = engine.telemetry
    for step in range(n_steps):
        with tm.span("step/compute", step=step):
            providers = app_step(step)
        engine.on_step(step, providers)
    engine.finish()
    return tm
