"""Runtime event log: per-step / per-task wall-clock spans.

The paper's methodology is *measurement*: every figure is an execution-time
comparison between in-situ modes (plus NSight/HPC-monitor evidence that the
accelerator does or does not stall). This module is the framework's analog of
that instrumentation layer — a lightweight, thread-safe span recorder that the
training loop, the staging buffer, and the in-situ workers all write into; the
benchmarks then aggregate the spans exactly the way the paper's figures do
(total time, app time, in-situ time, hand-off time).

Spans are (name, t0, t1, thread, step, meta). Recording is contention-free:
each thread appends to its own buffer (registered once, lock-free afterwards)
and readers merge the buffers — a worker's ``span()`` in the hot loop never
serializes on a global lock against the training thread.

Aggregation is by name prefix:
  step/compute          device step (dispatch->blocked-on-result)
  handoff/dispatch      D2H copy dispatch the loop blocks on (the "send")
  handoff/materialize   transfer drain on the consumer side (overlapped)
  step/handoff          loop-blocking materialization (SYNC / non-pipelined)
  insitu-sync/<task>    inline (blocking) task execution
  insitu-async/<task>   worker-side task execution (overlapped)
  staging/wait          producer blocked on a full ring (backpressure)
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional


@dataclass
class Span:
    name: str
    t0: float
    t1: float
    thread: str
    step: int = -1
    meta: dict = field(default_factory=dict)

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Telemetry:
    """Thread-safe span log. One instance per run (engine/loop share it).

    Writers are lock-free: the first record from a thread registers a
    per-thread buffer (one lock acquisition); every later append is a plain
    ``list.append`` — atomic under the GIL, invisible to other threads'
    hot paths. Readers snapshot and merge all buffers.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()       # buffer registry + counters only
        self._buffers: list[list[Span]] = []
        self._tls = threading.local()
        self._counters: dict[str, float] = defaultdict(float)

    # -- recording -----------------------------------------------------------

    def _buf(self) -> list:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = []
            self._tls.buf = buf
            with self._lock:
                self._buffers.append(buf)
        return buf

    @contextlib.contextmanager
    def span(self, name: str, step: int = -1, **meta: Any) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self._buf().append(
                Span(name, t0, t1, threading.current_thread().name, step,
                     dict(meta)))

    def record(self, name: str, t0: float, t1: float, step: int = -1,
               **meta: Any) -> None:
        self._buf().append(
            Span(name, t0, t1, threading.current_thread().name, step,
                 dict(meta)))

    def count(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += value

    # -- aggregation ---------------------------------------------------------

    def _merged(self) -> list[Span]:
        """All spans, unordered (aggregations that need t0 order sort the
        — usually much smaller — filtered subset themselves)."""
        with self._lock:
            buffers = list(self._buffers)
        out: list[Span] = []
        for buf in buffers:
            out.extend(buf)
        return out

    def spans(self, prefix: str = "") -> list[Span]:
        return sorted((s for s in self._merged()
                       if s.name.startswith(prefix)),
                      key=lambda s: s.t0)

    def total(self, prefix: str) -> float:
        return sum(s.dt for s in self._merged()
                   if s.name.startswith(prefix))

    def counters(self) -> dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def wall(self, prefix: str = "") -> float:
        """Wall-clock extent (union is approximated by max-end minus min-start)."""
        ss = self.spans(prefix)
        if not ss:
            return 0.0
        return max(s.t1 for s in ss) - min(s.t0 for s in ss)

    def busy(self, prefix: str = "") -> float:
        """Union of span intervals (true busy time across threads)."""
        ss = self.spans(prefix)          # merged spans arrive t0-sorted
        if not ss:
            return 0.0
        total = 0.0
        cur0, cur1 = ss[0].t0, ss[0].t1
        for s in ss[1:]:
            if s.t0 > cur1:
                total += cur1 - cur0
                cur0, cur1 = s.t0, s.t1
            else:
                cur1 = max(cur1, s.t1)
        return total + (cur1 - cur0)

    def summary(self) -> dict[str, dict[str, float]]:
        out: dict[str, dict[str, float]] = {}
        by_name: dict[str, list[Span]] = defaultdict(list)
        for s in self._merged():
            by_name[s.name].append(s)
        for name, ss in sorted(by_name.items()):
            dts = [s.dt for s in ss]
            out[name] = {
                "n": float(len(dts)),
                "total_s": sum(dts),
                "mean_s": sum(dts) / len(dts),
                "max_s": max(dts),
            }
        return out

    def step_overlap_report(self) -> dict[str, float]:
        """The paper's NSight question: did the device stall for in-situ work?

        ``handoff_s`` is the *critical-path* hand-off: copy dispatch plus any
        loop-blocking materialization (SYNC / non-pipelined / sharded). The
        overlapped drain is reported separately as ``handoff_materialize_s``.
        For an ideal pipelined async run the stall term is ~0 and only the
        dispatch remains on the critical path.
        """
        prefixes = {
            "step_compute_s": "step/compute",
            "handoff_dispatch_s": "handoff/dispatch",
            "handoff_materialize_s": "handoff/materialize",
            "_blocking": "step/handoff",
            "sync_stall_s": "insitu-sync/",
            "async_overlapped_s": "insitu-async/",
            "staging_backpressure_s": "staging/wait",
        }
        totals = dict.fromkeys(prefixes, 0.0)
        for s in self._merged():          # one merge for all seven prefixes
            for key, prefix in prefixes.items():
                if s.name.startswith(prefix):
                    totals[key] += s.dt
        totals["handoff_s"] = totals["handoff_dispatch_s"] \
            + totals.pop("_blocking")
        return totals

    def reset(self) -> None:
        with self._lock:
            for buf in self._buffers:
                buf.clear()
            self._counters.clear()


# A module-level default so simple call-sites don't need plumbing; the engine
# and benchmarks construct their own instances for isolation.
default = Telemetry()
