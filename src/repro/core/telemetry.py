"""Runtime event log: per-step / per-task wall-clock spans.

The paper's methodology is *measurement*: every figure is an execution-time
comparison between in-situ modes (plus NSight/HPC-monitor evidence that the
accelerator does or does not stall). This module is the framework's analog of
that instrumentation layer — a lightweight, thread-safe span recorder that the
training loop, the staging buffer, and the in-situ workers all write into; the
benchmarks then aggregate the spans exactly the way the paper's figures do
(total time, app time, in-situ time, hand-off time).

Spans are (name, t0, t1, thread, step, meta). Aggregation is by name prefix:
  step/compute        device step (dispatch->blocked-on-result)
  step/handoff        device->host transfer the app blocks on (ADIOS2 send)
  insitu/<task>/sync  inline (blocking) task execution
  insitu/<task>/async worker-side task execution (overlapped)
  staging/wait        producer blocked on a full ring (backpressure)
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional


@dataclass
class Span:
    name: str
    t0: float
    t1: float
    thread: str
    step: int = -1
    meta: dict = field(default_factory=dict)

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


class Telemetry:
    """Thread-safe span log. One instance per run (engine/loop share it)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._counters: dict[str, float] = defaultdict(float)

    # -- recording -----------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, step: int = -1, **meta: Any) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            with self._lock:
                self._spans.append(
                    Span(name, t0, t1, threading.current_thread().name, step,
                         dict(meta)))

    def record(self, name: str, t0: float, t1: float, step: int = -1,
               **meta: Any) -> None:
        with self._lock:
            self._spans.append(
                Span(name, t0, t1, threading.current_thread().name, step,
                     dict(meta)))

    def count(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += value

    # -- aggregation ---------------------------------------------------------

    def spans(self, prefix: str = "") -> list[Span]:
        with self._lock:
            return [s for s in self._spans if s.name.startswith(prefix)]

    def total(self, prefix: str) -> float:
        return sum(s.dt for s in self.spans(prefix))

    def counters(self) -> dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def wall(self, prefix: str = "") -> float:
        """Wall-clock extent (union is approximated by max-end minus min-start)."""
        ss = self.spans(prefix)
        if not ss:
            return 0.0
        return max(s.t1 for s in ss) - min(s.t0 for s in ss)

    def busy(self, prefix: str = "") -> float:
        """Union of span intervals (true busy time across threads)."""
        ss = sorted(self.spans(prefix), key=lambda s: s.t0)
        if not ss:
            return 0.0
        total = 0.0
        cur0, cur1 = ss[0].t0, ss[0].t1
        for s in ss[1:]:
            if s.t0 > cur1:
                total += cur1 - cur0
                cur0, cur1 = s.t0, s.t1
            else:
                cur1 = max(cur1, s.t1)
        return total + (cur1 - cur0)

    def summary(self) -> dict[str, dict[str, float]]:
        out: dict[str, dict[str, float]] = {}
        with self._lock:
            by_name: dict[str, list[Span]] = defaultdict(list)
            for s in self._spans:
                by_name[s.name].append(s)
        for name, ss in sorted(by_name.items()):
            dts = [s.dt for s in ss]
            out[name] = {
                "n": float(len(dts)),
                "total_s": sum(dts),
                "mean_s": sum(dts) / len(dts),
                "max_s": max(dts),
            }
        return out

    def step_overlap_report(self) -> dict[str, float]:
        """The paper's NSight question: did the device stall for in-situ work?

        Returns total app-step time, sync in-situ (stall) time, async in-situ
        (overlapped) time, and hand-off time. For an ideal async run the stall
        term is ~0 and only the hand-off remains on the critical path.
        """
        return {
            "step_compute_s": self.total("step/compute"),
            "handoff_s": self.total("step/handoff"),
            "sync_stall_s": self.total("insitu-sync/"),
            "async_overlapped_s": self.total("insitu-async/"),
            "staging_backpressure_s": self.total("staging/wait"),
        }

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
            self._counters.clear()


# A module-level default so simple call-sites don't need plumbing; the engine
# and benchmarks construct their own instances for isolation.
default = Telemetry()
