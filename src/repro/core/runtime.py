"""The pluggable in-situ pipeline runtime.

Every in-situ consumer in the tree — training analytics, serving snapshots,
checkpointing — is one declarative task

    DeviceStage? -> Handoff -> [HostStage ...] -> Sink

scheduled by a single shared worker-pool scheduler that owns the staging
ring. The paper's three placements (Fig. 1) are *scheduling policies* of
that one scheduler, not separate code paths:

  SYNC   : the whole chain runs while the loop blocks (Fig. 1a). A
           non-sharded firing executes inline on the loop thread; an
           internally-parallel firing (``shards > 1``) fans its shards out
           on the shared pool and the loop waits on a latch — no transient
           executors are ever constructed.
  ASYNC  : the loop blocks only for DeviceStage + hand-off *dispatch*; the
           transfer drains, and host stages plus the sink run, on the pool,
           fed through the bounded staging ring (Fig. 1b, the ADIOS2-send
           analog).
  HYBRID : ASYNC scheduling for a task that declares a DeviceStage — the
           deeply-coupled device kernel (Pallas spectral lossy) shrinks the
           payload before the hand-off, so the D2H transfer ships the small
           residue (Fig. 1c, the NEKO pattern).

The hand-off is two-phase ("blocks only for the send", Fig. 1b):

  dispatch     (loop thread, ``handoff/dispatch``): snapshot jax leaves
               with a device-side copy (donation-proofing — see
               ``PipelineTask.snapshot``), start the D2H copy per leaf via
               ``copy_to_host_async``, and enqueue a ``PendingHandoff``
               token. This is the only hand-off cost the loop pays for a
               pipelined ASYNC/HYBRID task.
  materialize  (consumer thread, ``handoff/materialize``): the task's
               ``handoff`` function turns the token's payload into host
               numpy — overlapped with the next device steps; the bounded
               staging ring double-buffers in-flight transfers.

SYNC tasks (and tasks with ``pipelined=False``, the pre-pipelined blocking
behaviour) run both phases inline under the legacy ``step/handoff`` span, so
the loop-blocking hand-off cost keeps its historical name. Sharded firings
also materialize on the loop (a token cannot be split); that stall is
likewise recorded as ``step/handoff``.

Backpressure on a full ring is a per-task policy:

  block : wait for a slot; the stall is recorded as ``staging/wait`` —
          the paper's F3 regime, and the default.
  drop  : shed the firing and count it (``runtime.drops``; telemetry
          counter ``staging/drop/<task>``) — for best-effort telemetry
          tasks that must never stall the loop.
  adapt : deliver, but under sustained pressure double the task's
          *effective* firing period (capped) — the F3 mitigation: fire
          less often when the in-situ side outgrows its resources.

A task with ``budget_s`` set additionally widens on *wall clock*: when the
loop-blocking in-situ cost of a firing (hand-off dispatch + any
loop-blocking materialization + sync chain time — exactly what the
telemetry spans charge to the critical path) exceeds the budget for
``adapt_after`` consecutive firings, the effective period doubles (capped
at ``adapt_max_every``). This is the straggler policy's lever: a contended
host sheds in-situ load before the application slows down.

Sink IO is failure-aware: a sink (or an injected fault hook — see
``inject_sink_fault``) raising :class:`TransientError` is retried with
capped exponential backoff (``retries`` / ``retry_backoff_s``); exhausted
retries put the task into a *degraded* state — the firing is dropped,
later firings are shed and counted (``runtime.degraded``), and the failure
is reported rather than raised, so a flaky sink can never crash the
training loop. Any other exception is permanent and still lands in
``runtime.errors`` (surfaced by ``Session.finish(raise_on_error=True)``).

Telemetry: every firing records per-placement spans under the same names
the pre-runtime engine used (``step/compute``, ``insitu-sync/<task>``,
``insitu-async/<task>``, ``insitu-device/<task>``, ``staging/wait``) plus
the hand-off split (``handoff/dispatch``, ``handoff/materialize``,
``step/handoff`` for loop-blocking transfers), so
``Telemetry.step_overlap_report`` and every benchmark figure read
identically; host stages additionally get ``stage/<task>/<stage>`` spans
for per-stage attribution, and a ``FanoutStage``'s stolen work items get
``stage/<task>/<stage>/item`` spans on whichever worker ran them.
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.core import transport
from repro.core.staging import (Closed, PendingHandoff, StagedItem,
                                StagingBuffer)
from repro.core.telemetry import Telemetry

PyTree = Any

BACKPRESSURE_POLICIES = ("block", "drop", "adapt")

_BACKOFF_CAP_S = 2.0          # ceiling for the exponential sink-retry backoff

# sentinel a degraded sink firing resolves to (never a caller-visible result)
_DEGRADED = object()


class TransientError(RuntimeError):
    """A sink failure expected to clear on retry (flaky IO, a briefly
    unreachable store, an injected fault). The runtime retries these with
    capped exponential backoff; anything else is permanent and goes to
    ``runtime.errors`` untouched."""


class Placement(enum.Enum):
    SYNC = "sync"
    ASYNC = "async"
    HYBRID = "hybrid"


@dataclass(frozen=True)
class Stage:
    """One named host stage: ``fn(step, payload) -> payload``."""
    name: str
    fn: Callable[[int, Any], Any]


@dataclass(frozen=True)
class FanoutStage:
    """A host stage whose work items fan out across the shared worker pool.

    ``split(step, payload)`` breaks the firing into independent work items
    (e.g. one per checkpoint leaf); ``fn(step, item)`` processes one item;
    ``gather(step, payload, results)`` merges the per-item results (ordered
    as split produced them) behind a barrier before the next stage / sink.

    Scheduling is help-first work stealing: the thread running the chain
    enqueues best-effort *steal tokens* on the staging ring and then drains
    the item queue itself; idle pool workers that pop a token pull items
    from the same queue concurrently. This is deadlock-free by construction
    — no thread ever blocks on ring capacity for fan-out work, and the
    barrier only waits on items another thread is actively executing — so it
    is safe at any pool size (a lone worker simply runs the items serially).
    """
    name: str
    split: Callable[[int, Any], Sequence]
    fn: Callable[[int, Any], Any]
    gather: Callable[[int, Any, list], Any]


class _CompletionLatch:
    """N-slot completion latch shared by sharded SYNC and fan-out firings."""

    def __init__(self, n: int) -> None:
        self.results: list = [None] * n
        self.errors: list[BaseException] = []
        self._remaining = n
        self._done = threading.Event()
        self._lock = threading.Lock()

    def complete(self, idx: int, result: Any,
                 error: Optional[BaseException] = None) -> None:
        with self._lock:
            if error is not None:
                self.errors.append(error)
            else:
                self.results[idx] = result
            self._remaining -= 1
            if self._remaining == 0:
                self._done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)


class _FanoutGroup(_CompletionLatch):
    """Shared work queue + completion latch for one fanned-out stage firing."""

    def __init__(self, step: int, task_name: str, stage: FanoutStage,
                 items: Sequence) -> None:
        super().__init__(len(items))
        self.step = step
        self.task_name = task_name
        self.stage = stage
        self._queue: deque = deque(enumerate(items))

    def take(self) -> Optional[tuple[int, Any]]:
        with self._lock:
            return self._queue.popleft() if self._queue else None


def _to_host(x: Any) -> Any:
    return np.asarray(x) if hasattr(x, "dtype") else x


def _start_d2h(payload: Any, snapshot: bool = False) -> Any:
    """Dispatch phase: start the device->host copy of every array leaf.

    ``copy_to_host_async`` returns immediately (the DMA engine moves the
    bytes while the loop keeps stepping); leaves without it (numpy, scalars)
    are already host-resident.

    ``snapshot`` detaches jax leaves from the caller's buffers with a
    device-side copy first. Required whenever materialization is deferred
    past the next step and the app's jitted step *donates* its inputs
    (``jit_train_step`` defaults ``donate=True``): donation deletes the
    original buffers at the next dispatch, and a pending token holding them
    would materialize into "Array has been deleted". The copy is enqueued
    like any other device op (async on accelerators), so the dispatch stays
    off the critical path.
    """
    def start(x: Any) -> Any:
        if hasattr(x, "copy_to_host_async"):
            if snapshot and hasattr(x, "is_deleted"):
                x = jax.numpy.copy(x)      # token-owned, donation-proof
            x.copy_to_host_async()
        return x

    return jax.tree.map(start, payload)


def default_handoff(payload: Any) -> Any:
    """Materialize phase: every array leaf becomes host numpy."""
    return jax.tree.map(_to_host, payload)


def split_payload(payload: Any, shards: int) -> list:
    """Shard a firing's payload on the leading axis.

    A bare ndarray splits directly; a pytree (dict/tuple/list) splits every
    array leaf on its leading axis, producing ``shards`` trees of the same
    structure. Payloads whose leaves cannot be sharded (scalars, 0-d arrays)
    raise — silently running one shard would miscount the parallelism the
    caller asked for.
    """
    if shards <= 1:
        return [payload]
    if isinstance(payload, np.ndarray):
        if payload.ndim < 1:
            raise ValueError("cannot shard a 0-d array payload")
        if payload.shape[0] < shards:
            raise ValueError(
                f"cannot shard leading axis of {payload.shape[0]} into "
                f"{shards} non-empty pieces")
        return np.array_split(payload, shards)
    leaves, treedef = jax.tree_util.tree_flatten(payload)
    if not leaves:
        raise ValueError(
            f"cannot shard an empty payload of type {type(payload).__name__}")
    split_leaves = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.ndim < 1:
            raise ValueError(
                f"cannot shard payload: leaf of type {type(leaf).__name__} "
                "has no leading axis")
        if arr.shape[0] < shards:
            raise ValueError(
                f"cannot shard leaf with leading axis {arr.shape[0]} into "
                f"{shards} non-empty pieces")
        split_leaves.append(np.array_split(arr, shards))
    return [jax.tree_util.tree_unflatten(treedef, [sl[i] for sl in split_leaves])
            for i in range(shards)]


@dataclass
class PipelineTask:
    """Declarative pipeline: ``DeviceStage? -> Handoff -> [HostStage...] -> Sink``.

    ``source``        key into the providers dict passed to ``submit()``; the
                      provider is only called on steps where the task fires.
    ``sink``          terminal consumer: a :class:`repro.core.transport.Sink`
                      (``write(step, payload) -> result``) or a legacy
                      ``sink(step, payload)`` callable — ``register``
                      normalizes callables through the ``CallableSink``
                      shim. The result lands in ``runtime.results``.
    ``host_stages``   ordered ``Stage`` chain run before the sink (same
                      thread as the sink, per the placement).
    ``device_stage``  optional ``fn(step, payload) -> payload`` run *before*
                      the hand-off (the hybrid device kernel).
    ``handoff``       the hand-off's *materialize* phase; override when the
                      transfer needs task-specific framing (e.g. checkpoint
                      serialization's bf16 bookkeeping). For a pipelined
                      ASYNC/HYBRID task it runs on the consumer thread.
    ``pipelined``     two-phase hand-off (default): the loop only dispatches
                      the D2H copies; materialization overlaps on the pool.
                      ``False`` restores the blocking hand-off (the loop
                      materializes inline — the pre-pipelined behaviour,
                      kept for benchmark baselines and host-driven sources).
    ``snapshot``      device-side copy of jax leaves at dispatch (default):
                      makes the deferred token immune to buffer *donation*
                      by the app's next jitted step. Disable only when the
                      producer guarantees buffer lifetime (no donation) and
                      wants to skip the copy.
    ``shards``        split each firing into N independent sub-items
                      (models the paper's internally-parallel in-situ tasks).
    ``backpressure``  ring-full policy: 'block' | 'drop' | 'adapt'.
    ``budget_s``      wall-clock widening: when a firing's loop-blocking
                      in-situ cost exceeds this budget ``adapt_after``
                      times in a row, the effective period doubles
                      (capped at ``adapt_max_every``).
    ``retries``       attempts re-run after a :class:`TransientError` from
                      the sink before the task degrades (drops firings
                      instead of raising).
    ``retry_backoff_s``  first retry delay; doubles per attempt, capped.
    """
    name: str
    source: str
    sink: Callable[[int, Any], Any]
    host_stages: Sequence[Stage] = ()
    device_stage: Optional[Callable[[int, Any], Any]] = None
    handoff: Callable[[Any], Any] = default_handoff
    pipelined: bool = True
    snapshot: bool = True
    placement: Placement = Placement.ASYNC
    every: int = 1
    shards: int = 1
    backpressure: str = "block"
    adapt_after: int = 2        # consecutive full-ring firings before adapting
    adapt_max_every: int = 64   # cap for the adapted firing period
    budget_s: Optional[float] = None
    retries: int = 3
    retry_backoff_s: float = 0.05

    def __post_init__(self) -> None:
        if self.backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"backpressure must be one of {BACKPRESSURE_POLICIES}, "
                f"got {self.backpressure!r}")
        if self.every < 1:
            raise ValueError("every must be >= 1")
        if self.budget_s is not None and self.budget_s <= 0:
            raise ValueError(f"budget_s must be > 0, got {self.budget_s}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}")


@dataclass
class TaskResult:
    task: str
    step: int
    result: Any
    worker: str
    duration_s: float


class _SyncGroup(_CompletionLatch):
    """Completion latch for a sharded SYNC firing executed on the pool."""


class PipelineRuntime:
    """The single scheduler: staging ring + shared ``workers`` pool.

    Tasks are registered (``register``) and fired (``submit``); the runtime
    owns placement, backpressure, telemetry spans, and the drain protocol.
    """

    def __init__(self, tasks: Sequence[PipelineTask] = (), *,
                 workers: int = 2, staging_capacity: int = 4,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.workers = workers
        self.telemetry = telemetry or Telemetry()
        self.staging = StagingBuffer(staging_capacity, self.telemetry)
        self.results: list[TaskResult] = []
        self.errors: list[tuple[str, int, BaseException]] = []
        self.drops: dict[str, int] = {}
        self.degraded: dict[str, dict] = {}       # task -> degradation info
        self.retry_counts: dict[str, int] = {}
        self._sink_faults: dict[str, Callable[[int], Any]] = {}
        self._sleep = time.sleep                  # injectable for tests
        self._tasks: dict[str, PipelineTask] = {}
        self._every: dict[str, int] = {}
        self._pressure: dict[str, int] = {}
        self._budget_over: dict[str, int] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queued = 0       # async items enqueued on the ring
        self._finished = 0     # async items completed (result or error)
        self._threads: list[threading.Thread] = []
        for t in tasks:
            self.register(t)

    # -- registration ---------------------------------------------------------

    def register(self, task: PipelineTask) -> PipelineTask:
        """Add a pipeline to the schedule; new workloads start here."""
        if task.name in self._tasks:
            raise ValueError(f"task {task.name!r} already registered")
        # one terminal protocol for every task: callables wear the
        # CallableSink shim, transport sinks pass through untouched
        task.sink = transport.as_sink(task.sink)
        self._tasks[task.name] = task
        self._every[task.name] = int(task.every)
        self._pressure[task.name] = 0
        self._budget_over[task.name] = 0
        self.drops[task.name] = 0
        self.retry_counts[task.name] = 0
        if (task.placement is not Placement.SYNC or task.shards > 1
                or any(isinstance(s, FanoutStage) for s in task.host_stages)):
            self._ensure_pool()
        return task

    @property
    def tasks(self) -> list[PipelineTask]:
        return list(self._tasks.values())

    def effective_every(self, name: str) -> int:
        """Current firing period (grows under the 'adapt' policy)."""
        return self._every[name]

    def widen_every(self, name: str, max_every: Optional[int] = None) -> bool:
        """Double a task's effective firing period (capped); False at cap.

        The shared lever behind the 'adapt' backpressure policy, the
        ``budget_s`` wall-clock trigger, and the straggler mitigation's
        shed-in-situ-load step (``Session.shed_insitu``).
        """
        task = self._tasks[name]
        cap = task.adapt_max_every if max_every is None else int(max_every)
        new = min(self._every[name] * 2, cap)
        if new == self._every[name]:
            return False
        self._every[name] = new
        return True

    def set_every(self, name: str, every: int) -> None:
        """Set a task's effective firing period directly — the steering
        channel's lever (a consumer retunes cadence mid-run); also resets
        the adapt/budget pressure counters so the new cadence gets a fair
        start."""
        if name not in self._tasks:
            raise ValueError(f"unknown task {name!r}")
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self._every[name] = int(every)
        self._pressure[name] = 0
        self._budget_over[name] = 0

    def inject_sink_fault(self, name: str,
                          fault: Optional[Callable[[int], Any]] = None) -> None:
        """Install (or clear, with ``fault=None``) a fault hook in front of a
        task's sink. ``fault(step)`` runs before every sink attempt —
        including retries — and raises to simulate the failure
        (:class:`TransientError` exercises the retry/degrade path, anything
        else the permanent-error path)."""
        if name not in self._tasks:
            raise ValueError(f"unknown task {name!r}")
        if fault is None:
            self._sink_faults.pop(name, None)
        else:
            self._sink_faults[name] = fault

    def _ensure_pool(self) -> None:
        while len(self._threads) < self.workers:
            th = threading.Thread(target=self._worker_loop,
                                  name=f"insitu-{len(self._threads)}",
                                  daemon=True)
            th.start()
            self._threads.append(th)

    # -- worker side ----------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            try:
                item = self.staging.get()
            except Closed:
                return
            if isinstance(item.group, _FanoutGroup):
                # steal token: pull items off the group's queue until dry
                # (a token popped after the group finished is a no-op)
                self._drain_fanout(item.group)
                continue
            task = self._tasks[item.name]
            if item.group is not None:
                self._run_sync_shard(task, item)
            else:
                self._run_async_item(task, item)

    def _resolve_payload(self, task: PipelineTask, item: StagedItem) -> Any:
        """Consumer-side phase 2: drain a pending transfer, if any."""
        payload = item.payload
        if isinstance(payload, PendingHandoff):
            with self.telemetry.span("handoff/materialize", step=item.step,
                                     task=task.name):
                payload = payload.materialize()
        return payload

    def _run_chain(self, task: PipelineTask, step: int, payload: Any) -> Any:
        for stage in task.host_stages:
            with self.telemetry.span(f"stage/{task.name}/{stage.name}",
                                     step=step):
                if isinstance(stage, FanoutStage):
                    payload = self._run_fanout_stage(task, stage, step,
                                                     payload)
                else:
                    payload = stage.fn(step, payload)
        return self._call_sink(task, step, payload)

    def _call_sink(self, task: PipelineTask, step: int, payload: Any) -> Any:
        """Sink IO with transient-failure retry and graceful degradation.

        :class:`TransientError` (from the sink or an injected fault hook)
        retries with capped exponential backoff; exhausting ``task.retries``
        degrades the task — the sentinel result is swallowed by every
        caller, the failure is recorded in ``self.degraded`` with step
        context, and later firings are shed in ``_fire``. Other exceptions
        propagate (permanent failures keep their existing error path).
        """
        attempt = 0
        while True:
            try:
                fault = self._sink_faults.get(task.name)
                if fault is not None:
                    fault(step)
                return task.sink.write(step, payload)
            except TransientError as e:
                attempt += 1
                if attempt > task.retries:
                    with self._lock:
                        # an already-degraded task keeps its first record
                        # (a racing in-flight firing must not reset the
                        # dropped counter)
                        self.degraded.setdefault(task.name, {
                            "step": step, "dropped": 0,
                            "retries": task.retries,
                            "error": f"{type(e).__name__}: {e}"})
                    self.telemetry.count(f"sink/degraded/{task.name}")
                    return _DEGRADED
                with self._lock:
                    self.retry_counts[task.name] += 1
                self.telemetry.count(f"sink/retry/{task.name}")
                self._sleep(min(task.retry_backoff_s * (2 ** (attempt - 1)),
                                _BACKOFF_CAP_S))

    def _drain_fanout(self, group: _FanoutGroup) -> None:
        """Run fan-out items until the group's queue is empty."""
        while (job := group.take()) is not None:
            idx, item = job
            try:
                with self.telemetry.span(
                        f"stage/{group.task_name}/{group.stage.name}/item",
                        step=group.step):
                    res = group.stage.fn(group.step, item)
            except BaseException as e:  # noqa: BLE001 - latch must fire
                group.complete(idx, None, e)
            else:
                group.complete(idx, res)

    def _run_fanout_stage(self, task: PipelineTask, stage: FanoutStage,
                          step: int, payload: Any) -> Any:
        items = list(stage.split(step, payload))
        if not items:
            return stage.gather(step, payload, [])
        group = _FanoutGroup(step, task.name, stage, items)
        if self._threads and len(items) > 1:
            # advertise steal tokens (best-effort: a full/closed ring just
            # means the coordinator keeps more of the work). Tokens bypass
            # the queued/finished accounting — they are hints, not items —
            # and are capped below the ring's free capacity: a hint must
            # never occupy the last free slot, or a busy pool would let
            # lingering tokens distort other tasks' backpressure (shed
            # 'drop' firings, stall 'block' producers, inflate 'adapt'
            # pressure) on a shared runtime.
            free = self.staging.capacity - len(self.staging)
            n_tokens = min(len(items) - 1, self.workers, free - 1)
            try:
                for _ in range(n_tokens):
                    if not self.staging.try_put(
                            StagedItem(step, task.name, None, group=group)):
                        break
            except Closed:
                pass
        self._drain_fanout(group)    # help-first: this thread works too
        group.wait()                 # gather barrier for stolen items
        if group.errors:
            raise group.errors[0]
        return stage.gather(step, payload, group.results)

    def _run_async_item(self, task: PipelineTask, item: StagedItem) -> None:
        t0 = time.perf_counter()
        try:
            payload = self._resolve_payload(task, item)
            with self.telemetry.span(f"insitu-async/{task.name}",
                                     step=item.step):
                res = self._run_chain(task, item.step, payload)
            with self._cv:
                if res is not _DEGRADED:
                    self.results.append(TaskResult(
                        task.name, item.step, res,
                        threading.current_thread().name,
                        time.perf_counter() - t0))
                self._finished += 1
                self._cv.notify_all()
        except BaseException as e:  # noqa: BLE001 - keep workers alive
            with self._cv:
                self.errors.append((task.name, item.step, e))
                self._finished += 1
                self._cv.notify_all()

    def _run_sync_shard(self, task: PipelineTask, item: StagedItem) -> None:
        try:
            payload = self._resolve_payload(task, item)
            res = self._run_chain(task, item.step, payload)
        except BaseException as e:  # noqa: BLE001 - latch must always fire
            item.group.complete(item.shard, None, e)
        else:
            item.group.complete(item.shard,
                                None if res is _DEGRADED else res)

    # -- loop side ------------------------------------------------------------

    def submit(self, step: int,
               providers: dict[str, Callable[[], Any]]) -> None:
        """Fire every registered task due at ``step`` with a provider."""
        for task in self._tasks.values():
            if step % self._every[task.name]:
                continue
            if task.source not in providers:
                continue
            self._fire(step, task, providers[task.source])

    def _fire(self, step: int, task: PipelineTask,
              provider: Callable[[], Any]) -> None:
        if task.name in self.degraded:
            # graceful degradation: an exhausted sink sheds firings instead
            # of crashing the loop; the dropped count is reported
            with self._lock:
                self.degraded[task.name]["dropped"] += 1
            self.telemetry.count(f"sink/degraded_drop/{task.name}")
            return
        pipelined = (task.pipelined and task.placement is not Placement.SYNC
                     and task.shards == 1)
        if (pipelined and task.backpressure == "drop"
                and len(self.staging) >= self.staging.capacity):
            # pre-flight shed: a drop task must never cost the loop, so
            # don't pay the provider, device stage, snapshot copy, or D2H
            # dispatch for a firing the full ring would discard anyway
            # (best-effort check — a race just falls through to try_put's
            # authoritative one).
            with self._lock:
                self.drops[task.name] += 1
            self.telemetry.count(f"staging/drop/{task.name}")
            return
        payload = provider()
        if task.device_stage is not None:
            with self.telemetry.span(f"insitu-device/{task.name}", step=step):
                payload = task.device_stage(step, payload)
        if pipelined:
            # two-phase: the loop pays only the copy dispatch; the consumer
            # materializes (handoff/materialize) off the critical path.
            t0 = time.perf_counter()
            with self.telemetry.span("handoff/dispatch", step=step,
                                     task=task.name):
                pending = PendingHandoff(
                    _start_d2h(payload, snapshot=task.snapshot), task.handoff)
            self._note_budget(task, time.perf_counter() - t0)
            self._enqueue(step, task, [pending])
            return
        # blocking hand-off: SYNC placement, non-pipelined tasks, and sharded
        # firings (a pending token cannot be split) materialize on the loop.
        t0 = time.perf_counter()
        with self.telemetry.span("step/handoff", step=step, task=task.name):
            payload = task.handoff(_start_d2h(payload))
        pieces = split_payload(payload, task.shards)
        if task.placement is Placement.SYNC:
            self._run_sync(step, task, pieces)
        else:
            self._enqueue(step, task, pieces)
        self._note_budget(task, time.perf_counter() - t0)

    def _note_budget(self, task: PipelineTask, cost_s: float) -> None:
        """Wall-clock Adaptive: widen the firing cadence when the
        loop-blocking cost of a firing (copy dispatch, blocking hand-off,
        sync in-situ work) stays over ``task.budget_s`` for ``adapt_after``
        consecutive firings."""
        if task.budget_s is None:
            return
        name = task.name
        if cost_s <= task.budget_s:
            self._budget_over[name] = 0
            return
        self._budget_over[name] += 1
        if self._budget_over[name] >= task.adapt_after:
            self._budget_over[name] = 0
            if self.widen_every(name):
                self.telemetry.count(f"budget/adapt/{name}")

    def _run_sync(self, step: int, task: PipelineTask, pieces: list) -> None:
        t0 = time.perf_counter()
        with self.telemetry.span(f"insitu-sync/{task.name}", step=step):
            if len(pieces) > 1:
                # internally-parallel sync firing: shards ride the shared
                # pool; the loop blocks on the latch (the "GPUs wait for
                # the CPU ranks" case) — no per-firing executor.
                group = _SyncGroup(len(pieces))
                for i, pc in enumerate(pieces):
                    self.staging.put(StagedItem(step, task.name, pc,
                                                group=group, shard=i))
                group.wait()
                if group.errors:
                    raise group.errors[0]
                res = group.results
            else:
                res = self._run_chain(task, step, pieces[0])
        if res is _DEGRADED:
            return
        with self._lock:
            self.results.append(TaskResult(
                task.name, step, res, threading.current_thread().name,
                time.perf_counter() - t0))

    def _enqueue(self, step: int, task: PipelineTask, pieces: list) -> None:
        for pc in pieces:
            item = StagedItem(step, task.name, pc)
            if task.backpressure == "block":
                self.staging.put(item)
                self._note_queued()
            elif task.backpressure == "drop":
                if self.staging.try_put(item):
                    self._note_queued()
                else:
                    with self._lock:
                        self.drops[task.name] += 1
                    self.telemetry.count(f"staging/drop/{task.name}")
            else:  # adapt
                if self.staging.try_put(item):
                    self._note_queued()
                    self._pressure[task.name] = 0
                else:
                    self._pressure[task.name] += 1
                    if self._pressure[task.name] >= task.adapt_after:
                        self._pressure[task.name] = 0
                        if self.widen_every(task.name):
                            self.telemetry.count(
                                f"backpressure/adapt/{task.name}")
                    self.staging.put(item)   # still deliver this firing
                    self._note_queued()

    def _note_queued(self) -> None:
        with self._cv:
            self._queued += 1

    # -- lifecycle ------------------------------------------------------------

    def wait_idle(self, timeout: float = 600.0) -> bool:
        """Block until every enqueued async item has finished."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._finished < self._queued:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(remaining)
        return True

    def drain(self, timeout: float = 600.0) -> None:
        """Drain the ring, join workers, close sinks (the non-overlapped
        tail; transport-backed sinks flush and release their backend —
        a StreamSink sends its BYE frame here)."""
        with self.telemetry.span("insitu/drain"):
            self.staging.close()
            for th in self._threads:
                th.join(timeout=timeout)
        for task in self._tasks.values():
            try:
                task.sink.flush()
                task.sink.close()
            except Exception:  # noqa: BLE001 - teardown must not raise
                pass

    # -- reporting ------------------------------------------------------------

    def report(self) -> dict[str, Any]:
        rep: dict[str, Any] = dict(self.telemetry.step_overlap_report())
        rep["n_results"] = len(self.results)
        rep["n_errors"] = len(self.errors)
        rep["staging_puts"] = self.staging.puts
        rep["drops"] = dict(self.drops)
        rep["effective_every"] = {n: self._every[n] for n in self._tasks}
        rep["retries"] = dict(self.retry_counts)
        rep["degraded"] = {n: dict(d) for n, d in self.degraded.items()}
        return rep


# ---------------------------------------------------------------------------
# Workflow driver: deprecation shim over repro.core.session.Session.
# ---------------------------------------------------------------------------

def run_pipeline(n_steps: int,
                 app_step: Callable[[int], dict[str, Callable[[], Any]]],
                 runtime: PipelineRuntime) -> Telemetry:
    """Run ``n_steps`` of the application with the pipeline runtime attached.

    ``app_step(step)`` dispatches one device step and returns the providers
    dict (lazy payload getters); the loop waits for the device result inside
    a ``step/compute`` span so device/in-situ attribution is exact.

    Deprecation shim: wraps the runtime in a
    :class:`~repro.core.session.Session` and drives ``Session.run`` — new
    code should declare an ``InSituPlan`` and own the Session directly.
    """
    from repro.core.session import Session
    return Session.over_runtime(runtime).run(n_steps, app_step)
