"""Delta encoding against a base snapshot (the serving-snapshot codec).

Huebl et al. show that at scale the *reduction ratio* — not raw IO
bandwidth — becomes the binding constraint, and the serving KV slab is
append-mostly: between two snapshot firings most pages are byte-identical
and only the freshly decoded tokens differ. Compressing the full slab with
a plain lossless codec re-pays for every unchanged byte on every firing;
delta encoding against the previous snapshot pays only for what changed.

Frame layout (``DMAGIC``, version 1): the array is split into the same
fixed-size chunks the lossless layer uses, and every chunk independently
picks the cheapest of three ops against the base bytes at its offset:

  COPY   the chunk is byte-identical to the base chunk — zero payload.
  XOR    payload is ``inner_codec(chunk XOR base_chunk)`` — append-mostly
         pages XOR to near-all-zeros, which zlib removes almost entirely.
  SELF   payload is ``inner_codec(chunk)`` — self-contained; chosen when
         the delta doesn't win (changed-beyond-recognition pages, or no
         base at all).

A frame encoded without a base is all-SELF and decodes standalone; a frame
with any COPY/XOR chunk records the base's byte length and refuses to
decode against a missing or wrong-sized base (``DeltaBaseMismatch``).
Chunks are independent, so encode and decode both ride the shared
chunk-parallel ``codecs.codec_pool``.

The ``delta`` name in the ``repro.core.compression`` registry is the
self-contained adapter (``encode(arr)`` == all-SELF frame); the base-aware
``encode``/``decode`` overloads are what :class:`repro.serving.snapshot.
SnapshotStore` chains.
"""
from __future__ import annotations

import struct
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import codecs

DMAGIC = b"RPRD"
_VERSION = 1

OP_COPY = 0
OP_XOR = 1
OP_SELF = 2

_FLAG_HAS_BASE = 1


class DeltaBaseMismatch(ValueError):
    """A delta frame references a base the caller didn't (correctly) supply."""


@dataclass(frozen=True)
class DeltaStats:
    raw_bytes: int
    stored_bytes: int
    n_copy: int
    n_xor: int
    n_self: int

    @property
    def ratio(self) -> float:
        """Paper Eq. (1): CR = (original - stored) / original."""
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.stored_bytes) / self.raw_bytes


def _encode_chunk(comp, target: memoryview,
                  base: Optional[memoryview]) -> tuple[int, bytes]:
    """Pick the cheapest op for one chunk; returns (op, payload).

    ``base`` is always chunk-length-matched: ``encode`` discards a base
    whose byte length differs from the target array, so both sides chunk
    identically (including the short tail chunk).
    """
    if base is None:
        return OP_SELF, comp(target)
    # vectorized compare: python-level memoryview equality is ~30x slower,
    # and on the append-mostly hot path unchanged chunks make this check
    # the entire encode cost
    t = np.frombuffer(target, np.uint8)
    b = np.frombuffer(base, np.uint8)
    if np.array_equal(t, b):
        return OP_COPY, b""
    # XOR first: on the append-mostly hot path the delta compresses to
    # almost nothing, and paying comp() twice per changed chunk would
    # double the publish CPU. Only a delta that barely compressed (the
    # page changed beyond recognition) is worth racing against SELF.
    xor_payload = comp(memoryview(np.bitwise_xor(t, b).data))
    if len(xor_payload) < (len(target) >> 3):        # clear delta win
        return OP_XOR, xor_payload
    self_payload = comp(target)
    if len(xor_payload) < len(self_payload):
        return OP_XOR, xor_payload
    return OP_SELF, self_payload


def encode(arr: np.ndarray, base: Optional[np.ndarray] = None, *,
           codec: str = "zlib", chunk_bytes: int = codecs.DEFAULT_CHUNK,
           pool: Optional[ThreadPoolExecutor] = None
           ) -> tuple[bytes, DeltaStats]:
    """Frame ``arr`` as a delta against ``base`` (None => self-contained).

    A base with a different byte length than ``arr`` is ignored (the frame
    falls back to self-contained): chunk offsets would not line up, so an
    XOR against it carries no signal.
    """
    if codec not in codecs._COMPRESSORS:
        raise KeyError(
            f"unknown inner codec {codec!r}; available: {codecs.available()}")
    cid, comp, _ = codecs._COMPRESSORS[codec]
    arr = np.ascontiguousarray(arr)
    if base is not None:
        base = np.ascontiguousarray(base)
        if base.nbytes != arr.nbytes:
            base = None
    views = codecs._chunk_views(arr, int(chunk_bytes))
    base_views: list[Optional[memoryview]]
    if base is None:
        base_views = [None] * len(views)
    else:
        base_views = list(codecs._chunk_views(base, int(chunk_bytes)))

    def one(i: int) -> tuple[int, bytes]:
        return _encode_chunk(comp, views[i], base_views[i])

    if pool is not None and len(views) > 1:
        coded = list(pool.map(one, range(len(views))))
    else:
        coded = [one(i) for i in range(len(views))]
    ops = bytes(op for op, _ in coded)
    payloads = [p for _, p in coded]
    has_base = any(op != OP_SELF for op in ops)
    dt = codecs._dtype_token(arr.dtype)
    parts = [
        DMAGIC,
        struct.pack("<BBBB", _VERSION, _FLAG_HAS_BASE if has_base else 0,
                    cid, len(dt)), dt,
        struct.pack("<B", arr.ndim),
        struct.pack(f"<{arr.ndim}q", *arr.shape),
        struct.pack("<qqqI", arr.nbytes,
                    base.nbytes if has_base else 0,
                    int(chunk_bytes), len(payloads)),
        ops,
        struct.pack(f"<{len(payloads)}I", *(len(p) for p in payloads)),
        *payloads,
    ]
    blob = b"".join(parts)
    n_copy = ops.count(OP_COPY)
    n_xor = ops.count(OP_XOR)
    return blob, DeltaStats(arr.nbytes, len(blob), n_copy, n_xor,
                            len(ops) - n_copy - n_xor)


def is_delta_frame(blob: bytes) -> bool:
    return bytes(blob[:4]) == DMAGIC


def frame_needs_base(blob: bytes) -> bool:
    """True when the frame has COPY/XOR chunks (cannot decode standalone)."""
    if not is_delta_frame(blob) or len(blob) < 6:
        raise ValueError("not a delta frame")
    return bool(blob[5] & _FLAG_HAS_BASE)


def decode(blob: bytes, base: Optional[np.ndarray] = None, *,
           pool: Optional[ThreadPoolExecutor] = None) -> np.ndarray:
    """Decode a delta frame, applying COPY/XOR chunks against ``base``."""
    if bytes(blob[:4]) != DMAGIC:
        raise ValueError("bad delta frame magic")
    view = memoryview(blob)
    version, flags, cid, dtlen = struct.unpack_from("<BBBB", blob, 4)
    if version != _VERSION:
        raise ValueError(f"unsupported delta frame version {version}")
    off = 8
    dtype = codecs._dtype_from_token(bytes(view[off:off + dtlen]).decode())
    off += dtlen
    (ndim,) = struct.unpack_from("<B", blob, off)
    off += 1
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    raw_nbytes, base_nbytes, chunk_bytes, n_chunks = struct.unpack_from(
        "<qqqI", blob, off)
    off += 28
    if chunk_bytes < 1 or raw_nbytes < 0:
        raise ValueError("corrupt delta frame header")
    want_chunks = -(-raw_nbytes // chunk_bytes)   # ceil; 0 for empty arrays
    if n_chunks != want_chunks:
        raise ValueError(
            f"delta chunk table mismatch: {n_chunks} chunks cannot cover "
            f"{raw_nbytes} raw bytes at {chunk_bytes} per chunk")
    ops = bytes(view[off:off + n_chunks])
    off += n_chunks
    sizes = struct.unpack_from(f"<{n_chunks}I", blob, off)
    off += 4 * n_chunks
    has_base = bool(flags & _FLAG_HAS_BASE)
    base_mv: Optional[memoryview] = None
    if has_base:
        if base is None:
            raise DeltaBaseMismatch(
                f"delta frame requires a base of {base_nbytes} bytes, "
                "got none")
        base = np.ascontiguousarray(base)
        if base.nbytes != base_nbytes:
            raise DeltaBaseMismatch(
                f"delta frame requires a base of {base_nbytes} bytes, "
                f"got {base.nbytes}")
        base_mv = codecs._byte_view(base)
    _, _, decomp = codecs._BY_ID[cid]
    out = bytearray(raw_nbytes)

    jobs = []
    in_off = off
    for i in range(n_chunks):
        jobs.append((in_off, sizes[i], i * chunk_bytes, ops[i]))
        in_off += sizes[i]
    if in_off > len(blob):
        raise ValueError("truncated delta frame payload")

    def _one(job: tuple[int, int, int, int]) -> None:
        src, size, dst, op = job
        want = min(chunk_bytes, raw_nbytes - dst)
        if op == OP_COPY:
            if size:
                raise ValueError("COPY chunk with payload")
            out[dst:dst + want] = base_mv[dst:dst + want]
            return
        raw = decomp(view[src:src + size])
        if len(raw) != want:
            raise ValueError(
                f"delta chunk length mismatch: {len(raw)} != {want}")
        if op == OP_XOR:
            # base_nbytes == raw_nbytes (validated above), so the base
            # slice is exactly chunk-length-matched
            t = np.frombuffer(raw, np.uint8)
            b = np.frombuffer(base_mv[dst:dst + want], np.uint8)
            out[dst:dst + want] = np.bitwise_xor(t, b).tobytes()
        elif op == OP_SELF:
            out[dst:dst + want] = raw
        else:
            raise ValueError(f"unknown delta chunk op {op}")

    if pool is not None and len(jobs) > 1:
        list(pool.map(_one, jobs))
    else:
        for job in jobs:
            _one(job)
    if raw_nbytes == 0:
        return np.empty(shape, dtype=dtype)
    return np.frombuffer(out, dtype=dtype).reshape(shape)


# ---------------------------------------------------------------------------
# registry adapter: 'delta' is a lossless Codec; without a base it emits a
# self-contained (all-SELF) frame, so the plain registry contract holds.
# ---------------------------------------------------------------------------

from repro.core import compression as _compression  # noqa: E402


class DeltaCodec:
    lossy = False
    name = "delta"

    def encode(self, arr: np.ndarray,
               base: Optional[np.ndarray] = None) -> bytes:
        return encode(arr, base)[0]

    def decode(self, blob: bytes,
               base: Optional[np.ndarray] = None) -> np.ndarray:
        return decode(blob, base)


_compression.register(DeltaCodec())
