"""Replica hydration: bring a serving replica up from a snapshot chain.

A new (or crashed) replica has two ways to reach serving state: re-prefill
the live traffic — recomputing work the fleet already did — or replay the
producer's ``serve_snapshot`` base+delta chain and start decoding from the
exact page pool the producer had. This module is the second path, the
point where PR 5's snapshot chains stop being an artifact and become the
scale-out/failover mechanism:

  * **local** — the chain is already on disk (a shared filesystem, or a
    ``SnapshotStore`` object handed over in-process): replay + rebuild.
  * **tcp** — the producer mirrors every frame to ``tcp://host:port``
    (``SnapshotStore.set_mirror`` / the ``serve_snapshot`` preset's
    ``to`` option). The hydrator *listens* there, ingests frames into a
    local replica store until the chain replays end to end, then rebuilds
    — mid-serve, without stopping the producer.

Either way the result is ``PagedServingEngine.from_snapshot``: page pool,
page tables, allocator free list + refcounts, in-flight requests, and
registered prefixes all restored bit-identically, so the replica's next
decoded token matches the producer's — no prefill at all. Cold-replica
time-to-first-token is then one decode step instead of one prefill per
active request (measured in ``benchmarks/prefix_sharing.py``).
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Optional, Union

from repro.core import transport
from repro.serving.snapshot import SnapshotStore

__all__ = ["ReplicaHydrator", "hydrate_serving_engine"]


class ReplicaHydrator:
    """Rebuild a ``PagedServingEngine`` from a snapshot chain.

    ``source`` names where the chain lives:

    - a :class:`SnapshotStore` — used as-is (in-process handover),
    - a directory path — a chain persisted by ``serve_snapshot``'s
      ``directory`` option (or mirrored to disk by a consumer),
    - ``tcp://host:port`` — an address to **listen** on; point the
      producer's snapshot mirror at it and hydration completes as soon
      as a replayable base(+delta) prefix has streamed in.
    """

    def __init__(self, source: Union[SnapshotStore, str], *,
                 stream: str = "kv_pages") -> None:
        self.stream = stream
        self._listen: Optional[tuple[str, int]] = None
        if isinstance(source, SnapshotStore):
            self.store = source
        elif isinstance(source, str) and "://" in source:
            scheme, rest = transport.parse_url(source)
            if scheme != "tcp":
                raise ValueError(
                    f"hydration source must be a store, a directory, or a "
                    f"tcp:// listen address, got {source!r}")
            host, _, port = rest.rpartition(":")
            if not host or not port.isdigit():
                raise ValueError(
                    f"tcp hydration source needs host:port, got {source!r}")
            self._listen = (host, int(port))
            self.store = SnapshotStore()         # filled by ingest
        else:
            if not os.path.isdir(str(source)):
                raise FileNotFoundError(
                    f"snapshot chain directory {source!r} does not exist")
            self.store = SnapshotStore(str(source))

    # -- readiness -----------------------------------------------------------

    def ready(self) -> bool:
        """True when the chain currently replays end to end."""
        return self.store.restorable(self.stream)

    def _consume_until_ready(self, ready: Callable[[], bool],
                             idle_timeout_s: float,
                             start_grace_s: Optional[float],
                             log) -> dict:
        from repro.launch import consume

        host, port = self._listen  # type: ignore[misc]
        return consume.consume_loop(
            host=host, port=port, store=self.store,
            idle_timeout_s=idle_timeout_s, start_grace_s=start_grace_s,
            stop=lambda _report: ready(), log=log)

    # -- the hydration entry point -------------------------------------------

    def hydrate(self, cfg, params, *, upto: Optional[int] = None,
                ready: Optional[Callable[[], bool]] = None,
                idle_timeout_s: float = 10.0,
                start_grace_s: Optional[float] = None,
                log=print) -> tuple[Any, dict]:
        """-> (engine, info): a serving engine at the snapshot's state.

        For a ``tcp://`` source this first listens and ingests mirrored
        frames until ``ready()`` (default: the chain is restorable); for
        local sources the chain must already replay. ``ready`` can demand
        more — e.g. the smoke test waits for a snapshot with in-flight
        requests. ``info`` reports where the state came from and how long
        the replay + rebuild took (the cold-replica TTFT numerator).
        """
        from repro.serving.pages import PagedServingEngine

        ready = ready if ready is not None else self.ready
        info: dict[str, Any] = {"stream": self.stream,
                                "mode": "tcp" if self._listen else "local"}
        if self._listen is not None:
            t0 = time.perf_counter()
            report = self._consume_until_ready(ready, idle_timeout_s,
                                               start_grace_s, log)
            info["ingest_s"] = time.perf_counter() - t0
            info["frames_ingested"] = report["snapshot_frames"]
            info["address"] = report["address"]
            if not ready():
                raise TimeoutError(
                    f"no restorable {self.stream!r} chain arrived on "
                    f"{report['address']} (ingested "
                    f"{report['snapshot_frames']} frame(s))")
        t0 = time.perf_counter()
        step, leaves = self.store.restore(self.stream, upto=upto)
        engine = PagedServingEngine.from_snapshot(cfg, params, leaves)
        info["restore_s"] = time.perf_counter() - t0
        info["step"] = step
        info["active_requests"] = sum(
            a is not None for a in engine.active)
        info["prefixes"] = len(engine.prefix)
        log(f"hydrated {self.stream!r} at step {step}: "
            f"{info['active_requests']} in-flight request(s), "
            f"{info['prefixes']} registered prefix(es), "
            f"{info['restore_s'] * 1e3:.1f} ms replay+rebuild")
        return engine, info


def hydrate_serving_engine(source: Union[SnapshotStore, str], cfg, params,
                           *, stream: str = "kv_pages",
                           **kw) -> tuple[Any, dict]:
    """One-call convenience over :class:`ReplicaHydrator`."""
    return ReplicaHydrator(source, stream=stream).hydrate(cfg, params, **kw)
