"""Production mesh builders.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS *before* the first jax call; smoke
tests and benches must keep seeing one CPU device).

Mesh topology:
  single-pod : (16, 16)    axes ('data', 'model')   — 256 chips, fast ICI
  multi-pod  : (2, 16, 16) axes ('pod', 'data', 'model') — 2 pods over DCI

'pod' is the slow inter-pod axis: the sharding rules keep parameters off it
(pure DP), and the optional int8 gradient ring (optim/grad_compress) shrinks
its wire bytes. 'data' carries FSDP + batch, 'model' carries TP/EP.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
