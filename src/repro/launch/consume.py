"""Consumer side of a streamed in-situ run.

The producer's terminal stages publish frames through ``repro.core.transport``
sinks (``to="tcp://host:port"`` in the plan options, a checkpoint ``mirror``,
or a ``SnapshotStore`` mirror). This module is the other end of the wire: a
:class:`~repro.core.transport.StreamSource` listener that decodes every frame
with the shared registry and routes it by payload codec:

- ``raw``  — snapshot chain frames: ingested into a local replica
  :class:`~repro.serving.snapshot.SnapshotStore`, so the consumer tails the
  producer's base+delta chain live and can ``restore()`` bit-identically at
  any point without stopping the producer.
- ``file`` — checkpoint shards mirrored by ``CheckpointManager``:
  materialized under ``out_dir`` with the same atomic tmp -> fsync -> rename
  publish as the producer side.
- ``tree`` / ``json`` — analysis artifacts (grad health reports, spectra):
  decoded and kept (latest per stream) for inspection.

The consumer also owns the steering back-channel: ``steer`` messages are
pushed up the same connections (``{"task": name, "every": N}`` or
``{"task": name, "lossy_eps": x}``) and applied by the producer's
``Session.poll_steering`` mid-run.

CLI wrapper: ``tools/insitu_consumer.py``.
"""
from __future__ import annotations

import json
from typing import Any, Callable, Optional, Sequence

from repro.core import transport
from repro.serving.snapshot import SnapshotStore


def consume_loop(source: Optional[transport.StreamSource] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 out_dir: Optional[str] = None,
                 snapshot_dir: Optional[str] = None,
                 store: Optional[SnapshotStore] = None,
                 steer: Optional[Sequence[dict]] = None,
                 steer_after: int = 1,
                 idle_timeout_s: float = 5.0,
                 start_grace_s: Optional[float] = None,
                 max_frames: Optional[int] = None,
                 on_frame: Optional[Callable[[transport.Frame], None]] = None,
                 stop: Optional[Callable[[dict], bool]] = None,
                 log=print) -> dict:
    """Listen for frames and route them until the stream drains.

    Pass an already-listening ``source`` (e.g. from a test socketpair) or
    let the loop bind its own listener on ``host:port`` (``port=0`` picks a
    free one; the chosen address is logged and returned). ``steer`` messages
    are sent up the back-channel once ``steer_after`` data frames have
    arrived — by then at least one producer connection is live.

    ``store`` overrides the loop's own replica :class:`SnapshotStore`
    (``snapshot_dir`` is then ignored) — the replica-hydration path shares
    one store between this loop and the engine being hydrated. ``stop``
    is checked after each routed frame with the running report; returning
    True ends the loop early (e.g. "the chain is restorable now").

    Returns a report dict: frame/byte counts per stream and codec, the
    replica ``store`` (for ``restore()`` assertions), materialized file
    paths, decoded latest artifacts, and how many producers each steering
    message reached.
    """
    own_source = source is None
    if own_source:
        source = transport.StreamSource(host=host, port=port)
    if store is None:
        store = SnapshotStore(snapshot_dir) if snapshot_dir is not None \
            else SnapshotStore()
    steer = list(steer or [])
    report: dict[str, Any] = {
        "address": source.address,
        "frames": 0, "bytes": 0,
        "by_codec": {}, "by_stream": {},
        "snapshot_frames": 0, "files": [], "artifacts": {},
        "steering_sent": [], "errors": [],
        "store": store,
    }
    log(f"consumer listening on {source.address}")
    try:
        for frame in source.frames(idle_timeout_s=idle_timeout_s,
                                   start_grace_s=start_grace_s,
                                   max_frames=max_frames):
            report["frames"] += 1
            report["bytes"] += len(frame.payload)
            report["by_codec"][frame.codec] = \
                report["by_codec"].get(frame.codec, 0) + 1
            report["by_stream"][frame.stream] = \
                report["by_stream"].get(frame.stream, 0) + 1
            try:
                _route(frame, store, out_dir, report)
            except transport.TransportError as e:
                report["errors"].append(str(e))
                log(f"consumer: dropped frame ({e})")
            if on_frame is not None:
                on_frame(frame)
            if steer and report["frames"] >= steer_after:
                for msg in steer:
                    reached = source.send_control(msg)
                    report["steering_sent"].append(
                        {"message": msg, "reached": reached})
                    log(f"consumer: steered {msg} -> "
                        f"{reached} producer(s)")
                steer = []
            if stop is not None and stop(report):
                log("consumer: stop condition met, detaching")
                break
    finally:
        if own_source:
            source.close()
    log(f"consumer: {report['frames']} frames "
        f"({report['bytes'] / 1e6:.2f} MB) across "
        f"{sorted(report['by_stream'])}")
    return report


def _route(frame: transport.Frame, store: SnapshotStore,
           out_dir: Optional[str], report: dict) -> None:
    """One frame into the right terminal: replica chain, disk, or memory."""
    if frame.codec == transport.CODEC_RAW:
        # snapshot chain frame mirrored by SnapshotStore._forward_frame —
        # the payload is a complete versioned chain frame, self-describing
        placed = store.ingest(frame.stream, frame.payload)
        report["snapshot_frames"] += 1
        report.setdefault("last_snapshot", {})[frame.stream] = placed
    elif frame.codec == transport.CODEC_FILE:
        root = out_dir if out_dir is not None else "consumed"
        report["files"].append(transport.materialize_file(frame, root))
    else:
        obj = transport.decode_frame_payload(frame)
        report["artifacts"][frame.stream] = {"step": frame.step,
                                             "value": obj}


def restore_report(report: dict, stream: str = "kv_pages") -> dict:
    """Summarize the replica's newest restorable snapshot for ``stream``:
    step, leaf count, and a content digest (stable across producer and
    replica when the chains match bit-for-bit)."""
    import hashlib

    store: SnapshotStore = report["store"]
    step, leaves = store.restore(stream)
    h = hashlib.sha256()
    for key in sorted(leaves):
        arr = leaves[key]
        h.update(key.encode())
        h.update(str(getattr(arr, "dtype", type(arr))).encode())
        h.update(arr.tobytes() if hasattr(arr, "tobytes")
                 else repr(arr).encode())
    return {"stream": stream, "step": step, "n_leaves": len(leaves),
            "digest": h.hexdigest()}


def main(argv: Optional[Sequence[str]] = None) -> dict:
    import argparse

    ap = argparse.ArgumentParser(
        description="attach to a producer's transport sinks, tail frames, "
                    "and optionally steer it back")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = pick a free one)")
    ap.add_argument("--out-dir", default=None,
                    help="root for materialized checkpoint shards")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist the replica snapshot chain here "
                         "(default: in-memory)")
    ap.add_argument("--idle-timeout", type=float, default=5.0,
                    help="exit after this many idle seconds with no "
                         "live connections")
    ap.add_argument("--start-grace", type=float, default=None,
                    help="wait this long for the first producer to "
                         "connect (default: --idle-timeout)")
    ap.add_argument("--max-frames", type=int, default=None)
    ap.add_argument("--steer", action="append", default=[],
                    metavar="JSON",
                    help="steering message to push back, e.g. "
                         "'{\"task\": \"kv_snapshot\", \"every\": 2}' "
                         "(repeatable)")
    ap.add_argument("--steer-after", type=int, default=1,
                    help="send steering once this many frames arrived")
    ap.add_argument("--restore", default=None, metavar="STREAM",
                    help="after draining, restore this stream from the "
                         "replica chain and print step + digest")
    args = ap.parse_args(argv)

    steer = [json.loads(s) for s in args.steer]
    report = consume_loop(host=args.host, port=args.port,
                          out_dir=args.out_dir,
                          snapshot_dir=args.snapshot_dir,
                          steer=steer, steer_after=args.steer_after,
                          idle_timeout_s=args.idle_timeout,
                          start_grace_s=args.start_grace,
                          max_frames=args.max_frames)
    if args.restore is not None:
        rr = restore_report(report, args.restore)
        print(f"restored {rr['stream']!r} at step {rr['step']}: "
              f"{rr['n_leaves']} leaves, digest {rr['digest']}")
        report["restore"] = rr
    return report


if __name__ == "__main__":
    main()
