"""Training driver: sharded train step factory + end-to-end loop.

The step factory builds one jitted train step for (arch config, mesh, rules):

  * params/moments sharded by the logical-axis rules (FSDP over 'data',
    TP/EP over 'model', pure DP over 'pod')
  * batch sharded over ('pod', 'data')
  * optional cross-pod gradient compression: the loss+grad computation runs
    inside a *partially-manual* shard_map (manual over 'pod' only), local
    grads are reduced over the pod ring with bf16/int8 payloads
    (optim/grad_compress), with error-feedback residual carried in the state
  * optional in-graph in-situ hooks (HYBRID mode): the spectral-lossy device
    stage for selected state leaves is compiled into the step, so the step's
    outputs already contain the reduced representation (the NEKO pattern)

The loop (main) wires the substrate together: data prefetcher, in-situ
engine (analytics + checkpointing), straggler monitor, restore-on-start.
Runs on CPU for smoke configs; the same code lowers for the production mesh
in the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import base as configs
from repro.distributed import sharding
from repro.models import params as P_lib
from repro.models import transformer
from repro.optim import grad_compress

PyTree = Any


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def state_spec(cfg: configs.ModelConfig, *, master: bool = False,
               ef_pods: int = 0) -> dict:
    """Abstract (ShapeDtypeStruct) training state for lowering."""
    pspec = transformer.param_spec(cfg)
    params = P_lib.abstract(pspec)
    mdt = jnp.bfloat16
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params)
    state = {
        "params": params,
        "mu": mom,
        "nu": mom,
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if master:
        state["master"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)
    if ef_pods:
        # per-pod local residual: leading pod axis
        state["ef"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((ef_pods,) + s.shape,
                                           jnp.bfloat16), params)
    return state


def init_state(cfg: configs.ModelConfig, rng, opt_cfg: optim.AdamWConfig,
               *, ef_residual: bool = False) -> dict:
    pspec = transformer.param_spec(cfg)
    params = P_lib.materialize(rng, pspec)
    ostate = optim.init(params, opt_cfg)
    state = {"params": params, "mu": ostate.mu, "nu": ostate.nu,
             "count": ostate.count}
    if opt_cfg.master_weights:
        state["master"] = ostate.master
    if ef_residual:
        state["ef"] = grad_compress.ef_init(params)
    return state


def state_shardings(cfg: configs.ModelConfig, mesh: Mesh,
                    rules=None, *, master: bool = False,
                    ef_residual: bool = False) -> dict:
    rules = rules if rules is not None else sharding.DEFAULT_RULES
    pspec = transformer.param_spec(cfg)
    axes = P_lib.logical_axes(pspec)
    abstract = P_lib.abstract(pspec)
    pspecs = sharding.tree_partition_specs(abstract, axes, rules, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    out = {
        "params": pshard,
        "mu": pshard,
        "nu": pshard,
        "count": NamedSharding(mesh, P()),
    }
    if master:
        out["master"] = pshard
    if ef_residual:
        out["ef"] = jax.tree.map(
            lambda s: NamedSharding(mesh, P("pod", *tuple(s))), pspecs)
    return out


def batch_shardings(cfg: configs.ModelConfig, shape: configs.ShapeConfig,
                    mesh: Mesh, rules=None) -> dict:
    extra = sharding.batch_over_model(rules) if rules is not None else False
    bspec = sharding.batch_spec(mesh, shape.global_batch, extra_model=extra)
    out = {"tokens": NamedSharding(mesh, bspec),
           "labels": NamedSharding(mesh, bspec)}
    if cfg.frontend:
        out["prefix"] = NamedSharding(mesh, bspec)
    return out


def batch_abstract(cfg: configs.ModelConfig, shape: configs.ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend:
        out["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return out


# ---------------------------------------------------------------------------
# step factory
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepConfig:
    opt: optim.AdamWConfig = dataclasses.field(
        default_factory=optim.AdamWConfig)
    grad_compress: str = "none"      # none | bf16 | int8 (cross-pod wire)
    lr_peak: float = 3e-4
    lr_warmup: int = 100
    lr_total: int = 10000
    remat: bool = True


def make_train_step(cfg: configs.ModelConfig, mesh: Mesh,
                    step_cfg: StepConfig, *, rules=None,
                    shape: Optional[configs.ShapeConfig] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics) (to be jitted)."""
    rules = rules if rules is not None else sharding.DEFAULT_RULES
    n_pods = grad_compress.pod_size(mesh, "pod")
    use_pod_ring = step_cfg.grad_compress != "none" and n_pods > 1
    # batch activation constraint on dim 0: dp axes (+ 'model' for pure_dp)
    gb = shape.global_batch if shape is not None else 1 << 30
    bspec = sharding.batch_spec(mesh, gb,
                                extra_model=sharding.batch_over_model(rules))

    def local_grads(params, batch, bspec_):
        loss_fn = lambda p, b: transformer.train_loss(p, cfg, b, bspec=bspec_)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def _pod_ring_grads(params, batch, state):
        """Compressed cross-pod gradient path (manual over 'pod' only).

        XLA's SPMD partitioner cannot partition token-embedding *gathers*
        inside a partially-manual region (hard CHECK), so the embedding
        lookups are hoisted OUT and vjp-split: their cotangents flow back
        through the auto context (exact scatter-reduction over all axes),
        while every dense gradient rides the compressed pod ring. The loss
        also switches to the gather-free cross-entropy.
        """
        use_ef = "ef" in state
        emb_table = params["embed"]["embedding"]

        def gather_stage(tbl):
            outs = {"h0": jnp.take(tbl, batch["tokens"], axis=0)}
            if cfg.family == "moe" and cfg.mtp_weight > 0:
                outs["mtp_cur"] = jnp.take(tbl, batch["tokens"], axis=0)
                outs["mtp_emb"] = jnp.take(tbl, batch["labels"], axis=0)
            return outs

        gathered, gather_vjp = jax.vjp(gather_stage, emb_table)

        # pod-major reshape: a dim cannot mix manual+auto axes in one spec
        # entry, so 'pod' gets its own leading axis.
        def to_pod_major(x):
            x = x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:])
            return jax.lax.with_sharding_constraint(x, P("pod", "data"))

        batch_pm = jax.tree.map(to_pod_major, batch)
        gathered_pm = jax.tree.map(to_pod_major, gathered)
        pspec_none = jax.tree.map(lambda _: P(), params)
        pm_specs = jax.tree.map(lambda _: P("pod"), batch_pm)
        g_specs = jax.tree.map(lambda _: P("pod"), gathered_pm)
        ef_specs = (jax.tree.map(lambda _: P("pod"), params)
                    if use_ef else P())

        def pod_local(params_, batch_, gathered_, ef_):
            batch_ = jax.tree.map(lambda x: x[0], batch_)
            gathered_ = jax.tree.map(lambda x: x[0], gathered_)

            def loss_fn(p, g):
                mtp_pre = ((g["mtp_cur"], g["mtp_emb"])
                           if "mtp_cur" in g else None)
                return transformer.train_loss(
                    p, cfg, batch_, bspec=P("data"), h0=g["h0"],
                    mtp_pre=mtp_pre, gather_free=True)

            loss_, (grads_, dgath_) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(params_, gathered_)
            if use_ef:
                ef_ = jax.tree.map(lambda e: e[0], ef_)
                grads_ = grad_compress.ef_pre(grads_, ef_)
            reduced = grad_compress.tree_reduce(
                grads_, method=step_cfg.grad_compress, axis="pod", n=n_pods)
            new_ef_ = (jax.tree.map(
                lambda e: e[None], grad_compress.ef_post(grads_, reduced))
                if use_ef else jnp.zeros((1,), jnp.int32))
            dgath_ = jax.tree.map(lambda x: x[None], dgath_)
            return jax.lax.pmean(loss_, "pod"), reduced, dgath_, new_ef_

        sm = sharding.shard_map(
            pod_local, mesh,
            in_specs=(pspec_none, pm_specs, g_specs, ef_specs),
            out_specs=(P(), pspec_none, g_specs,
                       ef_specs if use_ef else P("pod")),
            axis_names={"pod"}, check_vma=False)
        loss, grads, dgath_pm, new_ef = sm(
            params, batch_pm, gathered_pm,
            state["ef"] if use_ef else jnp.zeros((), jnp.int32))
        # embedding-gather cotangents: back through the auto context (the
        # scatter-add all-reduces exactly over pod+data — uncompressed)
        dgath = jax.tree.map(
            lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]),
            dgath_pm)
        # mean over pods for the gather path (ring already averaged the rest)
        dgath = jax.tree.map(lambda x: x / n_pods, dgath)
        demb = gather_vjp(dgath)[0]
        g_emb = grads["embed"]["embedding"]
        grads["embed"]["embedding"] = (g_emb + demb).astype(g_emb.dtype)
        if not use_ef:
            new_ef = None
        return loss, grads, new_ef

    def train_step(state, batch):
        params = state["params"]
        if use_pod_ring:
            loss, grads, new_ef = _pod_ring_grads(params, batch, state)
        else:
            loss, grads = local_grads(params, batch, bspec)
            new_ef = state.get("ef")

        lr = optim.schedules.warmup_cosine(
            state["count"], peak=step_cfg.lr_peak, warmup=step_cfg.lr_warmup,
            total=step_cfg.lr_total)
        ostate = optim.AdamWState(state["count"], state["mu"], state["nu"],
                                  state.get("master"))
        new_params, new_ostate = optim.update(grads, ostate, params,
                                              step_cfg.opt, lr=lr)
        new_state = dict(state)
        new_state.update(params=new_params, mu=new_ostate.mu,
                         nu=new_ostate.nu, count=new_ostate.count)
        if new_ostate.master is not None:
            new_state["master"] = new_ostate.master
        if new_ef is not None:
            new_state["ef"] = new_ef
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": optim.adamw.global_norm(grads),
                   "lr": lr}
        return new_state, metrics

    return train_step


def jit_train_step(cfg, mesh, step_cfg: StepConfig, shape, *, rules=None,
                   donate: bool = True):
    """Jitted + sharded train step and the (state, batch) shardings."""
    rules = rules if rules is not None else sharding.DEFAULT_RULES
    ef = step_cfg.grad_compress == "int8" and "pod" in mesh.axis_names
    st_sh = state_shardings(cfg, mesh, rules,
                            master=step_cfg.opt.master_weights,
                            ef_residual=ef)
    b_sh = batch_shardings(cfg, shape, mesh, rules)
    fn = make_train_step(cfg, mesh, step_cfg, rules=rules, shape=shape)
    jitted = jax.jit(
        fn,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else ())
    return jitted, st_sh, b_sh, ef


# ---------------------------------------------------------------------------
# end-to-end loop (smoke-scale on CPU; same code path as production)
# ---------------------------------------------------------------------------

def default_train_plan(*, insitu_mode: str = "async",
                       ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
                       analytics_every: int = 10, p_i: int = 2,
                       fault: bool = False,
                       fault_hosts: Optional[list] = None,
                       fault_grace_s: float = 30.0) -> dict:
    """The training loop's declarative in-situ plan, in plain-dict form.

    Two streams: ``grads`` (per-step gradient/param summaries) and
    ``train_state`` (the full checkpointable state). ``fault=True`` adds a
    third, ``health`` (per-step heartbeat + step time), bound to the
    ``fault`` preset — failed-host detection and straggler mitigation run
    on it. Callers can load the same shape from TOML/JSON and pass it to
    ``train_loop(plan=...)``.
    """
    plan: dict = {
        "streams": ["grads", "train_state"],
        "workers": p_i,
        "tasks": {
            "analytics": {"stream": "grads", "preset": "grad_health",
                          "every": analytics_every,
                          "placement": insitu_mode},
        },
    }
    if ckpt_dir:
        plan["tasks"]["checkpoint"] = {
            "stream": "train_state", "preset": "checkpoint",
            "every": ckpt_every, "placement": insitu_mode,
            "options": {"directory": ckpt_dir},
        }
    if fault:
        # sync + every=1: heartbeats must not be shed by backpressure, and
        # mitigation decisions should land on the step that triggered them
        plan["streams"].append("health")
        plan["tasks"]["fault"] = {
            "stream": "health", "preset": "fault", "every": 1,
            "placement": "sync", "pipelined": False,
            "options": {"hosts": list(fault_hosts or [0]),
                        "grace_s": fault_grace_s},
        }
    return plan


def train_loop(arch: str, *, steps: int = 50, smoke: bool = True,
               insitu_mode: str = "async", ckpt_dir: Optional[str] = None,
               ckpt_every: int = 20, seed: int = 0,
               analytics_every: int = 10, p_i: int = 2,
               plan: Optional[Any] = None,
               sink_faults: Optional[dict] = None,
               on_session: Optional[Callable[[Any], None]] = None,
               log: Callable[[str], None] = print) -> dict:
    """End-to-end training with the in-situ stack declared as a plan.

    All in-situ work — analytics and checkpointing — is one
    :class:`~repro.core.session.InSituPlan` driven through a single
    :class:`~repro.core.session.Session`; the loop's only in-situ calls are
    ``session.emit``. Pass ``plan`` (an ``InSituPlan`` or its dict form) to
    replace the default workflow wholesale; the legacy kwargs
    (``insitu_mode``/``ckpt_every``/``analytics_every``) parameterize the
    default plan. ``sink_faults`` maps task names to fault hooks installed
    via ``PipelineRuntime.inject_sink_fault`` (transient-failure drills).
    ``on_session`` runs once with the live session before the first step
    (e.g. to grab a task's transport sink for a network-fault drill).
    """
    from repro.core import InSituPlan, Session, Telemetry
    from repro.data.pipeline import Prefetcher, batch_spec_for
    from repro.distributed.fault import StragglerMonitor

    cfg = configs.get(arch, smoke=smoke)
    shape = configs.SMOKE_SHAPE if smoke else configs.SHAPES["train_4k"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step_cfg = StepConfig()
    tm = Telemetry()

    if plan is None:
        plan = default_train_plan(
            insitu_mode=insitu_mode, ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every, analytics_every=analytics_every, p_i=p_i)
    if not isinstance(plan, InSituPlan):
        plan = InSituPlan.from_dict(plan)

    with sharding.mesh_context(mesh):
        state = init_state(cfg, jax.random.PRNGKey(seed), step_cfg.opt)
        jitted, st_sh, b_sh, _ = jit_train_step(cfg, mesh, step_cfg, shape,
                                                donate=False)

        # ONE session: analytics and checkpointing share the staging ring
        # and the p_i worker pool (the paper's single p_o/p_i split).
        with Session(plan, telemetry=tm, raise_on_error=True) as session:
            for task_name, hook in (sink_faults or {}).items():
                session.runtime.inject_sink_fault(task_name, hook)
            if on_session is not None:
                on_session(session)
            # record the mesh geometry with every save so a later
            # restore(elastic=True) can plan the remesh from the manifest
            session.set_checkpoint_meta(mesh=mesh)
            if session.latest_checkpoint_step() is not None:
                start, state = session.restore(state)
                log(f"resumed from step {start}")

            pf = Prefetcher(batch_spec_for(cfg, shape), depth=2,
                            telemetry=tm)
            mon = StragglerMonitor()
            losses = []
            for i in range(steps):
                batch_np = next(pf)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.perf_counter()
                with session.step_span(i):
                    state, metrics = jitted(state, batch)
                    loss = float(metrics["loss"])
                step_s = time.perf_counter() - t0
                mon.observe(0, step_s)
                losses.append(loss)
                if "health" in session.streams:
                    # single-process loop: host 0's beat + step time; a
                    # multi-host launcher emits {"hosts": {h: s}} instead
                    session.emit("health", i, {"host": 0, "step_s": step_s})
                # a custom plan may declare only a subset of the default
                # streams — offer each payload only where declared
                if "grads" in session.streams:
                    params_now = state["params"]
                    session.emit("grads", i, lambda p=params_now: {
                        "params": np.asarray(
                            jax.tree.leaves(p)[0].astype(jnp.float32))})
                if "train_state" in session.streams:
                    session.emit("train_state", i, lambda s=state: s)
                if i % 10 == 0:
                    log(f"step {i} loss {loss:.4f} "
                        f"lr {float(metrics['lr']):.2e}")
            pf.close()
    n_analytics = sum(1 for r in session.results if r.task == "analytics")
    return {"losses": losses, "telemetry": tm,
            "insitu_results": n_analytics,
            "session_report": session.report(),
            "straggler_report": mon.report()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--insitu", default="async",
                    choices=["sync", "async", "hybrid"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — production mesh only")
    args = ap.parse_args()
    out = train_loop(args.arch, steps=args.steps, smoke=not args.full,
                     insitu_mode=args.insitu, ckpt_dir=args.ckpt_dir)
    print("final loss:", out["losses"][-1])
    print("in-situ results:", out["insitu_results"])


if __name__ == "__main__":
    main()
