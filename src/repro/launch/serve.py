"""Serving driver: batched-request serving with in-situ tasks attached.

Runs the ServingEngine on a smoke config (CPU) or lowers the full-config
decode step for the production mesh (see dryrun.py for the mesh pass). The
in-situ engine attaches the paper's tasks to the *serving* loop: per-step KV
cache statistics (the "image") and periodic serving-state snapshots
(prefix-cache persistence — the serving analog of checkpointing), published
as a base+delta chain through the versioned ``SnapshotStore`` (the slab is
append-mostly, so deltas push the effective ratio far past plain zlib).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.configs import base as configs
from repro.core import InSituPlan, Session, Telemetry
from repro.models import params as P_lib
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.pages import PagedServingEngine


def default_serve_plan(*, insitu_mode: str = "async",
                       snapshot_every: int = 4, base_every: int = 8,
                       codec: str = "zlib",
                       snapshot_dir: Optional[str] = None,
                       snapshot_to: Optional[str] = None,
                       p_i: int = 2) -> dict:
    """The serving loop's declarative in-situ plan (plain-dict form).

    One stream — ``kv_pages``, the live KV cache slab — with the
    ``serve_snapshot`` preset attached best-effort: drop on a full ring,
    never stall the decode loop. Snapshots go through the versioned
    delta store: every ``base_every``-th publish is a self-contained base
    frame, the rest delta-encode against the previous snapshot (the slab
    is append-mostly), and firings where the engine version is unchanged
    collapse to a no-op frame. ``snapshot_dir`` persists the chain
    crash-safely on disk (default: in-memory probe). ``snapshot_to``
    additionally streams every raw chain frame to a transport URL
    (``tcp://host:port`` of a ``repro.launch.consume`` consumer) — the
    remote replica tails the delta chain live and can restore
    bit-identically while this loop keeps serving.
    """
    options: dict = {"base_every": base_every, "codec": codec}
    if snapshot_dir is not None:
        options["directory"] = snapshot_dir
    if snapshot_to is not None:
        options["to"] = snapshot_to
    return {
        "streams": ["kv_pages"],
        "workers": p_i,
        "tasks": {
            "kv_snapshot": {"stream": "kv_pages", "preset": "serve_snapshot",
                            "every": snapshot_every,
                            "placement": insitu_mode,
                            "backpressure": "drop",
                            "options": options},
        },
    }


def serve_loop(arch: str, *, n_requests: int = 8, max_new: int = 8,
               slots: int = 4, insitu_mode: str = "async",
               seed: int = 0, plan: Optional[Any] = None,
               engine_kind: str = "paged", num_pages: int = 17,
               page_size: int = 16, prefix_len: int = 0,
               hydrate_from: Optional[Any] = None, log=print) -> dict:
    """Serve ``n_requests`` with the in-situ plan attached.

    ``prefix_len > 0`` gives every request a common ``prefix_len``-token
    system prompt, registered on the paged engine so matching admits map
    the shared chain copy-on-write and prefill only their own suffix.
    ``hydrate_from`` (a chain directory, ``tcp://host:port`` listen
    address, or ``SnapshotStore``) skips cold start entirely: the paged
    engine is rebuilt from the snapshot chain — pool, tables, allocator,
    prefixes, in-flight requests — and keeps serving from there.
    """
    cfg = configs.get(arch, smoke=True)
    params = P_lib.materialize(jax.random.PRNGKey(seed),
                               transformer.param_spec(cfg))
    hydrate_info = None
    prompt_len = max(16, prefix_len + 8)
    max_len = max(64, ((prompt_len + max_new + page_size - 1)
                       // page_size) * page_size)
    if engine_kind == "paged":
        if hydrate_from is not None:
            from repro.launch.hydrate import ReplicaHydrator

            # a cold replica usually starts BEFORE the producer has
            # published anything — give the producer's jit warm-up a
            # grace window before the first frame, then a generous idle
            # timeout between frames
            engine, hydrate_info = ReplicaHydrator(hydrate_from).hydrate(
                cfg, params, idle_timeout_s=30.0, start_grace_s=120.0,
                log=log)
        else:
            # default: continuous batching over the shared page pool —
            # same KV budget as `slots` dense stripes
            # ((num_pages-1) * page_size tokens) but admission is
            # per-page, so short requests stop blocking.
            engine = PagedServingEngine(cfg, params, num_pages=num_pages,
                                        page_size=page_size,
                                        max_reqs=2 * slots,
                                        prompt_len=prompt_len,
                                        max_len=max_len)
    elif engine_kind == "dense":
        if hydrate_from is not None:
            raise ValueError("hydration needs the paged engine "
                             "(engine_kind='paged')")
        # parity / benchmark baseline: fixed dense slots
        engine = ServingEngine(cfg, params, slots=slots,
                               prompt_len=prompt_len, max_len=max_len)
    else:
        raise ValueError(f"unknown engine kind {engine_kind!r}")
    tm = Telemetry()

    # serving-side in-situ declared as a plan, same shape as training
    if plan is None:
        plan = default_serve_plan(insitu_mode=insitu_mode)
    if not isinstance(plan, InSituPlan):
        plan = InSituPlan.from_dict(plan)

    rng = np.random.default_rng(seed)
    if prefix_len > 0:
        # shared system prompt + a short per-request unique tail
        prefix = rng.integers(0, cfg.vocab_size, size=prefix_len)
        requests = [
            Request(i, np.concatenate(
                [prefix, rng.integers(0, cfg.vocab_size, size=4)]),
                max_new=max_new)
            for i in range(n_requests)]
        if engine_kind == "paged" and prefix_len >= engine.page_size:
            engine.register_prefix(prefix)
    else:
        requests = [
            Request(i, rng.integers(0, cfg.vocab_size, size=16),
                    max_new=max_new)
            for i in range(n_requests)]

    # a hydrated engine carries the producer's in-flight requests — they
    # drain through the same loop and count toward the serve totals, but
    # tokens the producer already generated (in req.out at hydration
    # time) are not this replica's work and stay out of its tok/s
    pending = list(requests)
    carried_toks = 0
    if hydrate_info is not None:
        carried = [a for a in engine.active if a is not None]
        carried_toks = sum(len(r.out) for r in carried)
        requests = carried + requests
    step = 0
    t0 = time.perf_counter()
    with Session(plan, telemetry=tm, raise_on_error=True) as session:
        while pending or any(a is not None for a in engine.active):
            while pending and engine.admit(pending[0]):
                pending.pop(0)
            if any(a is not None for a in engine.active):
                with session.step_span(step):
                    engine.step()
                if "kv_pages" in session.streams:
                    session.emit("kv_pages", step,
                                 lambda: engine.snapshot_payload())
            step += 1
            if step > 10000:
                break
    total = time.perf_counter() - t0
    done = sum(1 for r in requests if r.done)
    toks = sum(len(r.out) for r in requests) - carried_toks
    rep = session.report()
    prefix_stats = None
    if engine_kind == "paged":
        ps = engine.page_stats()
        log(f"page pool: {ps['used_pages']}/{ps['num_pages'] - 1} pages "
            f"in use at exit, {ps['active_requests']} active rows")
        prefix_stats = engine.prefix_stats()
        log(f"prefix sharing: {prefix_stats['prefixes']} prefix(es) "
            f"({prefix_stats['prefix_pages']} pages), "
            f"hit rate {prefix_stats['hit_rate']:.0%} "
            f"({prefix_stats['hits']} hit / {prefix_stats['misses']} miss), "
            f"{prefix_stats['shared_pages']} shared pages now, "
            f"{prefix_stats['pages_saved']} pages saved by sharing, "
            f"{prefix_stats['shared_tokens']} prompt tokens served from "
            f"shared pages vs {prefix_stats['prefill_tokens']} prefilled")
    snap = rep["tasks"].get("kv_snapshot", {})
    if snap.get("publishes"):
        log(f"snapshots: {snap['publishes']} published "
            f"({snap['bases']} base / {snap['deltas']} delta / "
            f"{snap['noops']} noop), "
            f"effective compression {snap['effective_compression_x']:.1f}x, "
            f"chain depth {snap['chain_depth']}")
    log(f"served {done}/{len(requests)} requests, {toks} tokens "
        f"in {total:.2f}s ({toks / max(total, 1e-9):.1f} tok/s), "
        f"insitu results={rep['n_results']}, "
        f"handoff dispatch={rep['handoff_dispatch_s'] * 1e3:.2f}ms "
        f"(materialize {rep['handoff_materialize_s'] * 1e3:.2f}ms overlapped)")
    return {"requests": requests, "telemetry": tm, "steps": step,
            "insitu_results": len(session.results),
            "session_report": rep, "tok_per_s": toks / total,
            "prefix_stats": prefix_stats, "hydrate_info": hydrate_info}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--insitu", default="async",
                    choices=["sync", "async", "hybrid"])
    ap.add_argument("--engine", default="paged",
                    choices=["paged", "dense"],
                    help="paged = continuous batching over a shared page "
                         "pool (default); dense = fixed-slot baseline")
    ap.add_argument("--num-pages", type=int, default=17,
                    help="page-pool size incl. the reserved scratch page")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (must divide max_len)")
    ap.add_argument("--snapshot-base-every", type=int, default=8,
                    help="full base frame every N snapshot publishes")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist the snapshot chain to this directory")
    ap.add_argument("--snapshot-to", default=None,
                    help="stream the snapshot chain to a transport URL "
                         "(tcp://host:port of a live consumer)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="give every request a common system prompt of "
                         "this many tokens, registered for COW sharing "
                         "on the paged engine")
    ap.add_argument("--hydrate-from", default=None,
                    help="bring the paged engine up from a snapshot "
                         "chain instead of cold: a chain directory, or a "
                         "tcp://host:port address to listen on for a "
                         "producer's mirrored frames")
    args = ap.parse_args()
    plan = default_serve_plan(insitu_mode=args.insitu,
                              base_every=args.snapshot_base_every,
                              snapshot_dir=args.snapshot_dir,
                              snapshot_to=args.snapshot_to)
    serve_loop(args.arch, n_requests=args.requests, max_new=args.max_new,
               insitu_mode=args.insitu, plan=plan,
               engine_kind=args.engine, num_pages=args.num_pages,
               page_size=args.page_size, prefix_len=args.prefix_len,
               hydrate_from=args.hydrate_from)


if __name__ == "__main__":
    main()
