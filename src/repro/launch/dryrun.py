import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract (ShapeDtypeStruct) state + inputs with the cell's
     shardings — no device allocation anywhere,
  3. ``jit(step).lower(...).compile()`` — a sharding mismatch, a collective
     the partitioner can't build, or an OOM-at-compile is a FAILURE,
  4. records memory_analysis() (bytes/device), cost_analysis() (flops,
     bytes), and the parsed collective schedule into
     artifacts/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

Run:  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
          [--mesh single|multi|both] [--grad-compress none|bf16|int8]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as configs
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.launch import train as train_lib
from repro.models import transformer
from repro.roofline import analysis as roofline
from repro.roofline import extrapolate, memory_model
from repro.serving import engine as serving_engine
from repro.serving import kvcache

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _mesh_desc(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def _default_group(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def lower_train_cell(cfg, shape, mesh, *, grad_compress="none",
                     rules=None, extra_jit_kwargs=None):
    step_cfg = train_lib.StepConfig(grad_compress=grad_compress)
    ef = grad_compress == "int8" and "pod" in mesh.axis_names
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 0)
    st = train_lib.state_spec(cfg, ef_pods=n_pods if ef else 0)
    st_sh = train_lib.state_shardings(cfg, mesh, rules, ef_residual=ef)
    batch = train_lib.batch_abstract(cfg, shape)
    b_sh = train_lib.batch_shardings(cfg, shape, mesh, rules)
    fn = train_lib.make_train_step(cfg, mesh, step_cfg, rules=rules,
                                   shape=shape)
    with jax.set_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, NamedSharding(mesh, P())),
                         donate_argnums=(0,))
        lowered = jitted.lower(st, batch)
        compiled = lowered.compile()
    return lowered, compiled


def lower_decode_cell(cfg, shape, mesh, *, rules=None):
    b, s = shape.global_batch, shape.seq_len
    params_abs = train_lib.state_spec(cfg)["params"]
    p_sh = train_lib.state_shardings(cfg, mesh, rules)["params"]
    cache_abs = kvcache.cache_spec(cfg, b, s)
    cache_specs = kvcache.cache_partition_spec(cfg, b, s, mesh)
    cache_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cache_specs)
    dp = sharding.batch_spec(mesh, b)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    lens = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, dp)
    lens_sh = NamedSharding(mesh, dp)
    fn = serving_engine.make_decode(cfg)
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            fn, in_shardings=(p_sh, cache_sh, tok_sh, lens_sh),
            out_shardings=(NamedSharding(mesh, dp), cache_sh, lens_sh),
            donate_argnums=(1,))
        lowered = jitted.lower(params_abs, cache_abs, tok, lens)
        compiled = lowered.compile()
    return lowered, compiled


def lower_prefill_cell(cfg, shape, mesh, *, rules=None):
    b, s = shape.global_batch, shape.seq_len
    params_abs = train_lib.state_spec(cfg)["params"]
    p_sh = train_lib.state_shardings(cfg, mesh, rules)["params"]
    cache_specs = kvcache.cache_partition_spec(cfg, b, s, mesh)
    cache_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cache_specs)
    dp = sharding.batch_spec(mesh, b)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    fn = serving_engine.make_prefill(cfg, max_len=s, last_only=True)
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            fn, in_shardings=(p_sh, NamedSharding(mesh, dp)),
            out_shardings=(NamedSharding(mesh, dp), cache_sh,
                           NamedSharding(mesh, dp)))
        lowered = jitted.lower(params_abs, tok)
        compiled = lowered.compile()
    return lowered, compiled


def _lower_one(cfg, shape, mesh, *, grad_compress, rules):
    if shape.kind == "train":
        return lower_train_cell(cfg, shape, mesh,
                                grad_compress=grad_compress, rules=rules)
    if shape.kind == "decode":
        return lower_decode_cell(cfg, shape, mesh, rules=rules)
    return lower_prefill_cell(cfg, shape, mesh, rules=rules)


def _cost_triple(compiled, default_group):
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    wire = sum(o.wire_bytes
               for o in roofline.parse_collectives(hlo, default_group))
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), wire)


def extrapolated_cost(cfg, shape, mesh, *, grad_compress, rules,
                      default_group):
    """Depth-variant unrolled lowering -> exact (flops, bytes, wire).

    All internal scans (attention kv loop, ssm/mlstm chunks, MoE dispatch
    groups — 32 groups at token_chunk=32768) unroll in the variants, so
    every iteration is counted at the deployed configuration.
    """
    variants, full = extrapolate.depth_variants(cfg)
    samples = []
    for vcfg, counts in variants:
        _, c = _lower_one(vcfg, shape, mesh, grad_compress=grad_compress,
                          rules=rules)
        triple = _cost_triple(c, default_group)
        samples.append((counts, triple))
    out = []
    for i in range(3):
        out.append(extrapolate.solve_and_extrapolate(
            [(c, v[i]) for c, v in samples], full))
    out[0] += extrapolate.slstm_recurrent_flops(
        cfg, shape, train=(shape.kind == "train"))
    return tuple(out)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             grad_compress: str = "none", rules=None, save: bool = True,
             tag: str = "", exact_cost: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    import dataclasses as dc
    cfg = configs.get(arch)
    if cfg_overrides:
        moe_kw = {k[4:]: v for k, v in cfg_overrides.items()
                  if k.startswith("moe_")}
        top = {k: v for k, v in cfg_overrides.items()
               if not k.startswith("moe_")}
        if moe_kw and cfg.moe is not None:
            top["moe"] = dc.replace(cfg.moe, **moe_kw)
        cfg = dc.replace(cfg, **top)
    shape = configs.SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, compiled = _lower_one(cfg, shape, mesh,
                                   grad_compress=grad_compress, rules=rules)
    compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    hlo = compiled.as_text()
    chips = int(np.prod(mesh.devices.shape))
    raw_cost = dict(cost)
    wire_override = None
    if exact_cost:
        t1 = time.time()
        fx, bx, wx = extrapolated_cost(
            cfg, shape, mesh, grad_compress=grad_compress, rules=rules,
            default_group=_default_group(mesh))
        cost = {"flops": fx, "bytes accessed": bx}
        wire_override = wx
        extrap_s = time.time() - t1
    cache_b = (kvcache.cache_bytes(cfg, shape.global_batch, shape.seq_len)
               if shape.kind in ("decode", "prefill") else 0)
    mem_model = memory_model.analytic_memory_bytes(cfg, shape, mesh,
                                                   cache_bytes=cache_b)
    report = roofline.analyze(
        arch=arch, shape=shape_name, mesh_desc=_mesh_desc(mesh), chips=chips,
        cost=cost, hlo_text=hlo,
        model_flops_global=roofline.model_flops(cfg, shape),
        memory_stats=mem_stats, default_group=_default_group(mesh),
        wire_bytes_override=wire_override,
        model_bytes_per_device=mem_model)
    out = json.loads(report.to_json())
    out["compile_s"] = compile_s
    out["grad_compress"] = grad_compress
    out["tag"] = tag
    if exact_cost:
        out["raw_scanned_cost"] = {
            "flops": raw_cost.get("flops"),
            "bytes_accessed": raw_cost.get("bytes accessed")}
        out["extrapolate_s"] = extrap_s
    if save:
        os.makedirs(ARTIFACTS, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(
            ARTIFACTS,
            f"{arch}__{shape_name}__{_mesh_desc(mesh)}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
    return out


def cells_for(arch: str) -> list[str]:
    return [s.name for s in configs.cells(arch)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--rules", default="default",
                    choices=list(sharding.RULE_SETS))
    ap.add_argument("--moe-grouped", action="store_true",
                    help="grouped DP-local MoE dispatch (hillclimb)")
    ap.add_argument("--n-groups", type=int, default=16)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rules = sharding.RULE_SETS[args.rules]
    overrides = ({"moe_grouped_dispatch": True, "moe_n_groups": args.n_groups}
                 if args.moe_grouped else None)

    archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(arch)
        for shape_name in shapes:
            for multi in meshes:
                desc = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
                try:
                    out = run_cell(arch, shape_name, multi,
                                   grad_compress=args.grad_compress,
                                   rules=rules, tag=args.tag,
                                   cfg_overrides=overrides)
                    print(f"OK   {desc}: step={out['step_s']*1e3:.2f}ms "
                          f"bottleneck={out['bottleneck']} "
                          f"frac={out['roofline_fraction']:.3f} "
                          f"compile={out['compile_s']:.0f}s")
                except Exception as e:  # noqa: BLE001
                    failures.append((desc, e))
                    print(f"FAIL {desc}: {type(e).__name__}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")
    print("all dry-run cells passed")


if __name__ == "__main__":
    main()
