"""Synthetic token pipeline with host prefetch + in-situ preprocessing hooks.

The paper's future-work section names "integrating pre-processing as an
in-situ task of AI training" — this pipeline is built that way: generation
(synthetic corpus), preprocessing (packing/shifting into (tokens, labels)),
and device transfer run on p_o host threads *ahead* of the device, via a
bounded prefetch queue (the same StagingBuffer semantics, direction
reversed). The training loop only ever blocks when the pipeline falls behind,
and that wait is telemetered (``data/wait``) like every other phase.

Synthetic corpus: deterministic per-step PRNG token draws with a Zipf-like
marginal (so compression benchmarks on token data see realistic skew), plus
the frontend-embedding stand-ins for [vlm]/[audio] archs.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.telemetry import Telemetry


@dataclass(frozen=True)
class BatchSpec:
    batch: int
    seq_len: int
    vocab_size: int
    frontend_tokens: int = 0
    d_model: int = 0


def batch_spec_for(cfg: ModelConfig, shape: ShapeConfig) -> BatchSpec:
    return BatchSpec(shape.global_batch, shape.seq_len, cfg.vocab_size,
                     cfg.frontend_tokens if cfg.frontend else 0, cfg.d_model)


def synth_batch(spec: BatchSpec, step: int, seed: int = 0) -> dict[str, np.ndarray]:
    """Deterministic synthetic batch for one step (host-side numpy)."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    # Zipf-ish skew via squared uniform — cheap and stationary
    u = rng.random((spec.batch, spec.seq_len + 1))
    toks = (u * u * spec.vocab_size).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if spec.frontend_tokens:
        batch["prefix"] = rng.standard_normal(
            (spec.batch, spec.frontend_tokens, spec.d_model)).astype(np.float32)
    return batch


class Prefetcher:
    """Background producer of preprocessed batches (p_o-side threads)."""

    def __init__(self, spec: BatchSpec, *, depth: int = 2, seed: int = 0,
                 n_threads: int = 1, telemetry: Optional[Telemetry] = None,
                 preprocess=None) -> None:
        self.spec = spec
        self.seed = seed
        self.preprocess = preprocess
        self._telemetry = telemetry
        self._q: "queue.Queue[tuple[int, dict]]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = 0
        self._lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._produce, name=f"data-{i}", daemon=True)
            for i in range(n_threads)]
        for t in self._threads:
            t.start()

    def _produce(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                step = self._next
                self._next += 1
            batch = synth_batch(self.spec, step, self.seed)
            if self.preprocess is not None:
                batch = self.preprocess(step, batch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        t0 = time.perf_counter()
        step, batch = self._q.get()
        t1 = time.perf_counter()
        if self._telemetry is not None and t1 - t0 > 1e-5:
            self._telemetry.record("data/wait", t0, t1, step=step)
        return batch

    def close(self) -> None:
        self._stop.set()
        # unblock producers stuck on a full queue
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
