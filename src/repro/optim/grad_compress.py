"""Cross-pod gradient compression (beyond-paper; the paper's codec idea
applied to the collective layer).

The multi-pod mesh's 'pod' axis rides the slow inter-pod link, so the
cross-pod gradient all-reduce is the collective-bound roofline term of
multi-pod training. This module shrinks its wire bytes:

  none  : plain psum (autodiff default) — f32/bf16 operands
  bf16  : pmean on bf16 operands (2x vs f32)
  int8  : error-feedback int8 ring all-reduce — a shared global scale (one
          scalar pmax) quantizes each pod's local gradient to int8; a
          ppermute ring exchanges *int8* payloads (visible as 1-byte
          collective-permute operands in the compiled HLO — 4x fewer wire
          bytes than f32, 2x fewer than bf16), accumulating locally in f32.

Usage: the train step wraps its grad computation in a *partially-manual*
``jax.shard_map`` (manual over 'pod' only, 'data'/'model' stay automatic).
Within-pod reductions stay exact psums on fast ICI; only the slow axis is
compressed. The quantization residual is returned for error feedback (the
EF-SGD argument: compression error is delayed, not dropped, so it does not
bias convergence).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

PyTree = Any

METHODS = ("none", "bf16", "int8")


def pod_size(mesh: Mesh, axis: str = "pod") -> int:
    if axis not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def int8_ring_mean(x: jax.Array, axis: str, n: int) -> jax.Array:
    """Mean over manual mesh axis ``axis``; int8 payloads on the wire."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)          # tiny f32 collective
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    acc = q.astype(jnp.float32)
    perm = [(i, (i + 1) % n) for i in range(n)]
    buf = q
    for _ in range(n - 1):
        buf = jax.lax.ppermute(buf, axis, perm)             # int8 on the wire
        acc = acc + buf.astype(jnp.float32)
    return acc * (scale / n)


def reduce_leaf(g: jax.Array, *, method: str, axis: str, n: int) -> jax.Array:
    """Cross-pod mean of one gradient leaf inside a manual-over-pod region."""
    if method == "none" or n <= 1:
        return jax.lax.pmean(g, axis)
    if method == "bf16":
        return jax.lax.pmean(g.astype(jnp.bfloat16), axis).astype(g.dtype)
    if method == "int8":
        return int8_ring_mean(g.astype(jnp.float32), axis, n).astype(g.dtype)
    raise ValueError(f"unknown grad-compression method {method!r}")


def tree_reduce(grads: PyTree, *, method: str, axis: str, n: int) -> PyTree:
    return jax.tree.map(
        lambda g: reduce_leaf(g, method=method, axis=axis, n=n), grads)


# -- error feedback ------------------------------------------------------------

def ef_init(params: PyTree) -> PyTree:
    """Residual buffer, bf16 (it stores already-small quantization leftovers)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def ef_pre(grads: PyTree, residual: PyTree) -> PyTree:
    """Add the carried residual before compression."""
    return jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, residual)


def ef_post(grads_pre: PyTree, grads_reduced: PyTree) -> PyTree:
    """New residual = information the compressed reduction lost this step."""
    return jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
        .astype(jnp.bfloat16), grads_pre, grads_reduced)


# ---------------------------------------------------------------------------
# registry adapter: the int8 ring's wire format as a host-side Codec — the
# same global-amax scale + int8 quantization that rides the pod ring, framed
# for storage (gradient snapshots, wire-byte accounting in benchmarks).
# ---------------------------------------------------------------------------

import struct  # noqa: E402

import numpy as np  # noqa: E402

from repro.core import compression as _compression  # noqa: E402


class Int8WireCodec:
    lossy = True
    name = "int8-ef"

    def encode(self, arr: np.ndarray) -> bytes:
        from repro.core import codecs
        arr = np.asarray(arr, np.float32)
        amax = float(np.max(np.abs(arr))) if arr.size else 0.0
        scale = max(amax, 1e-30) / 127.0
        q = np.clip(np.round(arr / scale), -127, 127).astype(np.int8)
        framed, _ = codecs.encode(q, "zlib")
        return struct.pack("<d", scale) + framed

    def decode(self, blob: bytes) -> np.ndarray:
        from repro.core import codecs
        (scale,) = struct.unpack_from("<d", blob, 0)
        return codecs.decode(blob[8:]).astype(np.float32) * scale

    def error_bound(self) -> float:
        # max abs error is scale/2 = amax/254 per element; for any signal
        # with amax <= ~8 sigma that is rel-L2 <= 8/254 — round up.
        return 0.05


_compression.register(Int8WireCodec())
