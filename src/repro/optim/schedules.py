"""Learning-rate schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float, warmup: int, total: int,
                  floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def linear(step, *, peak: float, warmup: int, total: int):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    return jnp.where(step < warmup, warm, peak * (1 - t))


def constant(step, *, peak: float, **_):
    return jnp.full((), peak, jnp.float32)
