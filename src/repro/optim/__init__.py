from repro.optim.adamw import AdamWConfig, AdamWState, init, update
from repro.optim import schedules, grad_compress

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "schedules",
           "grad_compress"]
