"""Functional AdamW (pytree in / pytree out; jit/pjit-friendly).

Moments can be held in bf16 ("bf16_moments") — halves optimizer-state HBM and
checkpoint bytes; the update math still runs in f32. This is also what makes
the lossy-checkpoint policy sensible: moments are noise-dominated statistics,
the exact analog of the paper's "discard all but the energetic motions".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    count: jax.Array          # () int32
    mu: PyTree                # first moment
    nu: PyTree                # second moment
    master: PyTree | None     # f32 master weights (None when params are f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    bf16_moments: bool = True
    master_weights: bool = False   # keep f32 masters when params are bf16


def init(params: PyTree, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.bfloat16 if cfg.bf16_moments else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    master = None
    if cfg.master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params),
                      master)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads: PyTree, state: AdamWState, params: PyTree,
           cfg: AdamWConfig, lr: Optional[jax.Array] = None
           ) -> tuple[PyTree, AdamWState]:
    """Returns (new_params, new_state). lr overrides cfg.lr (schedules)."""
    lr = cfg.lr if lr is None else lr
    count = state.count + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, v, pm):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1.0 - cfg.b1)
        # max(v, 0): a lossy-restored second moment (error-bounded spectral
        # codec on checkpoint moments) may carry eps-scale negative values;
        # sqrt of those would poison the whole update with nan.
        v32 = jnp.maximum(v.astype(jnp.float32), 0.0) * cfg.b2 \
            + g * g * (1.0 - cfg.b2)
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = pm if pm is not None else p.astype(jnp.float32)
        if cfg.weight_decay > 0:
            upd = upd + cfg.weight_decay * p32
        p32_new = p32 - lr * upd
        out = (p32_new.astype(p.dtype), m32.astype(m.dtype),
               v32.astype(v.dtype))
        return out + ((p32_new,) if pm is not None else ())

    masters = state.master
    if masters is None:
        out = jax.tree.map(lambda p, g, m, v: leaf(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    else:
        out = jax.tree.map(leaf, params, grads, state.mu, state.nu, masters)
    is_t = lambda t: isinstance(t, tuple)
    p_new = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
    master = (jax.tree.map(lambda t: t[3], out, is_leaf=is_t)
              if masters is not None else None)
    return p_new, AdamWState(count, mu, nu, master)
