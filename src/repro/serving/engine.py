"""Serving: prefill + single-token decode for every assigned family.

``make_prefill`` / ``make_decode`` build jit-able step functions with
functional cache semantics:

  prefill(params, tokens)                  -> (logits, cache, lengths)
  decode (params, cache, tokens, lengths)  -> (logits, cache, lengths+1)

Cache convention: ``lengths`` counts tokens already *in* the cache. Decode
inserts the new token at slot ``lengths`` (ring slot ``lengths % window`` for
SWA layers), attends over ``lengths+1`` entries, and returns ``lengths+1``.

Decode is a lax.scan over (stacked layer params, stacked cache) pairs — one
compiled block body regardless of depth, same trick as training. The
ServingEngine below adds batched request slots on top (admit / step / drain),
and exposes an in-situ provider (serving-state snapshots for the engine's
compression tasks, the paper's checkpoint analog on the inference side).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import hymba as hymba_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import embed, mlp, rmsnorm, unembed
from repro.models.transformer import project_qkv
from repro.serving import kvcache

PyTree = Any


# ---------------------------------------------------------------------------
# per-family decode blocks (x: (B,1,d))
# ---------------------------------------------------------------------------

def _insert_at(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """cache (B,S,...) <- new (B,...) at per-batch slot idx (B,)."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), idx].set(new.astype(cache.dtype))


def _gqa_decode_attn(p, xn, cfg: ModelConfig, kv, lengths, *, window=0):
    """kv: {'k','v'} (B,S,N,hd). Returns (attn_out, new kv)."""
    ring = window > 0 and kv["k"].shape[1] == window
    pos = lengths[:, None]                       # rope position of new token
    q, k, v = project_qkv(p, xn, cfg, pos)
    slot = lengths % kv["k"].shape[1] if ring else lengths
    kc = _insert_at(kv["k"], k[:, 0], slot)
    vc = _insert_at(kv["v"], v[:, 0], slot)
    o = attn_lib.decode_attention(q, kc, vc, lengths + 1,
                                  window=window, ring=ring)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": kc, "v": vc}


def _mla_decode_attn(p, xn, cfg: ModelConfig, kv, lengths):
    pos = lengths[:, None]
    ckv_new, krope_new = mla_lib.mla_new_cache_entry(p, xn, cfg, pos)
    ckv = _insert_at(kv["ckv"], ckv_new[:, 0], lengths)
    krope = _insert_at(kv["krope"], krope_new[:, 0], lengths)
    o = mla_lib.mla_decode(p, xn, cfg, ckv, krope, lengths + 1)
    return o, {"ckv": ckv, "krope": krope}


def _dense_decode_block(p, x, cfg, kv, lengths, *, window=0):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = _mla_decode_attn(p["attn"], xn, cfg, kv, lengths)
    else:
        a, kv = _gqa_decode_attn(p["attn"], xn, cfg, kv, lengths,
                                 window=window)
    x = x + a
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn), kv


def _moe_decode_block(p, x, cfg, kv, lengths):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = _mla_decode_attn(p["attn"], xn, cfg, kv, lengths)
    else:
        a, kv = _gqa_decode_attn(p["attn"], xn, cfg, kv, lengths)
    x = x + a
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    y, _ = moe_lib.moe_ffn(p["moe"], xn, cfg)
    return x + y, kv


def _hybrid_decode_block(p, x, cfg, kv, ssm_state, lengths, *, window=0):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, kv = _gqa_decode_attn(p["attn"], xn, cfg, kv, lengths, window=window)
    s, ssm_state = ssm_lib.ssm_decode(p["ssm"], xn, cfg, ssm_state)
    x = x + hymba_lib.fuse(p["fusion"], a, s, cfg)
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn), kv, ssm_state


# ---------------------------------------------------------------------------
# decode step builders
# ---------------------------------------------------------------------------

def _maybe_scan(step, carry, xs, use_scan: bool):
    """lax.scan or an unrolled python loop over the leading axis of xs."""
    if use_scan:
        return jax.lax.scan(step, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = step(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


def _scan_decode(stacked_params, cache, h, lengths, body, use_scan=True):
    """Scan one block body over (params, cache) stacks; returns (h, cache)."""
    def step(carry, xs):
        p_layer, kv_layer = xs
        carry, kv_new = body(carry, p_layer, kv_layer)
        return carry, kv_new

    h, new_cache = _maybe_scan(step, h, (stacked_params, cache), use_scan)
    return h, new_cache


def make_decode(cfg: ModelConfig) -> Callable:
    """decode(params, cache, tokens (B,1), lengths (B,)) -> (logits, cache, lengths)."""

    def decode(params, cache, tokens, lengths):
        h = embed(params["embed"], tokens)

        if cfg.family in ("dense", "audio", "vlm"):
            body = lambda x, p, kv: _dense_decode_block(p, x, cfg, kv, lengths)
            h, kv = _scan_decode(params["blocks"], cache["kv"], h, lengths,
                                 body, use_scan=cfg.scan_layers)
            cache = {"kv": kv}

        elif cfg.family == "moe":
            m = cfg.moe
            new_cache = {}
            kv = cache["kv"]
            split = lambda t: (jax.tree.map(lambda a: a[:m.first_dense], t),
                               jax.tree.map(lambda a: a[m.first_dense:], t))
            kv_d, kv_m = split(kv) if m.first_dense else (None, kv)
            if m.first_dense:
                body_d = lambda x, p, k: _dense_decode_block(p, x, cfg, k, lengths)
                h, kv_d = _scan_decode(params["dense_blocks"], kv_d, h,
                                       lengths, body_d,
                                       use_scan=cfg.scan_layers)
            body_m = lambda x, p, k: _moe_decode_block(p, x, cfg, k, lengths)
            h, kv_m = _scan_decode(params["moe_blocks"], kv_m, h, lengths,
                                   body_m, use_scan=cfg.scan_layers)
            joined = (jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                   kv_d, kv_m) if m.first_dense else kv_m)
            cache = {"kv": joined}

        elif cfg.family == "hybrid":
            h, cache = _hybrid_decode(params, cfg, cache, h, lengths)

        elif cfg.family == "ssm":
            h, cache = _xlstm_decode(params, cfg, cache, h)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg.vocab_size)
        return logits, cache, lengths + 1

    return decode


def _hybrid_decode(params, cfg, cache, h, lengths):
    gids = set(hymba_lib.global_layer_ids(cfg))
    kinds = ["g" if i in gids else "s" for i in range(cfg.n_layers)]
    g_idx = s_idx = 0
    new_g_kv, new_s_kv, new_g_ssm, new_s_ssm = [], [], [], []
    i = 0
    while i < cfg.n_layers:
        j = i
        while j < cfg.n_layers and kinds[j] == kinds[i]:
            j += 1
        count = j - i
        is_g = kinds[i] == "g"
        idx0 = g_idx if is_g else s_idx
        pkey = "global_blocks" if is_g else "swa_blocks"
        kkey = "global_kv" if is_g else "swa_kv"
        skey = "ssm_global" if is_g else "ssm_swa"
        win = 0 if is_g else cfg.swa_window
        part_p = jax.tree.map(lambda t: t[idx0:idx0 + count], params[pkey])
        part_kv = jax.tree.map(lambda t: t[idx0:idx0 + count], cache[kkey])
        part_ssm = jax.tree.map(lambda t: t[idx0:idx0 + count], cache[skey])

        def step(carry, xs, win=win):
            p_layer, kv_layer, ssm_layer = xs
            x, kv, ssm = _hybrid_decode_block(
                p_layer, carry, cfg, kv_layer, ssm_layer, lengths, window=win)
            return x, (kv, ssm)

        h, (kv_new, ssm_new) = _maybe_scan(
            step, h, (part_p, part_kv, part_ssm), cfg.scan_layers)
        (new_g_kv if is_g else new_s_kv).append(kv_new)
        (new_g_ssm if is_g else new_s_ssm).append(ssm_new)
        if is_g:
            g_idx += count
        else:
            s_idx += count
        i = j

    cat = lambda parts: jax.tree.map(
        lambda *xs: jnp.concatenate(xs), *parts) if len(parts) > 1 else parts[0]
    cache = {"global_kv": cat(new_g_kv), "swa_kv": cat(new_s_kv),
             "ssm_global": cat(new_g_ssm), "ssm_swa": cat(new_s_ssm)}
    return h, cache


def _xlstm_decode(params, cfg, cache, h):
    def super_step(carry, xs):
        p_super, st_super = xs

        def m_step(c, mx):
            p_layer, st_layer = mx
            c, st_new = xlstm_lib.mlstm_decode(p_layer, c, cfg, st_layer)
            return c, st_new

        carry, m_new = _maybe_scan(
            m_step, carry, (p_super["mlstm"], st_super["mlstm"]),
            cfg.scan_layers)
        carry, s_new = xlstm_lib.slstm_decode(
            p_super["slstm"], carry, cfg, st_super["slstm"])
        return carry, {"mlstm": m_new, "slstm": s_new}

    h, new_state = _maybe_scan(
        super_step, h, (params["super"], cache), cfg.scan_layers)
    return h, new_state


# ---------------------------------------------------------------------------
# prefill builders (build the cache from a whole prompt)
# ---------------------------------------------------------------------------

def _gqa_prefill_attn(p, xn, cfg, positions, *, window, max_len):
    q, k, v = project_qkv(p, xn, cfg, positions)
    o = attn_lib.flash_attention(q, k, v, causal=True, window=window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 unroll=cfg.unroll_scans)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    b, s = xn.shape[:2]
    if window and max_len == window:
        # ring layout: keep the last ``window`` entries in ring order
        # slot of token t is t % window; for a prompt of length s the ring
        # holds tokens s-window..s-1 — rotate so slots line up.
        t0 = max(0, s - window)
        kr = k[:, t0:]
        vr = v[:, t0:]
        pad = window - kr.shape[1]
        if pad:
            kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vr = jnp.pad(vr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        shift = t0 % window
        kr = jnp.roll(kr, shift, axis=1)
        vr = jnp.roll(vr, shift, axis=1)
        return out, {"k": kr, "v": vr}
    pad = max_len - s
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": k, "v": v}


def _mla_prefill_attn(p, xn, cfg, positions, *, max_len):
    out = mla_lib.mla_attention(p, xn, cfg, positions)
    ckv, krope = mla_lib.mla_new_cache_entry(p, xn, cfg, positions)
    pad = max_len - xn.shape[1]
    if pad > 0:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        krope = jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))
    return out, {"ckv": ckv, "krope": krope}


def make_prefill(cfg: ModelConfig, max_len: int,
                 last_only: bool = False) -> Callable:
    """prefill(params, tokens (B,S)[, last_pos]) -> (logits, cache, lengths).

    ``last_only`` returns logits for the final position only — the serving
    path (avoids materializing (B,S,V), which at 32k x 152k vocab would be
    hundreds of GB). ``last_pos`` (traced) selects position ``last_pos-1``
    instead of ``-1`` — for callers that right-pad every prompt to one
    canonical width so all prefills share a single compiled shape (XLA
    kernel rounding is shape-dependent, so one shape is what makes a
    shared-prefix admit bitwise equal to an unshared one).
    """

    def prefill(params, tokens, last_pos=None):
        h = embed(params["embed"], tokens)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        lengths = jnp.full((b,), s, jnp.int32)

        if cfg.family in ("dense", "audio", "vlm", "moe"):
            def block(x, p):
                xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
                if cfg.mla is not None:
                    a, kv = _mla_prefill_attn(p["attn"], xn, cfg, positions,
                                              max_len=max_len)
                else:
                    a, kv = _gqa_prefill_attn(p["attn"], xn, cfg, positions,
                                              window=0, max_len=max_len)
                x = x + a
                xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
                if "moe" in p:
                    y, _ = moe_lib.moe_ffn(p["moe"], xn, cfg)
                else:
                    y = mlp(p["mlp"], xn)
                return x + y, kv

            if cfg.family == "moe" and cfg.moe.first_dense:
                h, kv_d = _maybe_scan(block, h, params["dense_blocks"],
                                      cfg.scan_layers)
                h, kv_m = _maybe_scan(block, h, params["moe_blocks"],
                                      cfg.scan_layers)
                kv = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                                  kv_d, kv_m)
            elif cfg.family == "moe":
                h, kv = _maybe_scan(block, h, params["moe_blocks"],
                                    cfg.scan_layers)
            else:
                h, kv = _maybe_scan(block, h, params["blocks"],
                                    cfg.scan_layers)
            cache = {"kv": kv}

        elif cfg.family == "hybrid":
            h, cache = _hybrid_prefill(params, cfg, h, positions, max_len)

        elif cfg.family == "ssm":
            h, cache = _xlstm_prefill(params, cfg, h)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        if last_only:
            if last_pos is not None:
                h = jax.lax.dynamic_slice_in_dim(h, last_pos - 1, 1, axis=1)
            else:
                h = h[:, -1:]
        logits = unembed(params["embed"], h, cfg.vocab_size)
        return logits, cache, lengths

    return prefill


def _hybrid_prefill(params, cfg, h, positions, max_len):
    gids = set(hymba_lib.global_layer_ids(cfg))
    kinds = ["g" if i in gids else "s" for i in range(cfg.n_layers)]
    win = min(cfg.swa_window, max_len)

    def block(x, p, window, kv_len):
        xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, kv = _gqa_prefill_attn(p["attn"], xn, cfg, positions,
                                  window=window, max_len=kv_len)
        s_out, ssm_state = ssm_lib.ssm_mixer(p["ssm"], xn, cfg,
                                             return_state=True)
        x = x + hymba_lib.fuse(p["fusion"], a, s_out, cfg)
        xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], xn), (kv, ssm_state)

    g_idx = s_idx = 0
    g_kv, s_kv, g_ssm, s_ssm = [], [], [], []
    i = 0
    while i < cfg.n_layers:
        j = i
        while j < cfg.n_layers and kinds[j] == kinds[i]:
            j += 1
        count = j - i
        is_g = kinds[i] == "g"
        idx0 = g_idx if is_g else s_idx
        pkey = "global_blocks" if is_g else "swa_blocks"
        part_p = jax.tree.map(lambda t: t[idx0:idx0 + count], params[pkey])

        def step(carry, p_layer, is_g=is_g):
            x, out = block(carry, p_layer, 0 if is_g else cfg.swa_window,
                           max_len if is_g else win)
            return x, out

        h, (kv_new, ssm_new) = _maybe_scan(step, h, part_p,
                                           cfg.scan_layers)
        (g_kv if is_g else s_kv).append(kv_new)
        (g_ssm if is_g else s_ssm).append(ssm_new)
        if is_g:
            g_idx += count
        else:
            s_idx += count
        i = j

    cat = lambda parts: (jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
                         if len(parts) > 1 else parts[0])
    return h, {"global_kv": cat(g_kv), "swa_kv": cat(s_kv),
               "ssm_global": cat(g_ssm), "ssm_swa": cat(s_ssm)}


def _xlstm_prefill(params, cfg, h):
    def super_step(carry, p_super):
        def m_step(c, p_layer):
            c, st = xlstm_lib.mlstm_mixer(p_layer, c, cfg, return_state=True)
            return c, st

        carry, m_states = _maybe_scan(m_step, carry, p_super["mlstm"],
                                      cfg.scan_layers)
        carry, s_state = xlstm_lib.slstm_mixer(p_super["slstm"], carry, cfg)
        return carry, {"mlstm": m_states, "slstm": s_state}

    h, cache = _maybe_scan(super_step, h, params["super"],
                           cfg.scan_layers)
    return h, cache


# ---------------------------------------------------------------------------
# batched-request engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _checked_prompt(req: Request, prompt_len: int) -> np.ndarray:
    """Clip a prompt to the engine window, loudly.

    Dropping leading tokens changes the completion, so it must never happen
    silently — the warning names the request and both lengths so the caller
    can resize the window or chunk the prompt.
    """
    prompt = np.asarray(req.prompt)
    if prompt.shape[-1] > prompt_len:
        warnings.warn(
            f"request {req.rid}: prompt length {prompt.shape[-1]} exceeds "
            f"the engine prompt window ({prompt_len}); keeping only the "
            f"last {prompt_len} tokens", RuntimeWarning, stacklevel=3)
        prompt = prompt[-prompt_len:]
    return prompt


class ServingEngine:
    """Slot-based batched serving with greedy decode (framework example).

    All slots share one jitted decode step; prefill runs per-request (padded
    to the slot prompt window). In-situ providers expose the serving state
    for the engine's compression/analytics tasks.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 prompt_len: int = 64, max_len: int = 256) -> None:
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.cache = kvcache.init_cache(cfg, slots, max_len)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.active: list[Optional[Request]] = [None] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(make_decode(cfg))
        self._prefill_one = jax.jit(make_prefill(cfg, max_len,
                                                 last_only=True))
        # page-dirty hint for the snapshot store: every cache mutation
        # (admit prefill, decode step) bumps the version. An idle engine's
        # version is stable, so an unchanged snapshot firing
        # short-circuits to a no-op frame; finer per-page change
        # detection is the delta codec's per-chunk COPY op.
        self._state_version = 0

    def admit(self, req: Request) -> bool:
        for i, a in enumerate(self.active):
            if a is None:
                self.active[i] = req
                prompt = _checked_prompt(req, self.prompt_len)
                toks = jnp.asarray(prompt, jnp.int32)[None, :]
                logits, cache1, _ = self._prefill_one(self.params, toks)
                # merge slot i of the batch cache from the single-row cache
                self.cache = jax.tree.map(
                    lambda full, one: _set_batch_slot(full, one, i,
                                                      self.cfg),
                    self.cache, cache1)
                # host already knows the prompt length — no device sync
                self.lengths = self.lengths.at[i].set(len(prompt))
                nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
                self.tokens = self.tokens.at[i, 0].set(nxt)
                req.out.append(int(nxt))
                self._state_version += 1
                return True
        return False

    def step(self) -> None:
        logits, self.cache, self.lengths = self._decode(
            self.params, self.cache, self.tokens, self.lengths)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        self._state_version += 1
        nxt_host = np.asarray(nxt)   # ONE device->host transfer per step
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt_host[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
                self.lengths = self.lengths.at[i].set(0)

    @property
    def state_version(self) -> int:
        """Monotonic cache-mutation counter (bumps on admit and decode)."""
        return self._state_version

    def snapshot_payload(self) -> dict[str, Any]:
        """The serve_snapshot payload: the KV slab plus its version hint.

        The hint lets an unchanged firing (idle engine between snapshot
        periods) short-circuit to a no-op frame in the snapshot store
        without touching the slab.
        """
        return {"cache": self.cache, "version": self._state_version}

    def insitu_providers(self) -> dict[str, Callable[[], Any]]:
        return {"serving_state": lambda: self.cache,
                "lengths": lambda: self.lengths,
                "kv_snapshot": lambda: self.snapshot_payload()}

    def run(self, requests: list[Request], max_steps: int = 512) -> None:
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if not pending and all(a is None for a in self.active):
                return
            if any(a is not None for a in self.active):
                self.step()


def _set_batch_slot(full, one, i, cfg):
    """Write batch row(s) of a single-request cache into slot i.

    Cache leaves have layout (L, B, ...) or (L, L2, B, ...) for xlstm mlstm
    stacks — the batch axis is the first axis of size matching ``one``'s.
    """
    # find the batch axis: the axis where one.shape[k] == 1 and
    # full.shape[k] == slots, scanning after leading layer axes
    for ax in range(full.ndim):
        if one.shape[ax] == 1 and full.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * full.ndim
            idx[ax] = i
            src = jnp.squeeze(one, axis=ax)
            return full.at[tuple(idx)].set(src.astype(full.dtype))
    # shapes already equal (e.g. slots==1)
    return one.astype(full.dtype)
