"""Per-arch serving-state layouts (KV cache / latent cache / SSM state).

Layout notes per family (the arch-level data-reduction story that parallels
the paper's in-situ compression):

  dense GQA        : k/v (L, B, S, N, hd) — N = kv heads (GQA shrinks the
                     cache by heads/N vs MHA).
  MLA (deepseek)   : latent c_kv (L, B, S, kv_lora=512) + shared rope key
                     (L, B, S, qk_rope=64) — 576 floats/token/layer instead
                     of 128 heads x (128+64+128); ~71x smaller, which is what
                     makes the 671B decode shapes feasible at all.
  SWA (hymba)      : ring buffer (L, B, window, N, hd) — bounded for
                     long_500k; plus per-layer SSM state (h, conv).
  ssm (xlstm)      : O(1) recurrent state per block (mLSTM matrix memory C,
                     normalizer n, stabilizer m, conv taps; sLSTM c/n/m/h).

``init_cache`` returns concrete zeros (engine), ``cache_spec`` returns
ShapeDtypeStructs (dry-run), ``cache_partition_spec`` returns PartitionSpecs
(batch over data axes, kv-heads over model when divisible).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import hymba as hymba_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.distributed import sharding

PyTree = Any


def _gqa_kv(cfg: ModelConfig, layers: int, batch: int, seq: int):
    hd = cfg.resolved_head_dim
    shape = (layers, batch, seq, cfg.n_kv_heads, hd)
    axes = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": (shape, axes, cfg.dtype), "v": (shape, axes, cfg.dtype)}


def _mla_kv(cfg: ModelConfig, layers: int, batch: int, seq: int):
    m = cfg.mla
    return {
        "ckv": ((layers, batch, seq, m.kv_lora),
                ("layers", "batch", "seq", None), cfg.dtype),
        "krope": ((layers, batch, seq, m.qk_rope),
                  ("layers", "batch", "seq", None), cfg.dtype),
    }


def _ssm_state(cfg: ModelConfig, layers: int, batch: int):
    s = cfg.ssm
    di = ssm_lib.d_inner(cfg)
    return {
        "h": ((layers, batch, di, s.d_state),
              ("layers", "batch", "mlp", "state"), "float32"),
        "conv": ((layers, batch, s.d_conv - 1, di),
                 ("layers", "batch", "conv", "mlp"), cfg.dtype),
    }


def _xlstm_state(cfg: ModelConfig, batch: int):
    x = cfg.xlstm
    n_super = cfg.n_layers // x.slstm_every
    per = x.slstm_every - 1
    _, m_inner, nh, m_dh = xlstm_lib._dims(cfg)
    conv_k = x.conv_kernel
    s_dh = cfg.d_model // nh
    neg = -1e30
    return {
        "mlstm": {
            "c": ((n_super, per, batch, nh, m_dh, m_dh),
                  ("layers", "layers", "batch", "heads", None, None), "float32"),
            "n": ((n_super, per, batch, nh, m_dh),
                  ("layers", "layers", "batch", "heads", None), "float32"),
            "m": ((n_super, per, batch, nh),
                  ("layers", "layers", "batch", "heads"), "float32", neg),
            "conv": ((n_super, per, batch, conv_k - 1, m_inner),
                     ("layers", "layers", "batch", "conv", "mlp"), cfg.dtype),
        },
        "slstm": {
            "c": ((n_super, batch, nh, s_dh),
                  ("layers", "batch", "heads", None), "float32"),
            "n": ((n_super, batch, nh, s_dh),
                  ("layers", "batch", "heads", None), "float32"),
            "m": ((n_super, batch, nh, s_dh),
                  ("layers", "batch", "heads", None), "float32", neg),
            "h": ((n_super, batch, nh, s_dh),
                  ("layers", "batch", "heads", None), cfg.dtype),
        },
    }


def cache_layout(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Tree of (shape, logical_axes, dtype) descriptors."""
    if cfg.family in ("dense", "audio", "vlm"):
        if cfg.mla is not None:
            return {"kv": _mla_kv(cfg, cfg.n_layers, batch, max_len)}
        return {"kv": _gqa_kv(cfg, cfg.n_layers, batch, max_len)}
    if cfg.family == "moe":
        if cfg.mla is not None:
            return {"kv": _mla_kv(cfg, cfg.n_layers, batch, max_len)}
        return {"kv": _gqa_kv(cfg, cfg.n_layers, batch, max_len)}
    if cfg.family == "hybrid":
        n_global = len(hymba_lib.global_layer_ids(cfg))
        n_swa = cfg.n_layers - n_global
        win = min(cfg.swa_window, max_len)
        return {
            "global_kv": _gqa_kv(cfg, n_global, batch, max_len),
            "swa_kv": _gqa_kv(cfg, n_swa, batch, win),
            "ssm_global": _ssm_state(cfg, n_global, batch),
            "ssm_swa": _ssm_state(cfg, n_swa, batch),
        }
    if cfg.family == "ssm":
        return _xlstm_state(cfg, batch)
    raise ValueError(cfg.family)


def paged_cache_layout(cfg: ModelConfig, num_pages: int, page_size: int,
                       max_reqs: int, max_len: int) -> tuple[PyTree, PyTree]:
    """Split :func:`cache_layout` into (pool_layout, state_layout).

    Leaves whose sequence axis spans ``max_len`` become block-indexed page
    pools shared by every request: ``(layers, batch, max_len, ...)`` turns
    into ``(layers, num_pages, page_size, ...)``. Everything else — SWA ring
    buffers (bounded at ``window``, already the smaller footprint), SSM /
    xLSTM recurrent state (O(1) per request) — stays a dense per-row slab
    with ``batch=max_reqs``; paging fixed-size state would add indirection
    and save nothing. Either side may be ``{}`` (ssm family has no pool;
    dense/moe have no state).
    """
    is_desc = lambda x: (isinstance(x, tuple) and len(x) in (3, 4)
                         and isinstance(x[0], tuple))

    def page_desc(d):
        shape, axes = d[0], d[1]
        assert axes[:3] == ("layers", "batch", "seq"), axes
        new_shape = (shape[0], num_pages, page_size) + shape[3:]
        new_axes = ("layers", "pages", "page_slot") + axes[3:]
        return (new_shape, new_axes) + d[2:]

    def split(node, in_ring):
        if is_desc(node):
            raise TypeError("cache_layout root must be a mapping")
        pool, state = {}, {}
        for k, v in node.items():
            # SWA ring buffers keep ring semantics (slot = t % window) even
            # when window == max_len, so they are state by name, not shape.
            ring = in_ring or k == "swa_kv"
            if is_desc(v):
                shape, axes = v[0], v[1]
                if (not ring and "seq" in axes
                        and shape[axes.index("seq")] == max_len):
                    pool[k] = page_desc(v)
                else:
                    state[k] = v
            else:
                p, s = split(v, ring)
                if p:
                    pool[k] = p
                if s:
                    state[k] = s
        return pool, state

    return split(cache_layout(cfg, max_reqs, max_len), False)


def _map_layout(layout: PyTree, fn) -> PyTree:
    is_desc = lambda x: (isinstance(x, tuple) and len(x) in (3, 4)
                         and isinstance(x[0], tuple))
    return jax.tree.map(fn, layout, is_leaf=is_desc)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    def mk(d):
        fill = d[3] if len(d) == 4 else 0.0
        return jnp.full(d[0], fill, jnp.dtype(d[2]))
    return _map_layout(cache_layout(cfg, batch, max_len), mk)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     max_reqs: int, max_len: int) -> tuple[PyTree, PyTree]:
    """Concrete zeros for (page pool, per-row state)."""
    def mk(d):
        fill = d[3] if len(d) == 4 else 0.0
        return jnp.full(d[0], fill, jnp.dtype(d[2]))
    pool_l, state_l = paged_cache_layout(cfg, num_pages, page_size,
                                         max_reqs, max_len)
    return _map_layout(pool_l, mk), _map_layout(state_l, mk)


def chain_view(pool_kv: PyTree, page_ids) -> PyTree:
    """Gather one page chain back into token order, jit-traceable.

    pool leaf ``(layers, num_pages, page_size, ...)`` -> view
    ``(layers, 1, n*page_size, ...)`` — the single-request prefill cache
    layout, so a continuation prefill can attend over a resident shared
    prefix without the host ever materializing it.
    """
    def leaf(a):
        gathered = a[:, page_ids]                      # (L, n, ps, ...)
        return gathered.reshape(a.shape[0], -1, *a.shape[3:])[:, None]
    return jax.tree.map(leaf, pool_kv)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    return _map_layout(cache_layout(cfg, batch, max_len),
                       lambda d: jax.ShapeDtypeStruct(d[0], jnp.dtype(d[2])))


_CACHE_RULES = {
    "layers": None, "batch": "data", "seq": None, "kv_heads": "model",
    "heads": "model", "head_dim": None, "mlp": "model", "state": None,
    "conv": None, None: None,
}


def cache_partition_spec(cfg: ModelConfig, batch: int, max_len: int,
                         mesh: Mesh) -> PyTree:
    """Batch over ('pod','data'); kv-heads/mlp over 'model' when divisible.

    Fallback: when the kv-heads dim does not divide the model axis (GQA with
    few kv heads — most assigned archs at model=16), the *sequence* axis of
    that leaf takes 'model' instead (cache sequence-parallelism). This is
    what keeps e.g. qwen1.5-110b's 1.4 TB decode_32k cache at ~5 GB/chip.
    """
    rules = dict(_CACHE_RULES)
    rules["batch"] = sharding.dp_axes(mesh)
    sizes = dict(mesh.shape)   # works for Mesh and AbstractMesh
    model_size = sizes.get("model", 1)

    def leaf(d):
        shape, axes = d[0], d[1]
        rr = dict(rules)
        # does any 'model'-destined dim actually divide?
        model_ok = any(
            rr.get(a) == "model" and dim % model_size == 0
            for dim, a in zip(shape, axes))
        if not model_ok and "seq" in axes:
            i = axes.index("seq")
            if shape[i] % model_size == 0:
                rr["seq"] = "model"
        return sharding.spec_for(shape, axes, rr, mesh)

    return _map_layout(cache_layout(cfg, batch, max_len), leaf)


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    total = 0
    for d in jax.tree.leaves(
            cache_layout(cfg, batch, max_len),
            is_leaf=lambda x: (isinstance(x, tuple) and len(x) in (3, 4)
                               and isinstance(x[0], tuple))):
        total += int(np.prod(d[0])) * jnp.dtype(d[2]).itemsize
    return total
