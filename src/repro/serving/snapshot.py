"""Versioned serving snapshots: a base+delta chain per stream.

The serving loop used to compress the **full** KV slab on every
``serve_snapshot`` firing, even though decode mutates the slab
append-mostly (a few slots gain one token per step; everything else is
byte-identical). This module is the openPMD/ADIOS2 "chain incremental
updates through a versioned store" pattern for that path:

  * ``SnapshotStore`` keeps the last published snapshot per stream and
    encodes each new one as a *delta frame* against it
    (:mod:`repro.core.delta`: per-chunk XOR/COPY/SELF, riding the shared
    chunk-parallel codec pool).
  * Every ``base_every``-th publish writes a self-contained **base** frame;
    the frames between are **deltas** — restore replays base → deltas.
    Bounded chains bound both restore cost and the corruption blast radius.
  * A publish whose payload ``version`` hint is unchanged (see
    ``ServingEngine.insitu_providers``) short-circuits to a **no-op**
    frame — a ~30-byte marker, no slab walk at all — even past the base
    cadence (an idle engine never re-encodes; the next *changed* publish
    writes the due base).
  * Publishes are kept step-monotonic per stream: a late out-of-order
    firing (concurrent pool workers) is skipped as ``stale`` rather than
    regressing the chain tip to an older slab.
  * ``keep_chains=N`` retention prunes frames behind the N-th newest base
    when a base publishes (replay never needs them); ``None`` keeps every
    frame for arbitrary-prefix restores.
  * Chains are validated on restore: a truncated, corrupted, or missing
    frame raises :class:`SnapshotCorruptError` naming the chain position.

Frames live in memory (``directory=None`` — the in-process probe the
serving preset uses by default) or on disk, one file per frame, published
crash-safely (write tmp → fsync → rename → fsync dir, the checkpoint
protocol): a reader never observes a torn frame, and any published prefix
of the chain restores.

Frame file layout (``SNAP_MAGIC``, version 1)::

  magic | version | kind (base/delta/noop) | seq | chain_pos | step
        | n_leaves | body crc32
  body: per leaf  key_len | key | blob_len | delta-frame blob

``seq`` is the stream-global frame index (file order); ``chain_pos`` is
the distance to the owning base frame — restore checks it is contiguous,
so a deleted frame in the middle of a chain is detected, not silently
skipped.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional

import numpy as np

from repro.core import codecs, delta, transport

PyTree = Any

SNAP_MAGIC = b"RPSS"
_VERSION = 1
_HEADER_PREFIX = "<BBIIqI"      # version kind seq chain_pos step n_leaves
_HEADER = _HEADER_PREFIX + "I"  # ... + crc32(prefix + body)
_HEADER_SIZE = 4 + struct.calcsize(_HEADER)

KIND_BASE = 0
KIND_DELTA = 1
KIND_NOOP = 2
_KIND_NAMES = {KIND_BASE: "base", KIND_DELTA: "delta", KIND_NOOP: "noop"}


class SnapshotCorruptError(RuntimeError):
    """A snapshot chain failed validation; names the stream and the chain
    position (frame ``seq``) at fault."""

    def __init__(self, stream: str, position: Optional[int],
                 reason: str) -> None:
        at = ("chain position ?" if position is None
              else f"chain position {position}")
        super().__init__(f"snapshot stream {stream!r}, {at}: {reason}")
        self.stream = stream
        self.position = position


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    """Stable key -> contiguous host array mapping for one payload tree.

    Always copies: the store retains these arrays as the next publish's
    delta base, so it must own the bytes — callers (the serving loop, the
    benchmarks) mutate their slab in place between firings.
    """
    import jax

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out: dict[str, np.ndarray] = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = np.array(np.asarray(leaf),
                                                   order="C")
    return out


@dataclass
class SnapshotRecord:
    """What one ``publish`` did (the serve_snapshot task's sink result)."""
    stream: str
    step: int
    seq: int
    kind: str
    chain_pos: int
    raw_bytes: int
    stored_bytes: int

    @property
    def ratio(self) -> float:
        """Paper Eq. (1) for this frame alone."""
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.stored_bytes) / self.raw_bytes


@dataclass
class _StreamState:
    seq: int = 0                 # next frame index
    frames_since_base: int = -1  # -1: no base yet
    last_leaves: Optional[dict[str, np.ndarray]] = None
    last_version: Optional[int] = None
    last_raw: int = 0            # raw bytes of the last encoded publish
    last_step: Optional[int] = None
    last_kind: Optional[int] = None
    mem_frames: list[tuple[int, bytes]] = field(default_factory=list)
    publishes: int = 0
    bases: int = 0
    deltas: int = 0
    noops: int = 0
    stale: int = 0               # out-of-order publishes skipped
    raw_bytes: int = 0
    stored_bytes: int = 0


class SnapshotStore:
    """The versioned per-stream snapshot store (base+delta chains)."""

    def __init__(self, directory: Optional[str] = None, *,
                 base_every: int = 8, codec: str = "zlib",
                 chunk_bytes: int = codecs.DEFAULT_CHUNK,
                 parallel: bool = True,
                 keep_chains: Optional[int] = None,
                 mirror: Optional[Any] = None) -> None:
        if base_every < 1:
            raise ValueError(f"base_every must be >= 1, got {base_every}")
        if keep_chains is not None and keep_chains < 1:
            raise ValueError(f"keep_chains must be >= 1, got {keep_chains}")
        if codec not in codecs.available():
            raise KeyError(f"unknown inner codec {codec!r}; "
                           f"available: {codecs.available()}")
        self.directory = directory
        self.base_every = int(base_every)
        self.codec = codec
        self.chunk_bytes = int(chunk_bytes)
        self.parallel = parallel
        # retention: frames behind the keep_chains-th newest base are dead
        # weight (replay starts at the newest base) and are pruned when a
        # new base publishes. None keeps everything — archival stores and
        # the crash/bench suites that restore arbitrary prefixes.
        self.keep_chains = keep_chains
        self._streams: dict[str, _StreamState] = {}
        self._lock = threading.Lock()
        self._mirror: Optional[transport.Sink] = None
        self.mirror_frames = 0
        self.mirror_failures = 0
        if mirror is not None:
            self.set_mirror(mirror)
        if directory is not None:
            os.makedirs(directory, exist_ok=True)

    def set_mirror(self, sink: Any) -> None:
        """Attach a transport-backed publish target: every written frame's
        raw bytes are forwarded as a ``CODEC_RAW`` transport frame, so a
        remote replica can tail the delta chain live (``ingest`` on the
        consumer side rebuilds a bit-identical chain). Accepts a
        :class:`~repro.core.transport.Sink` or a transport URL. Mirroring
        is best-effort: a dead consumer counts ``mirror_failures`` instead
        of failing the local publish."""
        self._mirror = (transport.connect(sink) if isinstance(sink, str)
                        else sink)

    def close_mirror(self) -> None:
        if self._mirror is not None:
            try:
                self._mirror.close()
            except Exception:  # noqa: BLE001 - teardown must not raise
                pass
            self._mirror = None

    # -- frame packing --------------------------------------------------------

    def _pack_frame(self, kind: int, seq: int, chain_pos: int, step: int,
                    blobs: Mapping[str, bytes]) -> bytes:
        body_parts = []
        for key, blob in blobs.items():
            kb = key.encode()
            body_parts.append(struct.pack("<H", len(kb)))
            body_parts.append(kb)
            body_parts.append(struct.pack("<q", len(blob)))
            body_parts.append(blob)
        body = b"".join(body_parts)
        # the crc covers the header fields too (a flipped step/n_leaves
        # byte must not validate), so it is computed over prefix+body and
        # appended as the header's last field
        prefix = struct.pack(_HEADER_PREFIX, _VERSION, kind, seq, chain_pos,
                             step, len(blobs))
        crc = zlib.crc32(prefix + body)
        return SNAP_MAGIC + prefix + struct.pack("<I", crc) + body

    def _unpack_frame(self, stream: str, seq_hint: Optional[int],
                      raw: bytes) -> tuple[int, int, int, int,
                                           dict[str, bytes]]:
        """-> (kind, seq, chain_pos, step, {key: blob}); raises
        SnapshotCorruptError on any structural problem."""
        def bad(reason: str) -> SnapshotCorruptError:
            return SnapshotCorruptError(stream, seq_hint, reason)

        if len(raw) < _HEADER_SIZE:
            raise bad(f"truncated frame header ({len(raw)} bytes)")
        if raw[:4] != SNAP_MAGIC:
            raise bad("bad frame magic")
        version, kind, seq, chain_pos, step, n_leaves, crc = \
            struct.unpack_from(_HEADER, raw, 4)
        if version != _VERSION:
            raise bad(f"unsupported frame version {version}")
        body = raw[_HEADER_SIZE:]
        if zlib.crc32(raw[4:_HEADER_SIZE - 4] + body) != crc:
            raise bad("frame crc mismatch (truncated or corrupted)")
        blobs: dict[str, bytes] = {}
        off = 0
        try:
            for _ in range(n_leaves):
                (klen,) = struct.unpack_from("<H", body, off)
                off += 2
                key = body[off:off + klen].decode()
                off += klen
                (blen,) = struct.unpack_from("<q", body, off)
                off += 8
                if off + blen > len(body):
                    raise bad(f"truncated leaf blob {key!r}")
                blobs[key] = body[off:off + blen]
                off += blen
        except struct.error:
            raise bad("truncated frame body") from None
        return kind, seq, chain_pos, step, blobs

    # -- frame IO -------------------------------------------------------------

    def _stream_dir(self, stream: str) -> str:
        assert self.directory is not None
        return os.path.join(self.directory, stream)

    def _frame_path(self, stream: str, seq: int) -> str:
        return os.path.join(self._stream_dir(stream), f"frame_{seq:08d}.snap")

    def _write_frame(self, st: _StreamState, stream: str,
                     frame: bytes) -> None:
        if self.directory is None:
            st.mem_frames.append((st.seq, frame))
        else:
            d = self._stream_dir(stream)
            os.makedirs(d, exist_ok=True)
            transport.atomic_write_bytes(
                self._frame_path(stream, st.seq), frame)
        self._forward_frame(stream, frame)

    def _forward_frame(self, stream: str, frame: bytes) -> None:
        """Best-effort mirror of one raw snapshot frame. The transport
        frame's step comes from the snapshot header; the raw bytes ship
        verbatim (``CODEC_RAW``) so the replica's chain — crcs and all —
        is bit-identical to the local one. A noop-collapse rewrite reuses
        its seq, which :meth:`ingest` resolves by replacement."""
        if self._mirror is None:
            return
        step = struct.unpack_from(_HEADER, frame, 4)[4]
        try:
            self._mirror.write(int(step), frame, stream=stream,
                               codec=transport.CODEC_RAW)
            self.mirror_frames += 1
        except Exception:  # noqa: BLE001 - replication never blocks publish
            self.mirror_failures += 1

    def _list_frames(self, stream: str) -> list[tuple[int, str]]:
        """Published (seq, path) pairs on disk, sorted by seq."""
        d = self._stream_dir(stream)
        if not os.path.isdir(d):
            return []
        out = []
        for name in os.listdir(d):
            if name.startswith("frame_") and name.endswith(".snap"):
                try:
                    out.append((int(name[len("frame_"):-len(".snap")]),
                                os.path.join(d, name)))
                except ValueError:
                    continue
        return sorted(out)

    def _frame_sources(self, stream: str) -> list[tuple[int, Any]]:
        """(seq, source) pairs, sorted; source is raw bytes (memory) or a
        file path (disk) — load lazily via :meth:`_head` / :meth:`_load`,
        so chain scans read 30-byte headers, not whole frame bodies."""
        if self.directory is None:
            st = self._streams.get(stream)
            return list(st.mem_frames) if st else []
        return self._list_frames(stream)

    def _head(self, source: Any) -> bytes:
        if isinstance(source, bytes):
            return source
        try:
            with open(source, "rb") as f:
                return f.read(_HEADER_SIZE)
        except OSError:
            # listed but gone (another writer's retention pruned it
            # between listdir and open): an unreadable header — never a
            # base candidate, and harmless behind the newest base
            return b""

    def _load(self, source: Any) -> bytes:
        if isinstance(source, bytes):
            return source
        with open(source, "rb") as f:
            return f.read()

    def _frame_kind(self, head: bytes) -> Optional[int]:
        """Lenient header peek; None when the header is unreadable."""
        if (len(head) >= _HEADER_SIZE and head[:4] == SNAP_MAGIC
                and head[4] == _VERSION):
            return struct.unpack_from(_HEADER, head, 4)[1]
        return None

    def _prune(self, st: _StreamState, stream: str) -> None:
        """Drop frames behind the ``keep_chains``-th newest base."""
        if self.keep_chains is None:
            return
        entries = self._frame_sources(stream)
        base_seqs = [seq for seq, src in entries
                     if self._frame_kind(self._head(src)) == KIND_BASE]
        if len(base_seqs) <= self.keep_chains:
            return
        cutoff = base_seqs[-self.keep_chains]
        if self.directory is None:
            st.mem_frames = [(s, r) for s, r in st.mem_frames if s >= cutoff]
            return
        for seq, path in entries:
            if seq < cutoff:
                try:
                    os.remove(path)
                except OSError:
                    pass

    # -- producer side --------------------------------------------------------

    def _state(self, stream: str) -> _StreamState:
        st = self._streams.get(stream)
        if st is None:
            st = _StreamState()
            if self.directory is not None:
                # a restarted store appends to the existing chain when it
                # can reconstruct the last snapshot, and rebases otherwise
                frames = self._list_frames(stream)
                if frames:
                    st.seq = frames[-1][0] + 1
                    try:
                        step, leaves, chain_pos = self._replay(stream)
                        st.last_leaves = leaves
                        st.frames_since_base = chain_pos
                        # seed the monotonic-step guard too, or a stale
                        # queued firing could regress a restarted chain
                        st.last_step = step
                    except SnapshotCorruptError:
                        st.last_leaves = None    # next publish: fresh base
                        st.frames_since_base = -1
            self._streams[stream] = st
        return st

    def publish(self, stream: str, step: int, tree: PyTree, *,
                version: Optional[int] = None,
                chunk_hints: Optional[Mapping[str, int]] = None
                ) -> SnapshotRecord:
        """Encode + publish one snapshot of ``tree`` on ``stream``.

        ``version`` is the producer's cheap mutation counter (e.g.
        ``ServingEngine.state_version``): when it matches the previously
        published version, the slab is untouched and the publish
        short-circuits to a no-op frame without walking the payload.

        ``chunk_hints`` maps flattened leaf keys to a per-leaf chunk size,
        overriding the store-wide ``chunk_bytes`` for those leaves. The
        paged serving engine passes one (layer, page) slab per chunk, so
        delta chunks align to KV pages and every untouched page frames as
        a zero-payload COPY op. Pass the same hints on every publish of a
        stream — chunk boundaries must line up with the retained base for
        the per-chunk comparison to detect unchanged pages.
        """
        with self._lock:
            st = self._state(stream)
            if st.last_step is not None and step < st.last_step:
                # a late out-of-order firing (concurrent workers draining
                # the ring) must not become the chain tip: publishing an
                # older slab as the newest frame would silently regress
                # restore(). Skip it; nothing is written.
                st.stale += 1
                return SnapshotRecord(stream, step, st.seq, "stale", -1,
                                      0, 0)
            st.last_step = step
            if (version is not None and st.last_version is not None
                    and version == st.last_version
                    and st.last_leaves is not None):
                # unchanged slab: always a no-op frame, even when the base
                # cadence has expired — an idle engine must not pay a full
                # re-encode; the next *changed* publish writes the base
                # (noop replay is a header parse, so restore stays cheap).
                # Consecutive no-ops COLLAPSE into the tip frame (rewritten
                # in place through the same tmp->rename protocol), so an
                # idle stream holds ONE noop marker, not one per firing —
                # chain length and frame count stay bounded.
                collapse = st.last_kind == KIND_NOOP
                seq = st.seq - 1 if collapse else st.seq
                pos = (st.frames_since_base if collapse
                       else st.frames_since_base + 1)
                frame = self._pack_frame(KIND_NOOP, seq, pos, step, {})
                if collapse:
                    if self.directory is None:
                        st.mem_frames[-1] = (seq, frame)
                        self._forward_frame(stream, frame)
                    else:
                        prev = st.seq           # _write_frame targets st.seq
                        st.seq = seq
                        try:
                            self._write_frame(st, stream, frame)
                        finally:
                            st.seq = prev
                else:
                    self._write_frame(st, stream, frame)
                    st.seq += 1
                    st.frames_since_base += 1
                    st.stored_bytes += len(frame)
                # a no-op frame still *represents* the full slab — count
                # its raw bytes so the effective ratio reflects what each
                # firing snapshotted, not just what it re-encoded
                rec = SnapshotRecord(stream, step, seq, "noop", pos,
                                     st.last_raw, len(frame))
                st.last_kind = KIND_NOOP
                st.publishes += 1
                st.noops += 1
                st.raw_bytes += st.last_raw
                return rec
            base_due = (st.last_leaves is None
                        or st.frames_since_base + 1 >= self.base_every)
            leaves = _flatten(tree)
            pool = codecs.codec_pool() if self.parallel else None
            blobs: dict[str, bytes] = {}
            raw = 0
            hints = chunk_hints or {}
            for key, arr in leaves.items():
                base = None if base_due else (st.last_leaves or {}).get(key)
                blob, stats = delta.encode(
                    arr, base, codec=self.codec,
                    chunk_bytes=int(hints.get(key, self.chunk_bytes)),
                    pool=pool)
                blobs[key] = blob
                raw += stats.raw_bytes
            kind = KIND_BASE if base_due else KIND_DELTA
            chain_pos = 0 if base_due else st.frames_since_base + 1
            frame = self._pack_frame(kind, st.seq, chain_pos, step, blobs)
            self._write_frame(st, stream, frame)
            rec = SnapshotRecord(stream, step, st.seq, _KIND_NAMES[kind],
                                 chain_pos, raw, len(frame))
            st.seq += 1
            st.frames_since_base = chain_pos
            st.last_leaves = leaves
            st.last_version = version
            st.last_raw = raw
            st.last_kind = kind
            st.publishes += 1
            st.raw_bytes += raw
            st.stored_bytes += len(frame)
            if kind == KIND_BASE:
                st.bases += 1
                self._prune(st, stream)
            else:
                st.deltas += 1
            return rec

    # -- consumer side --------------------------------------------------------

    def ingest(self, stream: str, raw: bytes) -> dict:
        """Place one mirrored frame (raw bytes off a transport) into this
        store's chain — the replica half of :meth:`set_mirror`.

        The frame's own header says where it goes: frames land by their
        embedded seq, and a frame re-arriving with an existing seq
        *replaces* it (that is how producer-side noop collapse — which
        rewrites the tip frame in place — reaches the replica). Validates
        magic/crc up front, so a corrupted frame raises the usual typed
        :class:`SnapshotCorruptError` instead of poisoning the chain.
        """
        raw = bytes(raw)
        kind, seq, chain_pos, step, _ = self._unpack_frame(stream, None, raw)
        with self._lock:
            st = self._state(stream)
            if self.directory is None:
                st.mem_frames = [(s, b) for s, b in st.mem_frames if s != seq]
                st.mem_frames.append((seq, raw))
                st.mem_frames.sort(key=lambda e: e[0])
            else:
                d = self._stream_dir(stream)
                os.makedirs(d, exist_ok=True)
                transport.atomic_write_bytes(self._frame_path(stream, seq),
                                             raw)
            st.seq = max(st.seq, seq + 1)
            # the replica must not treat replayed frames as local publishes
            # (its own stats stay producer-truthful), but the monotonic
            # guard still advances so a later local publish can't regress
            if st.last_step is None or step >= st.last_step:
                st.last_step = step
        return {"stream": stream, "seq": seq, "step": step,
                "kind": _KIND_NAMES.get(kind, str(kind)),
                "chain_pos": chain_pos}

    def _replay(self, stream: str, upto: Optional[int] = None
                ) -> tuple[int, dict[str, np.ndarray], int]:
        """Replay base -> deltas; -> (step, leaves, chain_pos of last)."""
        frames = self._frame_sources(stream)
        if upto is not None:
            frames = [(s, x) for s, x in frames if s <= upto]
        if not frames:
            raise KeyError(f"no published snapshots for stream {stream!r}")
        # pass 1 (lenient): find the newest base from the 30-byte headers
        # alone — no frame body is read. Frames *behind* that base are dead
        # weight: damage there must not block restoring the live chain, and
        # their bytes are never loaded; the replayed suffix is validated
        # strictly (crc + contiguity + decode) in pass 2.
        kinds = [self._frame_kind(self._head(x)) for _, x in frames]
        base_idx = max((i for i, k in enumerate(kinds) if k == KIND_BASE),
                       default=None)
        if base_idx is None:
            raise SnapshotCorruptError(
                stream, frames[0][0], "chain has no base frame")
        parsed = []
        for seq, src in frames[base_idx:]:
            try:
                raw = self._load(src)
            except OSError as e:
                # the file vanished after listing — a concurrent writer
                # published a newer base and pruned this chain; keep the
                # typed-error contract (callers may re-list and retry)
                raise SnapshotCorruptError(
                    stream, seq,
                    f"frame file disappeared during replay ({e})") from e
            kind, fseq, chain_pos, step, blobs = self._unpack_frame(
                stream, seq, raw)
            if fseq != seq:
                raise SnapshotCorruptError(
                    stream, seq, f"frame claims seq {fseq}")
            parsed.append((seq, kind, chain_pos, step, blobs))
        pool = codecs.codec_pool() if self.parallel else None
        base_seq = parsed[0][0]
        leaves: dict[str, np.ndarray] = {}
        step_out, chain_pos_out = parsed[0][3], 0
        expect = base_seq
        for seq, kind, chain_pos, step, blobs in parsed:
            if seq != expect:
                # a frame between the base and here was never published
                # (or was deleted): the chain cannot be replayed past it
                raise SnapshotCorruptError(
                    stream, expect,
                    f"chain gap: frame seq {expect} is missing "
                    f"(next published frame is seq {seq})")
            if chain_pos != seq - base_seq:
                raise SnapshotCorruptError(
                    stream, seq,
                    f"inconsistent chain: frame declares chain_pos "
                    f"{chain_pos}, expected {seq - base_seq}")
            expect += 1
            if kind == KIND_NOOP:
                step_out, chain_pos_out = step, chain_pos
                continue
            new_leaves: dict[str, np.ndarray] = {}
            for key, blob in blobs.items():
                try:
                    new_leaves[key] = delta.decode(
                        blob, leaves.get(key), pool=pool)
                except (ValueError, KeyError, struct.error) as e:
                    raise SnapshotCorruptError(
                        stream, seq,
                        f"leaf {key!r} failed to decode: {e}") from e
            leaves = new_leaves
            step_out, chain_pos_out = step, chain_pos
        return step_out, leaves, chain_pos_out

    def restore(self, stream: str, *, upto: Optional[int] = None,
                template: Optional[PyTree] = None
                ) -> tuple[int, Any]:
        """Rebuild the newest snapshot with frame seq <= ``upto`` (or the
        newest published) by replaying its base → delta chain.

        Returns ``(step, leaves)`` where leaves maps flattened tree paths to
        arrays; with ``template``, the leaves are unflattened into the
        template's structure instead (a template leaf missing from the
        snapshot raises ``KeyError`` naming it — tree-shape drift, same
        contract as checkpoint restore).
        """
        with self._lock:
            step, leaves, _ = self._replay(stream, upto)
        if template is None:
            return step, leaves
        import jax

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, _ in flat:
            key = jax.tree_util.keystr(path)
            if key not in leaves:
                raise KeyError(
                    f"template leaf {key} not in snapshot (tree shape "
                    "drifted since publish)")
            out.append(leaves[key])
        return step, jax.tree_util.tree_unflatten(treedef, out)

    def restorable(self, stream: str) -> bool:
        """True when the stream's chain currently replays end to end.

        The replica-hydration loop polls this while frames stream in over
        a mirror: a chain whose base hasn't arrived yet (ingest delivers
        frames in publish order, but the consumer may attach mid-chain)
        is simply not restorable *yet*, not corrupt.
        """
        with self._lock:
            try:
                self._replay(stream)
                return True
            except (KeyError, SnapshotCorruptError):
                return False

    # -- introspection --------------------------------------------------------

    def chain_depth(self, stream: str) -> int:
        """Frames since the owning base of the newest snapshot (0 = base)."""
        with self._lock:
            st = self._streams.get(stream)
            return max(st.frames_since_base, 0) if st else 0

    def published(self, stream: str) -> list[int]:
        """Seqs of the published frames (any prefix of these restores)."""
        with self._lock:
            return [seq for seq, _ in self._frame_sources(stream)]

    def stats(self, stream: str) -> dict[str, Any]:
        """Delta-chain statistics for :meth:`Session.report`."""
        with self._lock:
            st = self._streams.get(stream) or _StreamState()
            eq1 = ((st.raw_bytes - st.stored_bytes) / st.raw_bytes
                   if st.raw_bytes else 0.0)
            return {
                "publishes": st.publishes,
                "bases": st.bases,
                "deltas": st.deltas,
                "noops": st.noops,
                "stale_skipped": st.stale,
                "raw_bytes": st.raw_bytes,
                "stored_bytes": st.stored_bytes,
                "delta_ratio": eq1,                      # paper Eq. (1)
                "effective_compression_x": (
                    st.raw_bytes / st.stored_bytes if st.stored_bytes
                    else 0.0),
                "chain_depth": max(st.frames_since_base, 0),
                "base_every": self.base_every,
                "keep_chains": self.keep_chains,
                "codec": self.codec,
                "mirror_frames": self.mirror_frames,
                "mirror_failures": self.mirror_failures,
            }
