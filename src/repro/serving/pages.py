"""Paged KV cache + continuous batching (the vLLM/JetStream serving shape).

The dense ``ServingEngine`` gives every slot a full ``(max_len, ...)`` KV
stripe, so a 5-token reply pays the memory (and admission) cost of the
longest request and throughput collapses once the fixed slots fill. Here KV
memory is a shared pool of fixed-size pages per layer:

  pool leaf   (layers, num_pages, page_size, ...)   — block-indexed storage
  page_table  (max_reqs, pages_per_seq) int32       — per-request chains
  state leaf  (layers, max_reqs, ...)               — SWA rings / SSM state

A request is admitted whenever a batch row *and* enough free pages exist —
``ceil((prompt + max_new) / page_size)`` pages are reserved up front so a
mid-decode exhaustion can never corrupt a neighbour. Completion returns the
chain to the free list immediately (``free_resource``), so short requests
stop blocking long ones: no fixed slot count, no head-of-line blocking.

Lifecycle (JetStream's engine vocabulary):

  admit(req)      prefill the prompt, then insert
  _insert(...)    scatter the prefilled KV into the reserved pages and copy
                  recurrent state into the request's row
  step()          one batched decode for every row; page writes go through
                  the per-request page table
  free_resource() return pages, zero the table row

Page 0 is reserved as a scratch page: inactive rows' table entries point at
it, so their (masked, never-read) decode writes land harmlessly there.

Parity: gathering a chain back into token order and masking positions
``>= length`` to NEG_INF makes the softmax weights of garbage positions
exactly 0.0 (``exp(NEG_INF - m)`` underflows), so paged decode logits are
**bit-identical** to the dense slab's — asserted per family in
tests/test_paged_serving.py. On TPU the fused Pallas kernel
(repro/kernels/paged_attention.py) replaces the gather and matches to
float tolerance instead.

Snapshots: ``snapshot_payload`` emits the pool plus per-page dirty versions
and per-leaf ``chunk_hints`` sized to one (layer, page) slab, so the
serve_snapshot delta chunks align to pages and untouched pages frame as
zero-payload COPY ops in the PR-5 store.
"""
from __future__ import annotations

import json
from functools import partial
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import hymba as hymba_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import embed, mlp, rmsnorm, unembed
from repro.models.transformer import project_qkv
from repro.serving import engine as E
from repro.serving import kvcache
from repro.serving import prefix as prefix_lib
from repro.serving.engine import Request, make_prefill

PyTree = Any


# ---------------------------------------------------------------------------
# paged decode blocks (x: (B,1,d); kv leaves: (num_pages, page_size, ...))
# ---------------------------------------------------------------------------

def _paged_gqa_attn(p, xn, cfg: ModelConfig, kv, table, lengths, ps):
    pos = lengths[:, None]                       # rope position of new token
    q, k, v = project_qkv(p, xn, cfg, pos)
    kc = attn_lib.scatter_token(kv["k"], k[:, 0], table, lengths, ps)
    vc = attn_lib.scatter_token(kv["v"], v[:, 0], table, lengths, ps)
    o = attn_lib.paged_decode_attention(q, kc, vc, table, lengths + 1)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": kc, "v": vc}


def _paged_mla_attn(p, xn, cfg: ModelConfig, kv, table, lengths, ps):
    pos = lengths[:, None]
    ckv_new, krope_new = mla_lib.mla_new_cache_entry(p, xn, cfg, pos)
    ckv = attn_lib.scatter_token(kv["ckv"], ckv_new[:, 0], table, lengths, ps)
    krope = attn_lib.scatter_token(kv["krope"], krope_new[:, 0], table,
                                   lengths, ps)
    # MLA decode is a latent-space matmul over the whole prefix — gather the
    # chain into token order and reuse the dense path (masked identically).
    o = mla_lib.mla_decode(p, xn, cfg,
                           attn_lib.gather_pages(ckv, table),
                           attn_lib.gather_pages(krope, table), lengths + 1)
    return o, {"ckv": ckv, "krope": krope}


def _paged_dense_block(p, x, cfg, kv, table, lengths, ps):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = _paged_mla_attn(p["attn"], xn, cfg, kv, table, lengths, ps)
    else:
        a, kv = _paged_gqa_attn(p["attn"], xn, cfg, kv, table, lengths, ps)
    x = x + a
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn), kv


def _paged_moe_block(p, x, cfg, kv, table, lengths, ps):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = _paged_mla_attn(p["attn"], xn, cfg, kv, table, lengths, ps)
    else:
        a, kv = _paged_gqa_attn(p["attn"], xn, cfg, kv, table, lengths, ps)
    x = x + a
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    y, _ = moe_lib.moe_ffn(p["moe"], xn, cfg)
    return x + y, kv


def _paged_hybrid_block(p, x, cfg, kv, table, ssm_state, lengths, ps):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, kv = _paged_gqa_attn(p["attn"], xn, cfg, kv, table, lengths, ps)
    s, ssm_state = ssm_lib.ssm_decode(p["ssm"], xn, cfg, ssm_state)
    x = x + hymba_lib.fuse(p["fusion"], a, s, cfg)
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn), kv, ssm_state


def _paged_hybrid_decode(params, cfg, pool, state, table, lengths, h, ps):
    """Global layers page; SWA rings and SSM state stay per-row slabs."""
    gids = set(hymba_lib.global_layer_ids(cfg))
    kinds = ["g" if i in gids else "s" for i in range(cfg.n_layers)]
    g_idx = s_idx = 0
    new_g_kv, new_s_kv, new_g_ssm, new_s_ssm = [], [], [], []
    i = 0
    while i < cfg.n_layers:
        j = i
        while j < cfg.n_layers and kinds[j] == kinds[i]:
            j += 1
        count = j - i
        is_g = kinds[i] == "g"
        idx0 = g_idx if is_g else s_idx
        pkey = "global_blocks" if is_g else "swa_blocks"
        part = lambda t: jax.tree.map(lambda a: a[idx0:idx0 + count], t)
        part_p = part(params[pkey])
        if is_g:
            part_kv = part(pool["global_kv"])
            part_ssm = part(state["ssm_global"])

            def step(carry, xs):
                p_l, kv_l, ssm_l = xs
                x, kv, ssm = _paged_hybrid_block(
                    p_l, carry, cfg, kv_l, table, ssm_l, lengths, ps)
                return x, (kv, ssm)
        else:
            part_kv = part(state["swa_kv"])
            part_ssm = part(state["ssm_swa"])

            def step(carry, xs):
                p_l, kv_l, ssm_l = xs
                x, kv, ssm = E._hybrid_decode_block(
                    p_l, carry, cfg, kv_l, ssm_l, lengths,
                    window=cfg.swa_window)
                return x, (kv, ssm)

        h, (kv_new, ssm_new) = E._maybe_scan(
            step, h, (part_p, part_kv, part_ssm), cfg.scan_layers)
        (new_g_kv if is_g else new_s_kv).append(kv_new)
        (new_g_ssm if is_g else new_s_ssm).append(ssm_new)
        if is_g:
            g_idx += count
        else:
            s_idx += count
        i = j

    cat = lambda parts: (jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
                         if len(parts) > 1 else parts[0])
    pool = {"global_kv": cat(new_g_kv)}
    state = {"swa_kv": cat(new_s_kv), "ssm_global": cat(new_g_ssm),
             "ssm_swa": cat(new_s_ssm)}
    return h, pool, state


def make_paged_decode(cfg: ModelConfig, page_size: int) -> Callable:
    """decode(params, pool, state, page_table, tokens (B,1), lengths (B,))
    -> (logits, pool, state, lengths+1)."""
    ps = page_size

    def decode(params, pool, state, page_table, tokens, lengths):
        h = embed(params["embed"], tokens)

        if cfg.family in ("dense", "audio", "vlm"):
            body = lambda x, p, kv: _paged_dense_block(
                p, x, cfg, kv, page_table, lengths, ps)
            h, kv = E._scan_decode(params["blocks"], pool["kv"], h, lengths,
                                   body, use_scan=cfg.scan_layers)
            pool = {"kv": kv}

        elif cfg.family == "moe":
            m = cfg.moe
            kv = pool["kv"]
            split = lambda t: (jax.tree.map(lambda a: a[:m.first_dense], t),
                               jax.tree.map(lambda a: a[m.first_dense:], t))
            kv_d, kv_m = split(kv) if m.first_dense else (None, kv)
            if m.first_dense:
                body_d = lambda x, p, k: _paged_dense_block(
                    p, x, cfg, k, page_table, lengths, ps)
                h, kv_d = E._scan_decode(params["dense_blocks"], kv_d, h,
                                         lengths, body_d,
                                         use_scan=cfg.scan_layers)
            body_m = lambda x, p, k: _paged_moe_block(
                p, x, cfg, k, page_table, lengths, ps)
            h, kv_m = E._scan_decode(params["moe_blocks"], kv_m, h, lengths,
                                     body_m, use_scan=cfg.scan_layers)
            joined = (jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                   kv_d, kv_m) if m.first_dense else kv_m)
            pool = {"kv": joined}

        elif cfg.family == "hybrid":
            h, pool, state = _paged_hybrid_decode(
                params, cfg, pool, state, page_table, lengths, h, ps)

        elif cfg.family == "ssm":
            h, state = E._xlstm_decode(params, cfg, state, h)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg.vocab_size)
        return logits, pool, state, lengths + 1

    return decode


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Host-side refcounted free list over page ids 1..num_pages-1 (0 is
    scratch).

    Whole chains are reserved at admission, so allocation can never fail
    mid-decode. Pages are reference-counted so one chain can back many
    requests (shared-prefix COW mapping): ``alloc`` hands out pages at
    refcount 1, ``share`` takes another reference on an already-live chain,
    and ``free`` drops one reference — a page returns to the free list only
    when its count reaches zero, so a referenced page can never be
    reclaimed out from under a reader. Over-free and foreign-page frees
    raise instead of corrupting the list (property-tested in
    tests/test_paged_serving.py and tests/test_prefix_sharing.py).
    """

    def __init__(self, num_pages: int) -> None:
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> 1, 2, ...
        self._refs: dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        """Live references on ``page`` (0 = free or out of range)."""
        return self._refs.get(page, 0)

    def refcounts(self) -> dict[int, int]:
        """Snapshot of all live page refcounts (page id -> count)."""
        return dict(self._refs)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Reserve n pages at refcount 1, or None if the pool can't cover
        them."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages: list[int]) -> None:
        """Take one more reference on each page of a live chain."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"page {p} shared but not allocated")
        for p in pages:
            self._refs[p] += 1

    def free(self, pages: list[int]) -> None:
        """Drop one reference per page; reclaim pages that hit zero."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"page {p} freed but not allocated")
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)

    # -- hydration ------------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able exact state (free-list order preserved, so a restored
        allocator hands out the same pages in the same order)."""
        return {"num_pages": self.num_pages,
                "free": list(self._free),
                "refs": {str(p): c for p, c in sorted(self._refs.items())}}

    def load_state(self, state: Mapping[str, Any]) -> None:
        """Restore :meth:`state_dict` output bit-exactly."""
        if int(state["num_pages"]) != self.num_pages:
            raise ValueError(
                f"allocator size mismatch: snapshot has "
                f"{state['num_pages']} pages, this pool has {self.num_pages}")
        self._free = [int(p) for p in state["free"]]
        self._refs = {int(p): int(c) for p, c in state["refs"].items()}


# ---------------------------------------------------------------------------
# prefill-cache split + jitted insert helpers
# ---------------------------------------------------------------------------

def _split_tree(tree: dict, pool_l: dict, state_l: dict):
    """Partition a prefill cache into (pool-side, state-side) subtrees
    following the paged_cache_layout split."""
    pool, state = {}, {}
    for k, v in tree.items():
        if k in pool_l and k in state_l:          # mixed subtree
            p, s = _split_tree(v, pool_l[k], state_l[k])
            pool[k], state[k] = p, s
        elif k in pool_l:
            pool[k] = v
        else:
            state[k] = v
    return pool, state


def _insert_pages(pool, pool1, page_ids):
    """Scatter a single-request prefill cache into the reserved pages.

    pool leaf (L, NP, PS, ...) <- pool1 leaf (L, 1, max_len, ...): the first
    n*PS prompt positions, reshaped to n page slabs. Retraces per distinct
    page count n (bounded by pages_per_seq).
    """
    n = page_ids.shape[0]

    def leaf(full, one):
        layers, _, ps = full.shape[:3]
        chunk = one[:, 0, :n * ps].reshape(layers, n, ps, *one.shape[3:])
        return full.at[:, page_ids].set(chunk.astype(full.dtype))

    return jax.tree.map(leaf, pool, pool1)


def _insert_state(state, state1, row, cfg):
    return jax.tree.map(
        lambda full, one: E._set_batch_slot(full, one, row, cfg),
        state, state1)


def _insert_fused(pool, state, page_table, lengths, tokens,
                  pool1, state1, logits, row, page_ids, n_prompt, *, cfg):
    """Everything after prefill as ONE jitted computation.

    Scatters the prompt KV into the reserved pages, copies per-row state,
    writes the table row (unused slots stay on the scratch page 0), stamps
    the length, and picks the first sampled token — a single dispatch where
    the unfused path paid six plus an extra device sync. Retraces per page
    count (bounded by pages_per_seq) and per pytree structure only.
    """
    if pool1:
        pool = _insert_pages(pool, pool1, page_ids)
    if state1:
        state = _insert_state(state, state1, row, cfg)
    pps = page_table.shape[1]
    table_row = jnp.zeros((pps,), jnp.int32).at[:page_ids.shape[0]].set(
        page_ids)
    page_table = page_table.at[row].set(table_row)
    lengths = lengths.at[row].set(n_prompt)
    nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
    tokens = tokens.at[row, 0].set(nxt)
    return pool, state, page_table, lengths, tokens, nxt


def _insert_suffix_fused(pool, page_table, lengths, tokens,
                         kv1, logits, row, shared_ids, new_ids, n_prompt,
                         *, page_size):
    """Shared-prefix admit tail as ONE jitted computation.

    The prefix chain is already resident (refcount-shared, read-only), so
    only the suffix KV — computed by the continuation prefill from the
    first divergent token — is scattered, into the freshly allocated
    ``new_ids`` pages. The table row maps shared chain + new pages; the
    shared pages are never written, which is the copy-on-write invariant.
    The suffix KV arrives at the canonical padded width, which may be
    narrower (pad to the page budget, the new pages also cover decode
    slots) or wider (slice; the tail is never-attended pad junk) than
    ``n_new * page_size``. Retraces per (shared, new) page-count pair,
    bounded by pages_per_seq.
    """
    n_new = new_ids.shape[0]

    def leaf(full, one):
        layers = one.shape[0]
        want = n_new * page_size
        chunk = one[:, 0, :want]
        pad = want - chunk.shape[1]
        if pad > 0:
            chunk = jnp.pad(chunk,
                            [(0, 0), (0, pad)] + [(0, 0)] * (chunk.ndim - 2))
        chunk = chunk.reshape(layers, n_new, page_size, *one.shape[3:])
        return full.at[:, new_ids].set(chunk.astype(full.dtype))

    pool = jax.tree.map(leaf, pool, kv1)
    pps = page_table.shape[1]
    chain = jnp.concatenate([shared_ids, new_ids])
    table_row = jnp.zeros((pps,), jnp.int32).at[:chain.shape[0]].set(chain)
    page_table = page_table.at[row].set(table_row)
    lengths = lengths.at[row].set(n_prompt)
    nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
    tokens = tokens.at[row, 0].set(nxt)
    return pool, page_table, lengths, tokens, nxt


# ---------------------------------------------------------------------------
# continuous-batching engine
# ---------------------------------------------------------------------------

class PagedServingEngine:
    """Continuous batching over a paged KV pool (drop-in for ServingEngine).

    ``num_pages`` x ``page_size`` tokens of KV storage are shared by up to
    ``max_reqs`` concurrent rows; admission needs one free row plus the
    request's full page budget. Decode proceeds while new requests prefill
    into free pages between steps, and completed chains are reclaimed
    immediately.
    """

    def __init__(self, cfg: ModelConfig, params, *, num_pages: int = 65,
                 page_size: int = 16, max_reqs: int = 8,
                 prompt_len: int = 64, max_len: int = 256) -> None:
        if max_len % page_size:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size}")
        self.cfg = cfg
        self.params = params
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_reqs = max_reqs
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.pages_per_seq = max_len // page_size

        self._pool_layout, self._state_layout = kvcache.paged_cache_layout(
            cfg, num_pages, page_size, max_reqs, max_len)
        self.pool, self.state = kvcache.init_paged_cache(
            cfg, num_pages, page_size, max_reqs, max_len)
        self.page_table = jnp.zeros((max_reqs, self.pages_per_seq), jnp.int32)
        self.lengths = jnp.zeros((max_reqs,), jnp.int32)
        self.tokens = jnp.zeros((max_reqs, 1), jnp.int32)
        self.active: list[Optional[Request]] = [None] * max_reqs
        self.allocator = PageAllocator(num_pages)
        self._chains: list[list[int]] = [[] for _ in range(max_reqs)]
        self._len_host = np.zeros(max_reqs, np.int64)   # device-sync-free
        self.prefix = prefix_lib.PrefixCache()
        self.prefill_tokens = 0    # tokens actually run through prefill
        self.shared_tokens = 0     # prompt tokens served from shared pages

        _dec = make_paged_decode(cfg, page_size)

        def _step(params, pool, state, table, tokens, lengths):
            logits, pool, state, lengths = _dec(params, pool, state, table,
                                                tokens, lengths)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, nxt[:, None], pool, state, lengths

        self._decode = jax.jit(_step)
        self._prefill_one = jax.jit(make_prefill(cfg, max_len,
                                                 last_only=True))
        # attention families prefill every prompt right-padded to one
        # canonical width (prompt_len): XLA kernel rounding is
        # shape-dependent, so a single compiled shape is what makes the
        # prefix KV a register_prefix writes bitwise equal to the KV an
        # unshared admit of the same tokens would write — the ground of
        # the sharing-parity guarantee (and one prefill trace instead of
        # one per prompt length). Recurrent families (hybrid/ssm) keep
        # exact-length prefill: padding tokens would advance their per-row
        # state past the real prompt.
        self._pad_prompts = cfg.family in prefix_lib.SHAREABLE_FAMILIES
        self._insert_fused = jax.jit(partial(_insert_fused, cfg=cfg))
        self._insert_suffix = jax.jit(
            partial(_insert_suffix_fused, page_size=page_size))
        self._register_insert = jax.jit(_insert_pages)
        self._cont_prefill = None    # built on first shared admit
        self._clear_row = jax.jit(
            lambda table, lengths, row: (table.at[row].set(0),
                                         lengths.at[row].set(0)))

        self._state_version = 0
        self._page_versions = np.zeros(num_pages, np.int64)
        self._chunk_hints = {
            jax.tree_util.keystr(path):
                int(np.prod(leaf.shape[2:])) * leaf.dtype.itemsize
            for path, leaf in
            jax.tree_util.tree_flatten_with_path({"pool": self.pool})[0]}

    # -- lifecycle ----------------------------------------------------------

    def admit(self, req: Request) -> bool:
        """Prefill + insert; False when no row or not enough free pages.

        When the prompt starts with a registered prefix, the shared chain
        is mapped read-only into the row's page table (refcount +1 per
        page) and only the divergent suffix is prefilled into fresh pages
        — prefill cost drops from the whole prompt to the suffix.
        """
        row = next((i for i, a in enumerate(self.active) if a is None), None)
        if row is None:
            return False
        prompt = E._checked_prompt(req, self.prompt_len)
        s = len(prompt)
        if s + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({s}) + max_new ({req.max_new}) "
                f"exceeds max_len={self.max_len}")
        n_total = -(-(s + req.max_new) // self.page_size)
        entry = self.prefix.match(prompt) if self.prefix else None
        if entry is not None:
            # suffix >= 1 token (match is strictly shorter), so
            # n_total > len(entry.pages) and at least one fresh page fits
            # the first decode slot. The shared reference is taken BEFORE
            # allocating: _alloc_pages evicts refcount-1 prefix chains
            # under pool pressure, and the matched entry is refcount-1
            # until this request references it — sharing first (refcount
            # 2) keeps it off the eviction list while the admit needs it.
            self.allocator.share(entry.pages)
            new_pages = self._alloc_pages(n_total - len(entry.pages))
            if new_pages is None:
                self.allocator.free(entry.pages)   # roll the share back
                return False
            self._insert_shared(row, req, prompt, entry, new_pages)
            return True
        pages = self._alloc_pages(n_total)       # reserve the whole chain
        if pages is None:
            return False
        self._insert(row, req, prompt, pages)
        return True

    def _alloc_pages(self, n: int) -> Optional[list[int]]:
        """Allocate, evicting LRU unreferenced prefixes under pressure."""
        pages = self.allocator.alloc(n)
        while pages is None and self.prefix.evict_lru(self.allocator):
            pages = self.allocator.alloc(n)
        return pages

    def _prefill_prompt(self, prompt: np.ndarray):
        """Prefill at the canonical padded width (attention families) or
        exact length (recurrent families). Logits are for the last *real*
        position either way."""
        if not self._pad_prompts:
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            return self._prefill_one(self.params, toks)
        padded = np.zeros(self.prompt_len, np.int32)
        padded[:len(prompt)] = prompt
        return self._prefill_one(self.params, jnp.asarray(padded)[None, :],
                                 jnp.int32(len(prompt)))

    def _insert(self, row: int, req: Request, prompt: np.ndarray,
                pages: list[int]) -> None:
        logits, cache1, _ = self._prefill_prompt(prompt)
        pool1, state1 = _split_tree(cache1, self._pool_layout,
                                    self._state_layout)
        (self.pool, self.state, self.page_table, self.lengths,
         self.tokens, nxt) = self._insert_fused(
            self.pool, self.state, self.page_table, self.lengths,
            self.tokens, pool1, state1, logits, jnp.int32(row),
            jnp.asarray(pages, jnp.int32), jnp.int32(len(prompt)))
        req.out.append(int(nxt))                 # one device sync per admit
        self.active[row] = req
        self._chains[row] = list(pages)
        self._len_host[row] = len(prompt)
        self.prefill_tokens += len(prompt)
        self._state_version += 1
        self._page_versions[pages] = self._state_version

    def _insert_shared(self, row: int, req: Request, prompt: np.ndarray,
                       entry: prefix_lib.PrefixEntry,
                       new_pages: list[int]) -> None:
        """COW admit: continuation-prefill the suffix, scatter into fresh
        pages, map [shared chain ; fresh pages] into the row's table."""
        p0 = entry.length
        if self._cont_prefill is None:
            self._cont_prefill = jax.jit(prefix_lib.make_continue_prefill(
                self.cfg, self.page_size))
        shared_ids = jnp.asarray(entry.pages, jnp.int32)
        # suffixes right-pad to ONE canonical width — the longest suffix
        # any registered prefix can leave (prompt_len - page_size) — so
        # every shared admit runs one compiled continuation shape per
        # prefix, mirroring the padded full prefill: XLA rounding must
        # not depend on this request's suffix length.
        suffix = prompt[p0:]
        padded = np.zeros(self.prompt_len - self.page_size, np.int32)
        padded[:len(suffix)] = suffix
        logits, kv1 = self._cont_prefill(self.params, self.pool,
                                         shared_ids,
                                         jnp.asarray(padded)[None, :],
                                         jnp.int32(len(suffix)))
        (self.pool, self.page_table, self.lengths, self.tokens,
         nxt) = self._insert_suffix(
            self.pool, self.page_table, self.lengths, self.tokens,
            {"kv": kv1}, logits, jnp.int32(row), shared_ids,
            jnp.asarray(new_pages, jnp.int32), jnp.int32(len(prompt)))
        req.out.append(int(nxt))                 # one device sync per admit
        self.active[row] = req
        self._chains[row] = list(entry.pages) + list(new_pages)
        self._len_host[row] = len(prompt)
        self.prefill_tokens += len(prompt) - p0
        self.shared_tokens += p0
        self._state_version += 1
        self._page_versions[new_pages] = self._state_version

    def register_prefix(self, tokens: Any) -> str:
        """Prefill a shared prompt prefix once and pin its page chain.

        The prefix is truncated to a whole number of pages (sharing is
        page-granular) that leaves room for at least one divergent prompt
        token inside the prompt window. Registering the same tokens twice
        is a no-op returning the same key. The chain is owned by the
        prefix cache at refcount 1; each matching admit adds a reference.
        """
        if self.cfg.family not in prefix_lib.SHAREABLE_FAMILIES:
            raise ValueError(
                f"prefix sharing needs every cache leaf in the page pool; "
                f"family {self.cfg.family!r} keeps per-row recurrent state "
                f"that cannot be shared read-only")
        toks = np.asarray(tokens, np.int32).reshape(-1)
        p0 = (min(len(toks), self.prompt_len - 1)
              // self.page_size * self.page_size)
        if p0 < self.page_size:
            raise ValueError(
                f"prefix of {len(toks)} tokens is shorter than one page "
                f"({self.page_size}) after truncation to the prompt "
                f"window ({self.prompt_len})")
        toks = np.ascontiguousarray(toks[:p0])
        key = prefix_lib.prefix_key(toks)
        if self.prefix.get(key) is not None:
            return key
        pages = self._alloc_pages(p0 // self.page_size)
        if pages is None:
            raise RuntimeError(
                f"cannot register prefix: {p0 // self.page_size} pages "
                f"needed, {self.allocator.free_pages} free")
        logits, cache1, _ = self._prefill_prompt(toks)
        del logits                               # chain ends mid-prompt
        pool1, _ = _split_tree(cache1, self._pool_layout,
                               self._state_layout)
        self.pool = self._register_insert(self.pool, pool1,
                                          jnp.asarray(pages, jnp.int32))
        self.prefill_tokens += p0
        self._state_version += 1
        self._page_versions[pages] = self._state_version
        self.prefix.add(prefix_lib.PrefixEntry(key=key, tokens=toks,
                                               pages=list(pages)))
        return key

    def unregister_prefix(self, key: str) -> bool:
        """Drop a registered prefix (by the key ``register_prefix``
        returned): the cache's own reference is released and new admits
        stop matching it. In-flight requests that already map the chain
        keep their refcounts — the pages return to the pool when the last
        of them completes. Unknown keys return False.
        """
        return self.prefix.drop(key, self.allocator)

    def free_resource(self, row: int) -> None:
        """Return the chain to the pool and point the row at scratch."""
        self.allocator.free(self._chains[row])
        self._chains[row] = []
        self.active[row] = None
        self.page_table, self.lengths = self._clear_row(
            self.page_table, self.lengths, jnp.int32(row))
        self._len_host[row] = 0

    def step(self) -> None:
        nxt, self.tokens, self.pool, self.state, self.lengths = self._decode(
            self.params, self.pool, self.state, self.page_table,
            self.tokens, self.lengths)
        self._state_version += 1
        nxt_host = np.asarray(nxt)               # one device->host transfer
        for r, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt_host[r]))
            pos = self._len_host[r]              # slot this decode wrote
            self._page_versions[self._chains[r][pos // self.page_size]] = \
                self._state_version
            self._len_host[r] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.free_resource(r)

    def run(self, requests: list[Request], max_steps: int = 512) -> None:
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if not pending and all(a is None for a in self.active):
                return
            if any(a is not None for a in self.active):
                self.step()

    # -- introspection / in-situ --------------------------------------------

    @property
    def state_version(self) -> int:
        return self._state_version

    def page_stats(self) -> dict[str, float]:
        used = (self.num_pages - 1) - self.allocator.free_pages
        refs = self.allocator.refcounts()
        return {
            "num_pages": self.num_pages,
            "free_pages": self.allocator.free_pages,
            "used_pages": used,
            "page_utilization": used / max(1, self.num_pages - 1),
            "active_requests": sum(a is not None for a in self.active),
            "occupancy": (sum(a is not None for a in self.active)
                          / self.max_reqs),
            "shared_pages": sum(1 for c in refs.values() if c > 1),
        }

    def prefix_stats(self) -> dict[str, Any]:
        """Prefix-cache effectiveness: hit rate, sharing, tokens saved.

        ``pages_saved`` counts extra references — pages a request mapped
        instead of allocating+prefilling its own copy. ``shared_tokens``
        is the prompt-token count served from shared pages (the prefill
        work sharing avoided); ``prefill_tokens`` is what actually ran.
        """
        refs = self.allocator.refcounts()
        st = self.prefix.stats()
        st.update({
            "shared_pages": sum(1 for c in refs.values() if c > 1),
            "pages_saved": sum(c - 1 for c in refs.values()),
            "prefill_tokens": self.prefill_tokens,
            "shared_tokens": self.shared_tokens,
        })
        return st

    def snapshot_payload(self) -> dict[str, Any]:
        """serve_snapshot payload: pool + state + tables + host metadata.

        ``chunk_hints`` sizes each pool leaf's delta chunks to one
        (layer, page) slab and ``page_versions`` records which pages moved,
        so unchanged pages frame as zero-payload COPY ops in the store.

        The ``meta`` leaf is the host-side engine state as JSON bytes —
        allocator free list + refcounts, request chains, in-flight
        requests, registered prefixes — everything :meth:`from_snapshot`
        needs to hydrate a cold replica that serves its next token without
        re-prefilling (its byte length varies, which the delta codec
        handles by framing it self-contained whenever it changes size).
        """
        meta = {
            "engine": {"num_pages": self.num_pages,
                       "page_size": self.page_size,
                       "max_reqs": self.max_reqs,
                       "prompt_len": self.prompt_len,
                       "max_len": self.max_len},
            "allocator": self.allocator.state_dict(),
            "chains": [list(c) for c in self._chains],
            "len_host": self._len_host.tolist(),
            "active": [None if a is None else
                       {"rid": a.rid,
                        "prompt": np.asarray(a.prompt).tolist(),
                        "max_new": a.max_new, "out": list(a.out)}
                       for a in self.active],
            "prefix": self.prefix.state_dict(),
            "counters": {"prefill_tokens": self.prefill_tokens,
                         "shared_tokens": self.shared_tokens},
            "version": self._state_version,
            "page_versions": self._page_versions.tolist(),
        }
        meta_leaf = np.frombuffer(json.dumps(meta).encode(), np.uint8)
        cache = {"pool": self.pool, "state": self.state,
                 "page_table": self.page_table, "lengths": self.lengths,
                 "tokens": self.tokens, "meta": meta_leaf}
        return {"cache": cache, "version": self._state_version,
                "page_versions": self._page_versions.copy(),
                "chunk_hints": dict(self._chunk_hints)}

    # -- replica hydration ----------------------------------------------------

    @classmethod
    def from_snapshot(cls, cfg: ModelConfig, params,
                      leaves: Mapping[str, np.ndarray]
                      ) -> "PagedServingEngine":
        """Rebuild an engine from a restored ``kv_pages`` snapshot.

        ``leaves`` is ``SnapshotStore.restore``'s flattened-key mapping.
        The ``meta`` leaf fixes the engine geometry and the host state;
        the array leaves refill the device slabs bit-exactly. The result
        decodes in lockstep with the producer at snapshot time: same page
        pool, same tables, same in-flight requests, same registered
        prefixes — first token without any prefill.
        """
        try:
            meta_leaf = leaves["['meta']"]
        except KeyError:
            raise KeyError(
                "snapshot has no 'meta' leaf — it was published by an "
                "engine without hydration metadata (pre-prefix-sharing "
                "chain); re-publish from a current engine") from None
        meta = json.loads(np.asarray(meta_leaf, np.uint8).tobytes())
        eng = cls(cfg, params, **{k: int(v)
                                  for k, v in meta["engine"].items()})
        eng._apply_snapshot(leaves, meta)
        return eng

    def load_snapshot(self, leaves: Mapping[str, np.ndarray]) -> None:
        """Re-hydrate *this* engine in place from a restored snapshot.

        Same effect as :meth:`from_snapshot` but reuses the engine's
        compiled decode/prefill functions (jit caches are per-instance) —
        the warm path for repeated catch-up from a newer chain point, and
        what TTFT benchmarks time so they measure restore work rather
        than retracing.
        """
        meta = json.loads(np.asarray(leaves["['meta']"], np.uint8).tobytes())
        geo = {k: int(v) for k, v in meta["engine"].items()}
        mine = {"num_pages": self.num_pages, "page_size": self.page_size,
                "max_reqs": self.max_reqs, "prompt_len": self.prompt_len,
                "max_len": self.max_len}
        if geo != mine:
            raise ValueError(f"snapshot geometry {geo} does not match "
                             f"this engine {mine}; use from_snapshot()")
        self._apply_snapshot(leaves, meta)

    def _apply_snapshot(self, leaves: Mapping[str, np.ndarray],
                        meta: Mapping[str, Any]) -> None:
        template = {"pool": self.pool, "state": self.state,
                    "page_table": self.page_table, "lengths": self.lengths,
                    "tokens": self.tokens}
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            if key not in leaves:
                raise KeyError(f"snapshot is missing leaf {key} "
                               f"(engine geometry drifted since publish)")
            out.append(jnp.asarray(leaves[key], leaf.dtype))
        restored = jax.tree_util.tree_unflatten(treedef, out)
        self.pool = restored["pool"]
        self.state = restored["state"]
        self.page_table = restored["page_table"]
        self.lengths = restored["lengths"]
        self.tokens = restored["tokens"]
        self.allocator.load_state(meta["allocator"])
        self._chains = [[int(p) for p in c] for c in meta["chains"]]
        self._len_host = np.asarray(meta["len_host"], np.int64)
        self.active = [
            None if a is None else Request(
                rid=int(a["rid"]),
                prompt=np.asarray(a["prompt"], np.int32),
                max_new=int(a["max_new"]),
                out=[int(t) for t in a["out"]])
            for a in meta["active"]]
        self.prefix.load_state(meta["prefix"])
        self.prefill_tokens = int(meta["counters"]["prefill_tokens"])
        self.shared_tokens = int(meta["counters"]["shared_tokens"])
        self._state_version = int(meta["version"])
        self._page_versions = np.asarray(meta["page_versions"], np.int64)

    def insitu_providers(self) -> dict[str, Callable[[], Any]]:
        return {"serving_state": lambda: {"pool": self.pool,
                                          "state": self.state},
                "lengths": lambda: self.lengths,
                "page_stats": lambda: self.page_stats(),
                "kv_snapshot": lambda: self.snapshot_payload()}
