"""Shared-prefix COW cache: prefill a common prompt prefix once, share it.

Serving traffic with a common system prompt re-prefills the same tokens for
every request — the exact "recompute what you already produced" pattern the
paper's in-situ thesis argues against. With paged KV (repro.serving.pages)
the fix is structural, JetStream's ``ExistingPrefix``/``bulk_insert`` shape:

  * ``PagedServingEngine.register_prefix`` prefills the prefix ONCE and
    scatters it into a pinned page chain (one fused dispatch, the same
    ``_insert_pages`` machinery as normal admission).
  * ``PrefixCache`` (here) keys that chain by a hash of the prefix tokens
    and LRU-tracks it. ``admit`` consults :meth:`PrefixCache.match`; on a
    hit the chain is mapped **read-only** into the request's page table
    (allocator refcount +1 per page) and only the divergent suffix is
    prefilled — via :func:`make_continue_prefill` below — into freshly
    allocated pages.
  * Copy-on-write invariant: shared pages are written only at
    registration. Decode writes land at position ``lengths`` which is
    always past the shared prefix, i.e. in the request's own pages; frees
    drop refcounts and a page returns to the free list only at zero. The
    decode kernels read through the page table and never see the
    difference — sharing is purely a table-level concern, so decode stays
    bit-identical to the unshared path.
  * Under pool pressure ``evict_lru`` reclaims the least-recently-matched
    prefix whose pages nobody else references.

Sharing requires every cache leaf to live in the page pool, so it is
limited to ``SHAREABLE_FAMILIES``; hybrid/ssm keep per-row recurrent state
whose value at the prefix boundary depends on the row, not the pages.

The continuation prefill is numerically the tail of a full prefill: the
prefix KV is gathered from the pool inside the jit (``kvcache.chain_view``)
and suffix queries attend over [prefix ; suffix] keys with
``q_offset=len(prefix)`` — the same per-row online-softmax reductions the
full prefill would compute for those rows.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models.layers import embed, mlp, rmsnorm, unembed
from repro.models.transformer import project_qkv
from repro.serving import engine as E
from repro.serving import kvcache

#: Families whose entire serving cache pages (no per-row recurrent state).
SHAREABLE_FAMILIES = ("dense", "audio", "vlm", "moe")


def prefix_key(tokens: Any) -> str:
    """Stable content key for a token prefix (sha256 of the int32 bytes)."""
    arr = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


@dataclasses.dataclass
class PrefixEntry:
    key: str
    tokens: np.ndarray            # (p0,) int32, p0 a multiple of page_size
    pages: list[int]              # pinned chain, len p0 // page_size
    clock: int = 0                # LRU stamp (bumped on every match)

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


class PrefixCache:
    """Registered prefixes + hit/miss accounting + LRU eviction.

    Pure host-side bookkeeping: the engine owns the device work (prefill,
    scatter); this class owns which chains exist, which one a prompt
    matches, and which one to give back under pool pressure. State is
    JSON-able (:meth:`state_dict`) so replica hydration restores it
    alongside the allocator.
    """

    def __init__(self) -> None:
        self._entries: dict[str, PrefixEntry] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def entries(self) -> list[PrefixEntry]:
        return list(self._entries.values())

    def get(self, key: str) -> Optional[PrefixEntry]:
        return self._entries.get(key)

    def add(self, entry: PrefixEntry) -> None:
        self._clock += 1
        entry.clock = self._clock
        self._entries[entry.key] = entry

    def match(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        """Longest registered prefix of ``prompt`` that leaves >= 1 token.

        Strictly-shorter matters: the continuation prefill needs at least
        one divergent token to produce the request's first logits, so a
        prompt equal to the prefix still prefills its last token normally.
        Counts a miss only when the cache is non-empty (an engine that
        never registered anything should report a 0/0 rate, not misses).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        best: Optional[PrefixEntry] = None
        for e in self._entries.values():
            p0 = e.length
            if p0 >= prompt.shape[0]:
                continue
            if best is not None and p0 <= best.length:
                continue
            if np.array_equal(prompt[:p0], e.tokens):
                best = e
        if best is not None:
            self._clock += 1
            best.clock = self._clock
            self.hits += 1
        elif self._entries:
            self.misses += 1
        return best

    def evict_lru(self, allocator: Any) -> bool:
        """Free the LRU prefix whose pages only the cache still references
        (refcount exactly 1 on every page). True if something was evicted.
        """
        for e in sorted(self._entries.values(), key=lambda e: e.clock):
            if all(allocator.refcount(p) == 1 for p in e.pages):
                allocator.free(e.pages)
                del self._entries[e.key]
                self.evictions += 1
                return True
        return False

    def drop(self, key: str, allocator: Any) -> bool:
        """Unregister one prefix (frees its cache reference; shared users
        keep their refcounts and pages until they complete). Unknown keys
        are a no-op returning False."""
        e = self._entries.pop(key, None)
        if e is None:
            return False
        allocator.free(e.pages)
        return True

    def stats(self) -> dict[str, Any]:
        total = self.hits + self.misses
        return {
            "prefixes": len(self._entries),
            "prefix_pages": sum(len(e.pages) for e in
                                self._entries.values()),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
        }

    # -- hydration ------------------------------------------------------------

    def state_dict(self) -> dict[str, Any]:
        return {
            "clock": self._clock,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": [
                {"key": e.key, "tokens": e.tokens.tolist(),
                 "pages": list(e.pages), "clock": e.clock}
                for e in self._entries.values()],
        }

    def load_state(self, state: Mapping[str, Any]) -> None:
        self._clock = int(state["clock"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.evictions = int(state["evictions"])
        self._entries = {}
        for e in state["entries"]:
            self._entries[e["key"]] = PrefixEntry(
                key=e["key"], tokens=np.asarray(e["tokens"], np.int32),
                pages=[int(p) for p in e["pages"]], clock=int(e["clock"]))


# ---------------------------------------------------------------------------
# continuation prefill (suffix tokens against a resident page chain)
# ---------------------------------------------------------------------------

def _gqa_cont_attn(p, xn, cfg: ModelConfig, positions, pkv, p0):
    """Suffix flash attention over [shared prefix KV ; suffix KV]."""
    q, k, v = project_qkv(p, xn, cfg, positions)
    kf = jnp.concatenate([pkv["k"].astype(k.dtype), k], axis=1)
    vf = jnp.concatenate([pkv["v"].astype(v.dtype), v], axis=1)
    o = attn_lib.flash_attention(q, kf, vf, causal=True, q_offset=p0,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 unroll=cfg.unroll_scans)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": k, "v": v}


def _mla_cont_attn(p, xn, cfg: ModelConfig, positions, pkv, p0):
    """MLA continuation: concat cached+new latents, then the same per-head
    K/V reconstruction as ``mla_attention``'s prefill path."""
    m = cfg.mla
    b, s, _ = xn.shape
    q_nope, q_rope = mla_lib._project_q(p, xn, cfg, positions)
    c_new, krope_new = mla_lib._project_kv_latent(p, xn, cfg, positions)
    ckv = jnp.concatenate([pkv["ckv"].astype(c_new.dtype), c_new], axis=1)
    krope = jnp.concatenate(
        [pkv["krope"].astype(krope_new.dtype), krope_new], axis=1)
    k_nope = jnp.einsum("bsl,lhk->bshk", ckv, p["wk_b"])
    v = jnp.einsum("bsl,lhk->bshk", ckv, p["wv_b"])
    sk = ckv.shape[1]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (b, sk, cfg.n_heads, m.qk_rope))], axis=-1)
    o = attn_lib.flash_attention(q, k, v, causal=True, q_offset=p0,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 unroll=cfg.unroll_scans)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"ckv": c_new, "krope": krope_new}


def make_continue_prefill(cfg: ModelConfig, page_size: int):
    """cont(params, pool, page_ids, tokens (1,S)[, last_pos])
    -> (last-real-position logits, suffix kv).

    Prefills the divergent suffix of a prompt whose first
    ``page_ids.shape[0] * page_size`` tokens are already resident in the
    page pool as a shared chain. The prefix KV is gathered from the pool
    *inside* the jit, so the caller never materializes it; only the
    suffix's own KV comes back (per-layer leaves ``(L, 1, S, ...)``) for
    scattering into the request's fresh pages.

    ``last_pos`` (traced) selects the logits of suffix position
    ``last_pos - 1`` instead of ``-1`` — for callers that right-pad every
    suffix to one canonical width, the same single-compiled-shape
    discipline ``make_prefill``'s padded path uses: XLA kernel rounding
    is shape-dependent, so one suffix shape per prefix is what keeps a
    shared admit's KV and first-token logits bitwise independent of this
    request's suffix length (causal attention makes real positions
    independent of the zero-padded tail). With padded suffixes the
    continuation retraces per prefix page count only.
    """
    if cfg.family not in SHAREABLE_FAMILIES:
        raise ValueError(
            f"prefix sharing requires a fully paged cache; family "
            f"{cfg.family!r} keeps per-row recurrent state")

    def cont(params, pool, page_ids, tokens, last_pos=None):
        b, s = tokens.shape
        p0 = page_ids.shape[0] * page_size     # static -> positions static
        h = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(
            p0 + jnp.arange(s, dtype=jnp.int32), (b, s))
        prefix_kv = kvcache.chain_view(pool["kv"], page_ids)

        def block(x, xs):
            p, pkv = xs
            xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
            if cfg.mla is not None:
                a, kv = _mla_cont_attn(p["attn"], xn, cfg, positions,
                                       pkv, p0)
            else:
                a, kv = _gqa_cont_attn(p["attn"], xn, cfg, positions,
                                       pkv, p0)
            x = x + a
            xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if "moe" in p:
                y, _ = moe_lib.moe_ffn(p["moe"], xn, cfg)
            else:
                y = mlp(p["mlp"], xn)
            return x + y, kv

        if cfg.family == "moe" and cfg.moe.first_dense:
            fd = cfg.moe.first_dense
            split = lambda t: (jax.tree.map(lambda a: a[:fd], t),
                               jax.tree.map(lambda a: a[fd:], t))
            pkv_d, pkv_m = split(prefix_kv)
            h, kv_d = E._maybe_scan(block, h,
                                    (params["dense_blocks"], pkv_d),
                                    cfg.scan_layers)
            h, kv_m = E._maybe_scan(block, h,
                                    (params["moe_blocks"], pkv_m),
                                    cfg.scan_layers)
            kv = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                              kv_d, kv_m)
        elif cfg.family == "moe":
            h, kv = E._maybe_scan(block, h, (params["moe_blocks"],
                                             prefix_kv), cfg.scan_layers)
        else:
            h, kv = E._maybe_scan(block, h, (params["blocks"], prefix_kv),
                                  cfg.scan_layers)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        if last_pos is not None:
            h = jax.lax.dynamic_slice_in_dim(h, last_pos - 1, 1, axis=1)
        else:
            h = h[:, -1:]
        logits = unembed(params["embed"], h, cfg.vocab_size)
        return logits, kv

    return cont
