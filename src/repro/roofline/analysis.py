"""Three-term roofline from a compiled dry-run artifact (no hardware needed).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / ICI_link_bw

The SPMD-partitioned HLO is a *per-device* program, so cost_analysis() flops/
bytes are already per-device; dividing global quantities by chip count gives
the same numbers (the brief's formulas). Collective wire bytes are parsed
from the HLO text: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we take the per-device operand size and
apply the standard ring-algorithm wire multiplier:

  all-reduce       2 * s * (g-1)/g      (reduce-scatter + all-gather phases)
  all-gather       out * (g-1)/g        (each shard forwarded g-1 times)
  reduce-scatter   in * (g-1)/g
  all-to-all       s * (g-1)/g
  collective-permute  s                 (one hop)

Hardware constants are TPU v5e-class per chip: 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field, asdict
from typing import Any, Optional

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")

# `%x.1 = bf16[16,1024]{1,0} all-gather(...)` — also matches tuple-less async
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")

_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: tuple
    elem_bytes: int
    group_size: int
    wire_bytes: float

    @property
    def tensor_bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * self.elem_bytes


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1)
        return len([t for t in first.split(",") if t.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,g]<=[...]: G groups of g members
        return int(m.group(2))
    return default


def _wire_multiplier(kind: str, g: int) -> float:
    if kind.startswith("collective-permute"):
        return 1.0            # one hop, independent of any group annotation
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind.startswith("all-reduce"):
        return 2.0 * frac
    if kind.startswith("all-gather"):
        return frac           # applied to the (gathered) result size below
    if kind.startswith("reduce-scatter"):
        return frac           # applied to the (full) operand size
    if kind.startswith("all-to-all"):
        return frac
    return 1.0                # collective-permute: one hop


def parse_collectives(hlo_text: str, default_group: int = 1
                      ) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if not any(k in line for k in _COLLECTIVE_KINDS):
            continue
        if "-done" in line:          # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        g = _group_size(line, default_group)
        if dtype not in _DTYPE_BYTES:
            # exotic element type (e.g. f8e8m0): keep the op with zero
            # elem/wire bytes instead of dropping it silently — analyze()
            # surfaces the undercount in the report note and per-kind table.
            ops.append(CollectiveOp(kind.replace("-start", ""), dtype, shape,
                                    0, g, 0.0))
            continue
        eb = _DTYPE_BYTES[dtype]
        n = 1
        for d in shape:
            n *= d
        size = n * eb
        # result-size semantics per kind: all-gather result is the gathered
        # tensor; reduce-scatter result is the shard (operand = shard * g)
        if kind.startswith("reduce-scatter"):
            wire = size * g * _wire_multiplier(kind, g)
        else:
            wire = size * _wire_multiplier(kind, g)
        ops.append(CollectiveOp(kind.replace("-start", ""), dtype, shape, eb,
                                g, wire))
    return ops


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float                    # structural model (see memory_model)
    collective_s: float
    model_flops_global: float
    useful_flops_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str
    hlo_memory_s: float = 0.0          # unfused upper bound, reference only
    model_bytes_per_device: float = 0.0
    collectives_by_kind: dict = field(default_factory=dict)
    memory_per_device_bytes: Optional[dict] = None
    note: str = ""

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """useful-compute time / modelled step time (MFU-like, structural)."""
        if self.step_s <= 0 or self.chips <= 0:
            return 0.0
        ideal = self.model_flops_global / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.step_s

    def to_json(self) -> str:
        d = asdict(self)
        d["step_s"] = self.step_s
        d["roofline_fraction"] = self.roofline_fraction()
        return json.dumps(d, indent=1)


def analyze(*, arch: str, shape: str, mesh_desc: str, chips: int,
            cost: dict, hlo_text: str, model_flops_global: float,
            memory_stats: Optional[dict] = None,
            default_group: int = 1,
            wire_bytes_override: Optional[float] = None,
            model_bytes_per_device: Optional[float] = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    ops = parse_collectives(hlo_text, default_group)
    wire = (wire_bytes_override if wire_bytes_override is not None
            else sum(o.wire_bytes for o in ops))
    by_kind: dict[str, dict] = {}
    unknown = [o for o in ops if o.elem_bytes == 0]
    for o in ops:
        e = by_kind.setdefault(o.kind, {"count": 0, "wire_bytes": 0.0,
                                        "tensor_bytes": 0})
        e["count"] += 1
        e["wire_bytes"] += o.wire_bytes
        e["tensor_bytes"] += o.tensor_bytes
        if o.elem_bytes == 0:
            e["unknown_dtype"] = e.get("unknown_dtype", 0) + 1
    note = ""
    if unknown:
        dts = ", ".join(sorted({o.dtype for o in unknown}))
        note = (f"{len(unknown)} collective op(s) with unknown dtype(s) "
                f"[{dts}] counted with zero wire bytes — collective term "
                "is a lower bound")
    compute_s = flops / PEAK_FLOPS_BF16
    hlo_memory_s = byts / HBM_BW
    mem_bytes = (model_bytes_per_device if model_bytes_per_device is not None
                 else byts)
    memory_s = mem_bytes / HBM_BW
    collective_s = wire / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_global / (flops * chips)) if flops > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops_per_device=flops, hlo_bytes_per_device=byts,
        wire_bytes_per_device=wire, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops_global=model_flops_global,
        useful_flops_ratio=useful, bottleneck=bottleneck,
        hlo_memory_s=hlo_memory_s,
        model_bytes_per_device=float(mem_bytes),
        collectives_by_kind=by_kind, memory_per_device_bytes=memory_stats,
        note=note)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training (fwd+bwd), 2·N_active·D for
    forward-only kinds (prefill/decode), plus the causal attention term
    (4 flops per q·k pair fwd, 12 with backward)."""
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    n = cfg.n_active_params()
    param_mult = 6.0 if shape.kind == "train" else 2.0
    attn_mult = 12.0 if shape.kind == "train" else 4.0
    base = param_mult * n * tokens
    hd = cfg.resolved_head_dim
    s_kv = shape.seq_len
    causal_frac = 0.5 if shape.kind != "decode" else 1.0
    attn = (attn_mult * cfg.n_layers * cfg.n_heads * hd * s_kv * causal_frac
            * tokens)
    return base + attn
