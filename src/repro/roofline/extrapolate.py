"""Exact HLO cost accounting via depth-variant extrapolation.

XLA's HloCostAnalysis counts a while-loop *body once*, not x trip-count, so
a scanned-layers model under-reports flops/bytes/collective-bytes by ~L x.
Rather than trusting that, the dry-run lowers 2-3 SMALL UNROLLED variants of
each config (1-3 layers, ``scan_layers=False`` + ``unroll_scans=True`` so
the attention kv loop / ssm & mlstm chunk loops / moe token loops are
python-unrolled too), fits the linear model

    cost = a + sum_t b_t * n_t        (t = block type: dense/moe/global/...)

and extrapolates to the full depth. 'a' captures depth-independent work
(embedding, unembed+CE, optimizer elementwise on non-stacked leaves, MTP);
'b_t' captures per-layer work *including* remat recompute and per-layer
collectives, because the variants unroll exactly what the deployed scanned
program re-runs per iteration.

Known residual undercount (documented): the sLSTM time-step recurrence
(xlstm) keeps a per-token scan; its in-loop recurrent matmul
(4 * nh * dh^2 * B flops/step) is added analytically below.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _rep(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, scan_layers=False, unroll_scans=True,
                               remat=cfg.remat, **kw)


def depth_variants(cfg: ModelConfig):
    """[(variant_cfg, counts)], full_counts — linear-model sample points."""
    if cfg.family in ("dense", "audio", "vlm"):
        return ([(_rep(cfg, n_layers=1), {"L": 1}),
                 (_rep(cfg, n_layers=2), {"L": 2})],
                {"L": cfg.n_layers})
    if cfg.family == "moe":
        m = cfg.moe
        if m.first_dense:
            def mk(d, mm):
                return _rep(cfg, n_layers=d + mm,
                            moe=dataclasses.replace(m, first_dense=d))
            return ([(mk(1, 1), {"d": 1, "m": 1}),
                     (mk(1, 2), {"d": 1, "m": 2}),
                     (mk(2, 1), {"d": 2, "m": 1})],
                    {"d": m.first_dense, "m": cfg.n_layers - m.first_dense})
        return ([(_rep(cfg, n_layers=1), {"m": 1}),
                 (_rep(cfg, n_layers=2), {"m": 2})],
                {"m": cfg.n_layers})
    if cfg.family == "hybrid":
        def mk(g, s):
            return _rep(cfg, n_layers=g + s, n_global_layers=g)
        return ([(mk(1, 1), {"g": 1, "s": 1}),
                 (mk(1, 2), {"g": 1, "s": 2}),
                 (mk(2, 1), {"g": 2, "s": 1})],
                {"g": cfg.n_global_layers,
                 "s": cfg.n_layers - cfg.n_global_layers})
    if cfg.family == "ssm":
        e = cfg.xlstm.slstm_every
        return ([(_rep(cfg, n_layers=e), {"k": 1}),
                 (_rep(cfg, n_layers=2 * e), {"k": 2})],
                {"k": cfg.n_layers // e})
    raise ValueError(cfg.family)


def solve_and_extrapolate(samples: list[tuple[dict, float]],
                          full: dict) -> float:
    keys = sorted(full)
    a = np.array([[1.0] + [float(c.get(k, 0)) for k in keys]
                  for c, _ in samples])
    b = np.array([v for _, v in samples])
    coef, *_ = np.linalg.lstsq(a, b, rcond=None)
    val = coef[0] + sum(coef[1 + i] * full[k] for i, k in enumerate(keys))
    return float(max(val, 0.0))


def slstm_recurrent_flops(cfg: ModelConfig, shape: ShapeConfig,
                          train: bool) -> float:
    """Analytic adjunct for the per-token sLSTM recurrence (see module doc)."""
    if cfg.family != "ssm":
        return 0.0
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    n_slstm = cfg.n_layers // cfg.xlstm.slstm_every
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    fwd = 2.0 * 4 * nh * dh * dh * tokens * n_slstm
    return fwd * (3.0 if train else 1.0)   # bwd ~ 2x fwd
