"""Per-kernel roofline placement from compiled cost analysis.

``kernel_report(fn, args)`` lowers + compiles ``fn`` with ``jax.jit`` and
reads ``cost_analysis()`` flops / bytes-accessed to place the kernel on the
single-chip compute/memory roofline (same hardware constants as the step
roofline in ``analysis.py``):

  compute_s = flops / PEAK_FLOPS_BF16
  memory_s  = bytes / HBM_BW
  bound     = whichever ceiling is higher; intensity vs the ridge point
              (peak_flops / hbm_bw) tells the same story per byte.

``measure=True`` additionally times the compiled executable and records the
achieved fraction (roofline time / measured time). Off-TPU both numbers
describe the *interpret/XLA-CPU* artifact, not the TPU kernel — callers that
want hardware-honest FLOP counts off-TPU pass ``flops_override`` /
``bytes_override`` from an analytic model or a jnp mirror of the kernel math
(see ``benchmarks/kernel_roofline.py``).
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass
from typing import Optional

import jax

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS_BF16

RIDGE_INTENSITY = PEAK_FLOPS_BF16 / HBM_BW     # flops/byte at the roof knee


@dataclass
class KernelReport:
    name: str
    flops: float
    bytes_accessed: float
    intensity: float                  # flops per HBM byte
    ridge_intensity: float            # peak_flops / hbm_bw
    compute_s: float
    memory_s: float
    roofline_s: float                 # max(compute_s, memory_s)
    bound: str                        # "compute" | "memory"
    measured_s: Optional[float] = None
    achieved_fraction: Optional[float] = None   # roofline_s / measured_s
    note: str = ""

    def to_dict(self) -> dict:
        return asdict(self)


def _cost_dict(compiled) -> dict:
    """cost_analysis() is a dict, a list of dicts (one per computation), or
    None depending on backend/jax version — normalise to one dict."""
    try:
        cost = compiled.cost_analysis()
    except Exception:  # noqa: BLE001  backends may not implement it
        return {}
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        return dict(cost)
    except TypeError:
        return {}


def kernel_report(fn, args, *, name: str = "", measure: bool = False,
                  iters: int = 3,
                  flops_override: Optional[float] = None,
                  bytes_override: Optional[float] = None) -> KernelReport:
    """Compile ``fn(*args)`` and place it on the compute/memory roofline."""
    jfn = jax.jit(fn)
    compiled = jfn.lower(*args).compile()
    cost = _cost_dict(compiled)
    note = "" if cost else "cost_analysis unavailable on this backend"
    flops = float(cost.get("flops", 0.0) if flops_override is None
                  else flops_override)
    byts = float(cost.get("bytes accessed", 0.0) if bytes_override is None
                 else bytes_override)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    roofline_s = max(compute_s, memory_s)
    intensity = flops / byts if byts > 0 else 0.0
    measured = None
    achieved = None
    if measure:
        out = compiled(*args)
        jax.block_until_ready(out)     # warm-up outside the timer
        t0 = time.perf_counter()
        for _ in range(iters):
            out = compiled(*args)
        jax.block_until_ready(out)
        measured = (time.perf_counter() - t0) / iters
        achieved = roofline_s / measured if measured > 0 else 0.0
        if jax.default_backend() != "tpu":
            note = (note + "; " if note else "") + \
                "measured off-TPU: achieved fraction is not hardware-honest"
    return KernelReport(
        name=name or getattr(fn, "__name__", "kernel"),
        flops=flops, bytes_accessed=byts, intensity=intensity,
        ridge_intensity=RIDGE_INTENSITY, compute_s=compute_s,
        memory_s=memory_s, roofline_s=roofline_s,
        bound="compute" if compute_s >= memory_s else "memory",
        measured_s=measured, achieved_fraction=achieved, note=note)
