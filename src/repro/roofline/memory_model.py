"""Structural HBM-traffic model (fusion-aware), per device per step.

XLA's ``bytes accessed`` treats every HLO op as if operands stream from HBM
— with no fusion credit it overstates traffic by ~30x (granite train_4k:
6 TB/device/step), which would mark every cell memory-bound and destroy the
analysis. The roofline's memory term instead uses this structural model of
traffic that MUST cross HBM on a TPU (weights streamed once per use,
activations at remat boundaries, optimizer state read+write, KV cache
streamed per token); the raw HLO number is still recorded in the artifact
as ``hlo_memory_s`` for reference.

Terms (per device):
  train:   state shards r/w (params, mu, nu, grads)            8 x P/chips
           gathered weights, fwd + bwd reads                   2 x P_use/TP
           activations: ~8 passes x tokens_local x d x L x 2B  (remat: save
             boundary, recompute fwd, bwd read/write)
           logits + CE: ~6 passes x tokens_local x V/TP x 2B
  prefill: 1 x gathered weights + ~4 activation passes + cache write
  decode:  1 x gathered ACTIVE weights + full cache read + tiny vectors
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _mesh_sizes(mesh):
    return dict(mesh.shape)


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh,
                          cache_bytes: int = 0) -> float:
    sizes = _mesh_sizes(mesh)
    chips = int(np.prod(list(sizes.values())))
    tp = sizes.get("model", 1)
    dp = max(chips // tp, 1)
    d = cfg.d_model
    L = cfg.n_layers
    P = 2.0 * cfg.n_params()            # bf16 total param bytes
    P_active = 2.0 * cfg.n_active_params()
    vocab_local = cfg.padded_vocab / tp * 2.0  # bf16 logits slice per tok

    if shape.kind == "train":
        tokens_local = shape.global_batch * shape.seq_len / dp
        state_io = 8.0 * P / chips                      # p,mu,nu r/w + g r/w
        weights_io = 2.0 * P_active / tp                # fwd + bwd streams
        act_io = 8.0 * tokens_local * d * 2.0 * L
        logits_io = 3.0 * tokens_local * vocab_local * 2.0   # fwd+bwd, f32ish
        return state_io + weights_io + act_io + logits_io
    if shape.kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / dp
        weights_io = P_active / tp
        act_io = 4.0 * tokens_local * d * 2.0 * L
        cache_io = cache_bytes / chips
        return weights_io + act_io + cache_io
    # decode: one token per sequence
    tokens_local = shape.global_batch / dp if shape.global_batch >= dp else 1
    weights_io = P_active / tp
    cache_io = cache_bytes / chips                      # stream the cache
    act_io = 4.0 * tokens_local * d * 2.0 * L
    return weights_io + cache_io + act_io
