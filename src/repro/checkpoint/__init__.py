from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      default_lossy_policy)
from repro.checkpoint import serialization

__all__ = ["CheckpointConfig", "CheckpointManager", "default_lossy_policy",
           "serialization"]
