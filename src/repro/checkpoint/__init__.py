from repro.checkpoint.manager import (CheckpointConfig, CheckpointManager,
                                      default_lossy_policy)
from repro.checkpoint import serialization
from repro.checkpoint.serialization import CheckpointCorruptError

__all__ = ["CheckpointConfig", "CheckpointCorruptError", "CheckpointManager",
           "default_lossy_policy", "serialization"]
