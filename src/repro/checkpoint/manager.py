"""CheckpointManager: the checkpoint workload as ONE registered pipeline.

Checkpointing is the paper's motivating I/O problem (QE restart files,
hundreds of GB, written every few steps for walltime/failure reasons). The
manager no longer forks the in-situ engine — it registers a single
declarative pipeline into a ``repro.core.runtime.PipelineRuntime``:

    DeviceStage  (HYBRID only) Pallas spectral-lossy on the moment leaves —
                 ONE fused dispatch for the whole tree; the hand-off then
                 ships int8 coefficients + scales (~4-50x smaller — paper
                 Fig. 8/9, NEKO lossy-on-GPU)
    Handoff      two-phase: the loop only *dispatches* the D2H copies
                 (``handoff/dispatch``); ``state_to_host`` + bf16-key
                 bookkeeping materialize on the consumer side, overlapped
                 with the next steps (JAX arrays are immutable, so the
                 deferred snapshot is exact)
    HostStage    'encode': lossless framing of every leaf (core codecs,
                 chunk-parallel on the shared codec pool)
    Sink         'write': blobs -> manifest -> atomic directory rename,
                 then lock-guarded retention

SYNC / ASYNC / HYBRID are scheduling policies of the shared runtime
(Fig. 1, paper Figs. 10-12), not manager code paths. A runtime can be
shared with other in-situ tasks (the training loop passes its own), so
checkpoint writes and analytics draw from the same p_i worker pool.

Durability: blobs -> manifest -> atomic directory rename; a reader can
never observe a partial checkpoint. Retention keeps the newest K (guarded
by the manager lock — multiple async workers may finish writes
concurrently). ``restore`` re-places leaves under the *current* mesh's
shardings (elastic restart).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import serialization as ser
from repro.core.runtime import (PipelineRuntime, PipelineTask, Placement,
                                Stage)
from repro.core.telemetry import Telemetry

PyTree = Any

# historical name, same enum as the runtime's Placement
InSituMode = Placement

_STEP_RE = re.compile(r"^step_(\d{9})$")


def default_lossy_policy(key: str) -> bool:
    """Lossy only for optimizer moments (noise-dominated statistics)."""
    return (".mu" in key or ".nu" in key or "'mu'" in key or "'nu'" in key
            or "moment" in key)


@dataclass
class CheckpointConfig:
    directory: str
    mode: Placement = Placement.ASYNC
    every: int = 100
    keep: int = 3
    lossless: str = "zlib"
    lossy_eps: float = 1e-2
    lossy_moments: bool = True
    p_i: int = 2                      # workers for a manager-owned runtime
    staging_capacity: int = 2
    chunk_parallel: bool = True       # fan leaf chunks out on the codec pool


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig,
                 telemetry: Optional[Telemetry] = None,
                 runtime: Optional[PipelineRuntime] = None) -> None:
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self.reports: list[ser.SaveReport] = []
        self._lock = threading.Lock()
        self._owns_runtime = runtime is None
        if runtime is None:
            self.telemetry = telemetry or Telemetry()
            runtime = PipelineRuntime(
                workers=cfg.p_i, staging_capacity=cfg.staging_capacity,
                telemetry=self.telemetry)
        else:
            if telemetry is not None and telemetry is not runtime.telemetry:
                raise ValueError(
                    "pass either a telemetry or a runtime (whose telemetry "
                    "is used), not two different objects")
            self.telemetry = runtime.telemetry
        self.runtime = runtime
        device_stage = (self._device_lossy
                        if cfg.mode is Placement.HYBRID and cfg.lossy_moments
                        else None)
        self._task = self.runtime.register(PipelineTask(
            name="checkpoint",
            source="ckpt_state",
            placement=cfg.mode,
            every=1,                 # save()/maybe_save gate on cfg.every
            device_stage=device_stage,
            handoff=self._handoff,
            host_stages=(Stage("encode", self._encode_stage),),
            sink=self._write_sink,
        ))

    # -- pipeline stages ------------------------------------------------------

    def _lossy_policy(self) -> Optional[Callable[[str], bool]]:
        return default_lossy_policy if self.cfg.lossy_moments else None

    def _device_lossy(self, step: int, payload: tuple) -> tuple:
        """Device stage (HYBRID): spectral-lossy the moment leaves in-place."""
        from repro.kernels import ops as kops
        state, meta = payload
        state = kops.spectral_compress_tree(state, self.cfg.lossy_eps,
                                            default_lossy_policy)
        return state, meta

    def _handoff(self, payload: tuple) -> dict:
        """Device->host transfer + bf16 bookkeeping (numpy has no bf16)."""
        state, meta = payload
        host_state = ser.state_to_host(state)
        bf16_keys = {
            k for (p, l) in jax.tree_util.tree_flatten_with_path(state)[0]
            if l is not None and getattr(l, "dtype", None) == jax.numpy.bfloat16
            for k in [jax.tree_util.keystr(p)]}
        return {"state": host_state, "bf16_keys": bf16_keys,
                "meta": meta or {}}

    def _codec_pool(self):
        from repro.core import codecs
        return codecs.codec_pool() if self.cfg.chunk_parallel else None

    def _encode_stage(self, step: int, payload: dict) -> dict:
        """Host stage: lossless-encode every leaf (pure compute, no I/O).

        Chunks of one large leaf compress in parallel on the shared codec
        pool — the stdlib codecs release the GIL, so a single encode worker
        saturates spare host cores without stealing runtime workers.
        """
        encoded = ser.encode_blobs(
            payload["state"], lossless=self.cfg.lossless,
            eps=self.cfg.lossy_eps, lossy_policy=self._lossy_policy(),
            bf16_keys=payload["bf16_keys"], pool=self._codec_pool())
        return {"encoded": encoded, "meta": payload["meta"]}

    def _write_sink(self, step: int, payload: dict) -> ser.SaveReport:
        """Sink: atomic write (blobs -> manifest -> rename) + retention."""
        tmp = os.path.join(self.cfg.directory, f".tmp_step_{step:09d}")
        final = os.path.join(self.cfg.directory, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        entries = ser.write_encoded(tmp, payload["encoded"])
        ser.write_manifest(tmp, step, entries, payload["meta"])
        ser.commit(tmp, final)
        raw = sum(e["raw_bytes"] for e in entries.values())
        stored = sum(e["bytes"] for e in entries.values())
        report = ser.SaveReport(step, raw, stored, len(entries),
                                sum(1 for e in entries.values() if e["lossy"]))
        with self._lock:
            self.reports.append(report)
            # retention under the lock: concurrent async workers would
            # otherwise interleave list_steps()/rmtree
            self._retain_locked()
        return report

    def _retain_locked(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.cfg.keep] if self.cfg.keep > 0 else []:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- write path -----------------------------------------------------------

    def save(self, step: int, state: PyTree, meta: Optional[dict] = None) -> None:
        """Checkpoint one training state via the registered pipeline."""
        self.runtime.submit(step, {"ckpt_state": lambda: (state, meta)})

    def maybe_save(self, step: int, state: PyTree,
                   meta: Optional[dict] = None) -> bool:
        if step % self.cfg.every:
            return False
        self.save(step, state, meta)
        return True

    # -- read path ------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.cfg.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[int, PyTree]:
        """Elastic restore: re-places leaves under the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        with self.telemetry.span("checkpoint/restore", step=step):
            state = ser.read_state(d, template, shardings,
                                   pool=self._codec_pool())
        return step, state

    # -- lifecycle ------------------------------------------------------------

    def finish(self) -> None:
        if self._owns_runtime:
            self.runtime.drain()

    def wait_idle(self, timeout: float = 600.0) -> None:
        """Block until queued checkpoints are written (tests/end-of-run)."""
        self.runtime.wait_idle(timeout=timeout)
