"""CheckpointManager: sync / async / hybrid checkpointing as in-situ tasks.

Checkpointing is the paper's motivating I/O problem (QE restart files,
hundreds of GB, written every few steps for walltime/failure reasons). The
manager implements all three placements of Fig. 1 for the *compression +
write* work:

  SYNC   : hand-off + compress + write inline — the loop (and the device,
           which has nothing queued) stalls. Baseline, paper Fig. 10.
  ASYNC  : the loop blocks only for the device->host hand-off; compression
           and file I/O run on the in-situ workers (paper Fig. 11/12 — QE
           with ADIOS2 async compression).
  HYBRID : the spectral lossy stage runs on-device *inside a jit* (Pallas),
           the hand-off ships only int8 coefficients + scales (~4-50x
           smaller), the lossless stage + write run async on workers
           (paper Fig. 8/9 — NEKO lossy-on-GPU + Bzip2-on-CPU).

Durability: blobs -> manifest -> atomic directory rename; a reader can never
observe a partial checkpoint. Retention keeps the newest K. ``restore``
re-places leaves under the *current* mesh's shardings (elastic restart).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import serialization as ser
from repro.core.insitu import InSituEngine, InSituMode, InSituTask
from repro.core.telemetry import Telemetry

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{9})$")


def default_lossy_policy(key: str) -> bool:
    """Lossy only for optimizer moments (noise-dominated statistics)."""
    return (".mu" in key or ".nu" in key or "'mu'" in key or "'nu'" in key
            or "moment" in key)


@dataclass
class CheckpointConfig:
    directory: str
    mode: InSituMode = InSituMode.ASYNC
    every: int = 100
    keep: int = 3
    lossless: str = "zlib"
    lossy_eps: float = 1e-2
    lossy_moments: bool = True
    p_i: int = 2                      # workers for async/hybrid
    staging_capacity: int = 2


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.cfg = cfg
        self.telemetry = telemetry or Telemetry()
        os.makedirs(cfg.directory, exist_ok=True)
        self.reports: list[ser.SaveReport] = []
        self._lock = threading.Lock()
        self._engine: Optional[InSituEngine] = None
        if cfg.mode in (InSituMode.ASYNC, InSituMode.HYBRID):
            task = InSituTask("checkpoint", "ckpt_state", self._write_task,
                              mode=InSituMode.ASYNC, every=1)
            self._engine = InSituEngine(
                [task], p_i=cfg.p_i, staging_capacity=cfg.staging_capacity,
                telemetry=self.telemetry)

    # -- write path ---------------------------------------------------------

    def _lossy_policy(self) -> Optional[Callable[[str], bool]]:
        return default_lossy_policy if self.cfg.lossy_moments else None

    def _write_task(self, step: int, payload: dict) -> ser.SaveReport:
        """Host-side compress+write (runs inline for SYNC, on workers else)."""
        host_state: dict[str, np.ndarray] = payload["state"]
        bf16_keys: set = payload["bf16_keys"]
        meta: dict = payload["meta"]
        tmp = os.path.join(self.cfg.directory, f".tmp_step_{step:09d}")
        final = os.path.join(self.cfg.directory, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        entries = ser.write_blobs(
            host_state, tmp, lossless=self.cfg.lossless,
            eps=self.cfg.lossy_eps, lossy_policy=self._lossy_policy(),
            bf16_keys=bf16_keys)
        ser.write_manifest(tmp, step, entries, meta)
        ser.commit(tmp, final)
        raw = sum(e["raw_bytes"] for e in entries.values())
        stored = sum(e["bytes"] for e in entries.values())
        report = ser.SaveReport(step, raw, stored, len(entries),
                                sum(1 for e in entries.values() if e["lossy"]))
        with self._lock:
            self.reports.append(report)
        self._retain()
        return report

    def _retain(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.cfg.keep] if self.cfg.keep > 0 else []:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def save(self, step: int, state: PyTree, meta: Optional[dict] = None) -> None:
        """Checkpoint one training state according to the configured mode."""
        if self.cfg.mode is InSituMode.HYBRID and self.cfg.lossy_moments:
            # device-side lossy stage (Pallas spectral codec) BEFORE the
            # hand-off: the D2H transfer ships int8 coefficients + scales.
            from repro.kernels import ops as kops
            from repro.kernels.ref import Compressed
            policy = default_lossy_policy
            with self.telemetry.span("insitu-device/lossy", step=step):
                flat, treedef = jax.tree_util.tree_flatten_with_path(state)
                new_leaves = []
                for path, leaf in flat:
                    key = jax.tree_util.keystr(path)
                    if leaf is not None and policy(key):
                        new_leaves.append(kops.spectral_compress(
                            leaf, self.cfg.lossy_eps))
                    else:
                        new_leaves.append(leaf)
                state = jax.tree_util.tree_unflatten(treedef, new_leaves)
        with self.telemetry.span("step/handoff", step=step, task="checkpoint"):
            host_state = ser.state_to_host(state)
            bf16_keys = {
                k for (p, l) in jax.tree_util.tree_flatten_with_path(state)[0]
                if l is not None and getattr(l, "dtype", None) == jax.numpy.bfloat16
                for k in [jax.tree_util.keystr(p)]}
        payload = {"state": host_state, "bf16_keys": bf16_keys,
                   "meta": meta or {}}
        if self.cfg.mode is InSituMode.SYNC:
            with self.telemetry.span("insitu-sync/checkpoint", step=step):
                self._write_task(step, payload)
        else:
            assert self._engine is not None
            from repro.core.staging import StagedItem
            self._engine.staging.put(StagedItem(step, "checkpoint", payload))

    def maybe_save(self, step: int, state: PyTree,
                   meta: Optional[dict] = None) -> bool:
        if step % self.cfg.every:
            return False
        self.save(step, state, meta)
        return True

    # -- read path -----------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.cfg.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[int, PyTree]:
        """Elastic restore: re-places leaves under the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        with self.telemetry.span("checkpoint/restore", step=step):
            state = ser.read_state(d, template, shardings)
        return step, state

    # -- lifecycle --------------------------------------------------------------

    def finish(self) -> None:
        if self._engine is not None:
            self._engine.finish()

    def wait_idle(self, timeout: float = 600.0) -> None:
        """Block until queued checkpoints are written (tests/end-of-run)."""
        if self._engine is None:
            return
        t0 = time.time()
        while len(self._engine.staging) and time.time() - t0 < timeout:
            time.sleep(0.01)
        # one more grace period for in-flight task fn
        while (self._engine.staging.puts > self._engine.staging.gets
               and time.time() - t0 < timeout):
            time.sleep(0.01)
        t0 = time.time()
        while time.time() - t0 < timeout:
            with self._lock:
                done = len(self.reports)
            if done >= self._engine.staging.gets:
                return
            time.sleep(0.01)
