"""CheckpointManager: the checkpoint workload as ONE registered pipeline.

Checkpointing is the paper's motivating I/O problem (QE restart files,
hundreds of GB, written every few steps for walltime/failure reasons). The
manager no longer forks the in-situ engine — it registers a single
declarative pipeline into a ``repro.core.runtime.PipelineRuntime``:

    DeviceStage  (HYBRID only) Pallas spectral-lossy on the moment leaves —
                 ONE fused dispatch for the whole tree; the hand-off then
                 ships int8 coefficients + scales (~4-50x smaller — paper
                 Fig. 8/9, NEKO lossy-on-GPU)
    Handoff      two-phase: the loop only *dispatches* the D2H copies
                 (``handoff/dispatch``); ``state_to_host`` + bf16-key
                 bookkeeping materialize on the consumer side, overlapped
                 with the next steps (JAX arrays are immutable, so the
                 deferred snapshot is exact)
    HostStage    'encode': lossless framing of every leaf — a FanoutStage
                 whose per-leaf items are stolen by idle runtime workers
                 (many-small-leaf trees encode leaf-parallel), each item
                 additionally chunk-parallel on the shared codec pool
    Sink         'write': packed shard files (v2 offset-table layout; one
                 fsynced shard_NNN.bin instead of a file per leaf) ->
                 manifest -> crash-safe directory publish, then
                 lock-guarded retention

SYNC / ASYNC / HYBRID are scheduling policies of the shared runtime
(Fig. 1, paper Figs. 10-12), not manager code paths. A runtime can be
shared with other in-situ tasks (the training loop passes its own), so
checkpoint writes and analytics draw from the same p_i worker pool.

Durability: blobs -> manifest -> atomic directory rename; a reader can
never observe a partial checkpoint. Retention keeps the newest K (guarded
by the manager lock — multiple async workers may finish writes
concurrently). ``restore`` re-places leaves under the *current* mesh's
shardings (elastic restart).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import serialization as ser
from repro.core import transport
from repro.core.runtime import (FanoutStage, PipelineRuntime, PipelineTask,
                                Placement, Stage)
from repro.core.telemetry import Telemetry

PyTree = Any

# historical name, same enum as the runtime's Placement
InSituMode = Placement

_STEP_RE = re.compile(r"^step_(\d{9})$")


def default_lossy_policy(key: str) -> bool:
    """Lossy only for optimizer moments (noise-dominated statistics)."""
    return (".mu" in key or ".nu" in key or "'mu'" in key or "'nu'" in key
            or "moment" in key)


@dataclass
class CheckpointConfig:
    directory: str
    mode: Placement = Placement.ASYNC
    every: int = 100
    keep: int = 3
    lossless: str = "zlib"
    lossy_eps: float = 1e-2
    lossy_moments: bool = True
    p_i: int = 2                      # workers for a manager-owned runtime
    staging_capacity: int = 2
    chunk_parallel: bool = True       # fan leaf chunks out on the codec pool
    format: int = ser.CHECKPOINT_FORMAT  # 2: packed shards; 1: file per leaf
    shard_count: int = 1              # v2: number of shard_NNN.bin files
    leaf_parallel: bool = True        # fan encode out per leaf on the pool
    mirror: Optional[str] = None      # transport URL replicating committed
                                      # steps (file:// | tcp:// | memory://)

    def __post_init__(self) -> None:
        if self.every < 1:
            raise ValueError(
                f"CheckpointConfig.every must be >= 1, got {self.every}: "
                "maybe_save gates on step % every (every=0 divides by "
                "zero); use save() directly for one-off checkpoints")
        if self.keep < 0:
            raise ValueError(
                f"CheckpointConfig.keep must be >= 0, got {self.keep}")
        if self.format not in (1, ser.CHECKPOINT_FORMAT):
            raise ValueError(
                f"CheckpointConfig.format must be 1 (per-leaf files) or "
                f"{ser.CHECKPOINT_FORMAT} (packed shards), got {self.format}")
        if self.shard_count < 1:
            raise ValueError(
                f"CheckpointConfig.shard_count must be >= 1, "
                f"got {self.shard_count}")


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig,
                 telemetry: Optional[Telemetry] = None,
                 runtime: Optional[PipelineRuntime] = None) -> None:
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        # crash recovery: drop unpublished tmp dirs from dead saves and
        # re-publish a copy stranded mid-commit (see ser.sweep_stale)
        ser.sweep_stale(cfg.directory)
        self.reports: list[ser.SaveReport] = []
        self.mirror_stats = {"steps": 0, "frames": 0, "failures": 0}
        self._mirror = (transport.connect(cfg.mirror, stream="checkpoint")
                        if cfg.mirror else None)
        self._lock = threading.Lock()
        self._owns_runtime = runtime is None
        if runtime is None:
            self.telemetry = telemetry or Telemetry()
            runtime = PipelineRuntime(
                workers=cfg.p_i, staging_capacity=cfg.staging_capacity,
                telemetry=self.telemetry)
        else:
            if telemetry is not None and telemetry is not runtime.telemetry:
                raise ValueError(
                    "pass either a telemetry or a runtime (whose telemetry "
                    "is used), not two different objects")
            self.telemetry = runtime.telemetry
        self.runtime = runtime
        device_stage = (self._device_lossy
                        if cfg.mode is Placement.HYBRID and cfg.lossy_moments
                        else None)
        encode = (FanoutStage("encode", split=self._encode_split,
                              fn=self._encode_leaf_item,
                              gather=self._encode_gather)
                  if cfg.leaf_parallel
                  else Stage("encode", self._encode_stage))
        self._task = self.runtime.register(PipelineTask(
            name="checkpoint",
            source="ckpt_state",
            placement=cfg.mode,
            every=1,                 # save()/maybe_save gate on cfg.every
            device_stage=device_stage,
            handoff=self._handoff,
            host_stages=(encode,),
            sink=self._write_sink,
        ))

    # -- pipeline stages ------------------------------------------------------

    def _lossy_policy(self) -> Optional[Callable[[str], bool]]:
        return default_lossy_policy if self.cfg.lossy_moments else None

    def _device_lossy(self, step: int, payload: tuple) -> tuple:
        """Device stage (HYBRID): spectral-lossy the moment leaves in-place."""
        from repro.kernels import ops as kops
        state, meta = payload
        state = kops.spectral_compress_tree(state, self.cfg.lossy_eps,
                                            default_lossy_policy)
        return state, meta

    def _handoff(self, payload: tuple) -> dict:
        """Device->host transfer + bf16 bookkeeping (numpy has no bf16)."""
        state, meta = payload
        host_state = ser.state_to_host(state)
        bf16_keys = {
            k for (p, l) in jax.tree_util.tree_flatten_with_path(state)[0]
            if l is not None and getattr(l, "dtype", None) == jax.numpy.bfloat16
            for k in [jax.tree_util.keystr(p)]}
        return {"state": host_state, "bf16_keys": bf16_keys,
                "meta": meta or {}}

    def _codec_pool(self):
        from repro.core import codecs
        return codecs.codec_pool() if self.cfg.chunk_parallel else None

    def _encode_stage(self, step: int, payload: dict) -> dict:
        """Serial host stage (``leaf_parallel=False``): walk every leaf."""
        encoded = ser.encode_blobs(
            payload["state"], lossless=self.cfg.lossless,
            eps=self.cfg.lossy_eps, lossy_policy=self._lossy_policy(),
            bf16_keys=payload["bf16_keys"], pool=self._codec_pool())
        return {"encoded": encoded, "meta": payload["meta"]}

    # leaf-parallel encode: one work item per leaf, stolen by idle runtime
    # workers (FanoutStage), gathered before the sink so the commit protocol
    # (blobs -> manifest -> rename) is unchanged. Chunks of a large leaf
    # still fan out on the codec pool — the two pools are distinct, so leaf
    # items never block on their own chunk jobs.

    def _encode_split(self, step: int, payload: dict) -> list:
        bf16_keys = payload["bf16_keys"]
        return [(key, arr, bf16_keys) for key, arr in payload["state"].items()]

    def _encode_leaf_item(self, step: int, item: tuple) -> tuple:
        key, arr, bf16_keys = item
        blob, ent = ser.encode_leaf(
            key, arr, lossless=self.cfg.lossless, eps=self.cfg.lossy_eps,
            lossy_policy=self._lossy_policy(), bf16_keys=bf16_keys,
            pool=self._codec_pool())
        return key, (blob, ent)

    def _encode_gather(self, step: int, payload: dict, results: list) -> dict:
        return {"encoded": dict(results), "meta": payload["meta"]}

    def _write_sink(self, step: int, payload: dict) -> ser.SaveReport:
        """Sink: atomic write (blobs -> manifest -> rename) + retention."""
        tmp = os.path.join(self.cfg.directory, f".tmp_step_{step:09d}")
        final = os.path.join(self.cfg.directory, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        if self.cfg.format >= ser.CHECKPOINT_FORMAT:
            entries = ser.write_encoded_shards(tmp, payload["encoded"],
                                               self.cfg.shard_count)
        else:
            entries = ser.write_encoded(tmp, payload["encoded"])
        ser.write_manifest(tmp, step, entries, payload["meta"])
        ser.commit(tmp, final)
        self._mirror_committed(step, final)
        raw = sum(e["raw_bytes"] for e in entries.values())
        stored = sum(e["bytes"] for e in entries.values())
        report = ser.SaveReport(step, raw, stored, len(entries),
                                sum(1 for e in entries.values() if e["lossy"]))
        with self._lock:
            self.reports.append(report)
            # retention under the lock: concurrent async workers would
            # otherwise interleave list_steps()/rmtree
            self._retain_locked()
        return report

    def _mirror_committed(self, step: int, final: str) -> None:
        """Replicate a committed step through the secondary transport, one
        CODEC_FILE frame per file with the manifest last (the consumer's
        materialized copy honors the same publish-manifest-last protocol).

        Mirroring is strictly after the local commit and *best-effort*: a
        dead replica counts a failure in ``mirror_stats`` instead of
        raising — a TransientError here would send the whole sink back
        through the runtime's retry loop and re-commit an
        already-committed checkpoint."""
        if self._mirror is None:
            return
        try:
            n = transport.send_directory(
                self._mirror, step, final,
                prefix=os.path.basename(final), stream="checkpoint")
            with self._lock:
                self.mirror_stats["steps"] += 1
                self.mirror_stats["frames"] += n
        except Exception:  # noqa: BLE001 - replication never blocks saves
            with self._lock:
                self.mirror_stats["failures"] += 1

    def _retain_locked(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.cfg.keep] if self.cfg.keep > 0 else []:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- write path -----------------------------------------------------------

    def save(self, step: int, state: PyTree, meta: Optional[dict] = None) -> None:
        """Checkpoint one training state via the registered pipeline."""
        self.runtime.submit(step, {"ckpt_state": lambda: (state, meta)})

    def maybe_save(self, step: int, state: PyTree,
                   meta: Optional[dict] = None) -> bool:
        if step % self.cfg.every:
            return False
        self.save(step, state, meta)
        return True

    # -- read path ------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.cfg.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[int, PyTree]:
        """Elastic restore: re-places leaves under the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        with self.telemetry.span("checkpoint/restore", step=step):
            state = ser.read_state(d, template, shardings,
                                   pool=self._codec_pool())
        return step, state

    def read_meta(self, step: Optional[int] = None) -> dict:
        """The meta dict recorded with a checkpoint's manifest (e.g. the
        mesh geometry ``Session.set_checkpoint_meta`` attaches); latest
        step when ``step`` is None."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        return dict(ser.read_manifest(d).get("meta") or {})

    # -- lifecycle ------------------------------------------------------------

    def finish(self) -> None:
        if self._owns_runtime:
            self.runtime.drain()
        if self._mirror is not None:
            try:
                self._mirror.close()
            except Exception:  # noqa: BLE001 - teardown must not raise
                pass

    def wait_idle(self, timeout: float = 600.0) -> None:
        """Block until queued checkpoints are written (tests/end-of-run)."""
        self.runtime.wait_idle(timeout=timeout)
