"""Checkpoint serialization: framed tensors + shard manifest.

Every leaf of the training state becomes one self-describing framed blob
(lossless via core/codecs, or lossy via core/lossy for leaves the policy
allows — optimizer moments by default). A JSON manifest binds the tree
structure to stored bytes and records mesh/topology metadata so a restart
can *reshard elastically*: arrays are restored logically and re-placed under
whatever mesh the resumed job has (the paper's checkpoint/restart-for-
walltime story, plus elasticity).

Layout v2 (packed shards — the default):
    <dir>/step_000123/
        manifest.json        {step, format: 2, leaves: {key: {file, offset,
                              bytes, raw_bytes, lossy, bf16}}, meta}
        shard_000.bin        concatenated framed blobs, offset-addressed
        [shard_NNN.bin ...]  byte-balanced when shard_count > 1
All leaf blobs are packed into few large files bound by the manifest's
offset table, so save cost is IO bandwidth, not per-leaf open/write/fsync
metadata pressure (the small-file scaling failure of parallel-IO folklore),
and restore can readahead each shard sequentially.

Layout v1 (legacy, one file per leaf) is still written by format=1 configs
and always restored: entries without an ``offset`` name a per-leaf
``<key-hash>.bin`` file.

Commit/durability protocol (both layouts):
  1. blobs written and **fsynced** (per shard file / per leaf file),
  2. manifest written to a tmp name, fsynced, renamed into the tmp dir,
  3. the tmp dir is atomically published by ``commit``: any existing final
     dir is first moved *aside* (sibling rename — never deleted while it is
     the only copy), the tmp dir is renamed into place, the parent directory
     is fsynced, and only then is the old copy removed. A checkpoint without
     a manifest is invisible to discovery, so readers never observe partial
     state, and a crash at any point leaves either the old or the new
     checkpoint restorable. ``sweep_stale`` (run on manager init) removes
     crashed tmp dirs and re-publishes a copy stranded mid-commit.
"""
from __future__ import annotations

import hashlib
import json
import mmap
import os
import re
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs, lossy
from repro.kernels.ref import Compressed

PyTree = Any

CHECKPOINT_FORMAT = 2
_SHARD_FMT = "shard_{:03d}.bin"
_TMP_RE = re.compile(r"^\.tmp_step_\d{9}$")
_OLD_RE = re.compile(r"^\.old_(step_\d{9})$")


class CheckpointCorruptError(RuntimeError):
    """A stored blob does not match its manifest entry (truncation/corruption)."""


def _fname(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:16] + ".bin"


def _fsync_dir(path: str) -> None:
    """Durably record directory-entry changes (renames) under ``path``."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass                       # not all filesystems support dir fsync
    finally:
        os.close(fd)


@dataclass
class SaveReport:
    step: int
    raw_bytes: int
    stored_bytes: int
    n_leaves: int
    lossy_leaves: int

    @property
    def ratio(self) -> float:
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.stored_bytes) / self.raw_bytes


def state_to_host(state: PyTree) -> dict[str, np.ndarray | Compressed]:
    """Device->host hand-off: the part the step serializes on."""
    flat = jax.tree_util.tree_flatten_with_path(
        state, is_leaf=lambda x: isinstance(x, Compressed))[0]
    out: dict[str, Any] = {}
    for path, leaf in flat:
        if leaf is None:
            continue
        key = jax.tree_util.keystr(path)
        if isinstance(leaf, Compressed):
            out[key] = Compressed(np.asarray(leaf.q), np.asarray(leaf.scale),
                                  leaf.n_elements, leaf.shape, leaf.dtype)
            continue
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # numpy has no bf16: store the raw 16-bit pattern, remember it
            out[key] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def encode_leaf(key: str, arr: np.ndarray | Compressed, *,
                lossless: str = "zlib", eps: float = 1e-2,
                lossy_policy: Optional[Callable[[str], bool]] = None,
                bf16_keys: Optional[set] = None,
                pool=None) -> tuple[bytes, dict]:
    """Lossless-encode ONE leaf -> (framed blob, manifest entry sans file).

    Pure compute, no I/O. This is the unit the checkpoint pipeline fans out
    across the runtime worker pool (leaf-parallel encode); ``pool``
    additionally fans the chunks of a large leaf out on the shared codec
    executor (GIL-released stdlib codecs).
    """
    if isinstance(arr, Compressed):
        # HYBRID path: the lossy stage already ran on device; only the
        # lossless stage happens here.
        blob, st = lossy.frame_compressed(arr, lossless, pool)
        is_lossy, raw_bytes, is_bf16 = True, st.raw_bytes, False
    else:
        is_lossy = bool(lossy_policy and lossy_policy(key))
        is_bf16 = bool(bf16_keys and key in bf16_keys)
        raw_bytes = int(arr.nbytes)
        if is_lossy:
            # lossy path needs real float values; bf16-as-u16 goes via f32
            a = arr
            if is_bf16:
                a = np.asarray(jnp.asarray(arr.view(np.uint16))
                               .view(jnp.bfloat16).astype(jnp.float32))
            blob, _ = lossy.compress_tensor(a, eps=eps, lossless=lossless,
                                            pool=pool)
        else:
            blob, _ = codecs.encode(arr, lossless, pool=pool)
    return blob, {"bytes": len(blob), "lossy": is_lossy,
                  "raw_bytes": raw_bytes, "bf16": is_bf16}


def encode_blobs(host_state: dict[str, np.ndarray], *,
                 lossless: str = "zlib", eps: float = 1e-2,
                 lossy_policy: Optional[Callable[[str], bool]] = None,
                 bf16_keys: Optional[set] = None,
                 pool=None) -> dict[str, tuple[bytes, dict]]:
    """Serial leaf walk over ``encode_leaf`` (the pipeline fans leaves out)."""
    return {key: encode_leaf(key, arr, lossless=lossless, eps=eps,
                             lossy_policy=lossy_policy, bf16_keys=bf16_keys,
                             pool=pool)
            for key, arr in host_state.items()}


def write_encoded(directory: str,
                  encoded: dict[str, tuple[bytes, dict]]) -> dict[str, dict]:
    """v1 write stage: one fsynced file per leaf; returns manifest entries."""
    os.makedirs(directory, exist_ok=True)
    entries: dict[str, dict] = {}
    for key, (blob, ent) in encoded.items():
        fn = _fname(key)
        with open(os.path.join(directory, fn), "wb") as f:
            f.write(blob)
            f.flush()
            # a published manifest must never point at unwritten blob bytes
            os.fsync(f.fileno())
        entries[key] = {"file": fn, **ent}
    return entries


def write_encoded_shards(directory: str,
                         encoded: dict[str, tuple[bytes, dict]],
                         shard_count: int = 1) -> dict[str, dict]:
    """v2 write stage: pack every blob into ``shard_count`` fsynced files.

    One open/write/fsync per *shard* — independent of leaf count — with the
    manifest's offset table binding each leaf to (file, offset, bytes).
    Leaves pack sequentially in dict order; when ``shard_count > 1`` the
    stream rolls over at byte-balanced boundaries (``shard_count`` is an
    upper bound: a few large leaves may fill the budget in fewer files).
    """
    os.makedirs(directory, exist_ok=True)
    items = list(encoded.items())
    entries: dict[str, dict] = {}
    if not items:
        return entries
    total = sum(len(blob) for _, (blob, _) in items)
    shard_count = max(1, min(int(shard_count), len(items)))
    target = max(1, -(-total // shard_count))          # ceil(total/shards)
    si, offset, f = 0, 0, None
    try:
        for key, (blob, ent) in items:
            if f is None:
                fn = _SHARD_FMT.format(si)
                f = open(os.path.join(directory, fn), "wb")
                offset = 0
            entries[key] = {"file": fn, "offset": offset, **ent}
            f.write(blob)
            offset += len(blob)
            if offset >= target and si < shard_count - 1:
                f.flush()
                os.fsync(f.fileno())
                f.close()
                f, si = None, si + 1
        if f is not None:
            f.flush()
            os.fsync(f.fileno())
            f.close()
            f = None
    finally:
        if f is not None:
            f.close()
    return entries


def write_blobs(host_state: dict[str, np.ndarray], directory: str, *,
                lossless: str = "zlib", eps: float = 1e-2,
                lossy_policy: Optional[Callable[[str], bool]] = None,
                bf16_keys: Optional[set] = None,
                shard_count: int = 1) -> dict[str, dict]:
    """Encode + write in one call (the pipeline splits the two stages)."""
    return write_encoded_shards(directory, encode_blobs(
        host_state, lossless=lossless, eps=eps, lossy_policy=lossy_policy,
        bf16_keys=bf16_keys), shard_count)


def write_manifest(directory: str, step: int, entries: dict[str, dict],
                   meta: Optional[dict] = None) -> None:
    fmt = (CHECKPOINT_FORMAT
           if any("offset" in e for e in entries.values()) else 1)
    manifest = {"step": step, "format": fmt, "leaves": entries,
                "meta": meta or {}}
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, "manifest.json"))
    # durably record the step dir's own entries (shard/blob files + this
    # rename): commit() only fsyncs the *parent*, and without this a power
    # loss after publish could lose the entries inside the published dir
    _fsync_dir(directory)


# serializes commit's aside/publish rename pair against sweep_stale's
# recovery renames: a sweep running inside another manager's aside window
# would otherwise republish the .old_ copy and make the publish rename fail
# with ENOTEMPTY. In-process only — sharing one checkpoint directory across
# processes is out of scope (retention has the same caveat).
_commit_lock = threading.Lock()


def commit(tmp_dir: str, final_dir: str) -> None:
    """Atomic publish that never destroys the only copy of a step.

    Any existing ``final_dir`` is moved aside with a sibling rename (not
    deleted — a crash between a delete and the publish rename would lose
    both copies), the tmp dir is renamed into place, the parent directory's
    entries are fsynced so the publish survives power loss, and only then is
    the displaced copy removed. ``sweep_stale`` re-publishes a copy stranded
    in the aside window by a crash.
    """
    parent = os.path.dirname(os.path.abspath(final_dir))
    old = os.path.join(parent, ".old_" + os.path.basename(final_dir))
    with _commit_lock:
        displaced = False
        if os.path.exists(final_dir):
            if os.path.exists(old):
                shutil.rmtree(old)
            os.replace(final_dir, old)
            displaced = True
        os.replace(tmp_dir, final_dir)
        _fsync_dir(parent)
        if displaced:
            shutil.rmtree(old, ignore_errors=True)


def _latest_mtime(path: str) -> float:
    """Newest mtime of a dir or anything directly inside it.

    The dir's own mtime only moves on entry create/rename — a writer
    streaming into an already-open shard file advances the *file's* mtime,
    so liveness checks must look one level down.
    """
    try:
        newest = os.path.getmtime(path)
        for entry in os.scandir(path):
            try:
                newest = max(newest, entry.stat().st_mtime)
            except OSError:
                pass
    except OSError:
        return 0.0
    return newest


def sweep_stale(directory: str, tmp_grace_s: float = 60.0) -> None:
    """Crash recovery at startup: clear the commit protocol's debris.

    * ``.tmp_step_*`` dirs are unpublished partial saves — remove them,
      *unless* the dir or anything in it was modified within ``tmp_grace_s``
      seconds: a fresh tmp dir may belong to a still-live writer (a
      replacement manager constructed while the previous one's async save
      is mid-sink must not destroy it; it will be swept on a later init
      once it is genuinely stale).
    * ``.old_step_N`` with ``step_N`` present is a displaced copy whose
      replacement committed — remove it.
    * ``.old_step_N`` *without* ``step_N`` means the crash hit between the
      aside rename and the publish rename — move the copy back so the step
      is visible again (serialized against a live in-process ``commit`` by
      the shared lock).
    """
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return
    now = time.time()
    with _commit_lock:
        for name in names:
            path = os.path.join(directory, name)
            if _TMP_RE.match(name):
                if now - _latest_mtime(path) >= tmp_grace_s:
                    shutil.rmtree(path, ignore_errors=True)
                continue
            m = _OLD_RE.match(name)
            if m:
                final = os.path.join(directory, m.group(1))
                if os.path.exists(final):
                    shutil.rmtree(path, ignore_errors=True)
                elif os.path.exists(path):
                    os.replace(path, final)
        _fsync_dir(directory)


def read_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


def _load_shard(path: str):
    """Readahead one shard: mmap (sequential-advised) or a full read.

    Returns a bytes-like whose slices are the leaf blobs; mmap keeps the
    page cache in charge of the actual readahead while letting every leaf
    slice without a per-leaf syscall.
    """
    with open(path, "rb") as f:
        try:
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        except (ValueError, OSError):       # empty file / no-mmap fs
            f.seek(0)
            return f.read()
    if hasattr(mm, "madvise") and hasattr(mmap, "MADV_SEQUENTIAL"):
        try:
            mm.madvise(mmap.MADV_SEQUENTIAL)
        except OSError:
            pass
    return mm


def _fetch_blob(directory: str, key: str, ent: dict, shards: dict) -> bytes:
    """One leaf's stored bytes, validated against the manifest entry."""
    want = int(ent["bytes"])
    if "offset" in ent:                      # v2: slice the packed shard
        data = shards[ent["file"]]
        off = int(ent["offset"])
        blob = bytes(data[off:off + want])
    else:                                    # v1: per-leaf blob file
        try:
            with open(os.path.join(directory, ent["file"]), "rb") as f:
                blob = f.read()
        except FileNotFoundError as e:
            raise CheckpointCorruptError(
                f"checkpoint {directory}: leaf {key!r} names missing blob "
                f"file {ent['file']!r}") from e
    if len(blob) != want:
        raise CheckpointCorruptError(
            f"checkpoint {directory}: leaf {key!r} expected {want} stored "
            f"bytes, found {len(blob)} (truncated "
            f"{'shard' if 'offset' in ent else 'blob'} file {ent['file']!r})")
    return blob


def read_state(directory: str, template: PyTree,
               shardings: Optional[PyTree] = None,
               pool=None) -> PyTree:
    """Restore a pytree; re-place under ``shardings`` if given (elastic).

    v2 checkpoints are read with one sequential-readahead mmap per shard
    file and the per-leaf decode fanned out on ``pool`` (the shared codec
    executor); v1 per-leaf-file checkpoints restore through the same loop,
    one open per leaf. Truncated/corrupt stored bytes raise
    ``CheckpointCorruptError``; a template leaf missing from the manifest
    raises ``KeyError`` naming the leaf (tree-shape drift) instead of
    failing deep inside decode.
    """
    manifest = read_manifest(directory)
    entries = manifest["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    # readahead: map every referenced shard file once, before any decode
    shards = {}
    for fn in sorted({e["file"] for e in entries.values() if "offset" in e}):
        try:
            shards[fn] = _load_shard(os.path.join(directory, fn))
        except FileNotFoundError as e:
            raise CheckpointCorruptError(
                f"checkpoint {directory}: manifest references missing shard "
                f"file {fn!r}") from e
    jobs: list[Optional[tuple]] = []
    for (path, leaf), shd in zip(flat, shard_flat):
        if leaf is None:
            jobs.append(None)
            continue
        key = jax.tree_util.keystr(path)
        ent = entries.get(key)
        if ent is None:
            raise KeyError(
                f"checkpoint {directory} has no entry for template leaf "
                f"{key!r} — the template's tree shape drifted since this "
                f"checkpoint was written ({len(entries)} stored leaves)")
        jobs.append((key, ent, leaf, shd))

    fan_leaves = pool is not None and sum(j is not None for j in jobs) > 1

    def _restore_one(job: Optional[tuple]):
        if job is None:
            return None
        key, ent, leaf, shd = job
        blob = _fetch_blob(directory, key, ent, shards)
        # chunk-level fan-out only when leaves decode serially: nesting both
        # levels on one executor would have leaf jobs block on chunk jobs
        # that cannot be scheduled behind them.
        arr = lossy.decompress_blob(blob, None if fan_leaves else pool)
        arr = jnp.asarray(arr)
        if ent.get("bf16") and not ent["lossy"]:
            arr = arr.view(jnp.bfloat16)
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        want_shape = getattr(leaf, "shape", arr.shape)
        arr = arr.astype(want_dtype).reshape(want_shape)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        return arr

    if fan_leaves:
        leaves = list(pool.map(_restore_one, jobs))
    else:
        leaves = [_restore_one(j) for j in jobs]
    return jax.tree_util.tree_unflatten(treedef, leaves)
