"""Checkpoint serialization: framed tensors + shard manifest.

Every leaf of the training state becomes one self-describing framed blob
(lossless via core/codecs, or lossy via core/lossy for leaves the policy
allows — optimizer moments by default). A JSON manifest binds the tree
structure to blob files and records mesh/topology metadata so a restart can
*reshard elastically*: arrays are restored logically and re-placed under
whatever mesh the resumed job has (the paper's checkpoint/restart-for-
walltime story, plus elasticity).

Layout (one checkpoint):
    <dir>/step_000123/
        manifest.json        {step, leaves: {key: {file, bytes, lossy}}, meta}
        <key-hash>.bin       framed blob per leaf
Commit protocol: blobs first, manifest last, then an atomic rename of the
whole directory (tmp -> final). A checkpoint without a manifest is invisible
to discovery, so readers never see partial state.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs, lossy
from repro.kernels.ref import Compressed

PyTree = Any


def _fname(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:16] + ".bin"


@dataclass
class SaveReport:
    step: int
    raw_bytes: int
    stored_bytes: int
    n_leaves: int
    lossy_leaves: int

    @property
    def ratio(self) -> float:
        if self.raw_bytes == 0:
            return 0.0
        return (self.raw_bytes - self.stored_bytes) / self.raw_bytes


def state_to_host(state: PyTree) -> dict[str, np.ndarray | Compressed]:
    """Device->host hand-off: the part the step serializes on."""
    flat = jax.tree_util.tree_flatten_with_path(
        state, is_leaf=lambda x: isinstance(x, Compressed))[0]
    out: dict[str, Any] = {}
    for path, leaf in flat:
        if leaf is None:
            continue
        key = jax.tree_util.keystr(path)
        if isinstance(leaf, Compressed):
            out[key] = Compressed(np.asarray(leaf.q), np.asarray(leaf.scale),
                                  leaf.n_elements, leaf.shape, leaf.dtype)
            continue
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # numpy has no bf16: store the raw 16-bit pattern, remember it
            out[key] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def encode_blobs(host_state: dict[str, np.ndarray], *,
                 lossless: str = "zlib", eps: float = 1e-2,
                 lossy_policy: Optional[Callable[[str], bool]] = None,
                 bf16_keys: Optional[set] = None,
                 pool=None) -> dict[str, tuple[bytes, dict]]:
    """Lossless-encode stage: leaf -> (framed blob, manifest entry sans file).

    Pure compute, no I/O — this is the pipeline's host stage; the sink
    (``write_encoded``) owns the filesystem. ``pool`` fans the chunks of
    each large leaf out across the shared codec executor (the stdlib codecs
    release the GIL, so one encode worker compresses chunks in parallel).
    """
    encoded: dict[str, tuple[bytes, dict]] = {}
    for key, arr in host_state.items():
        if isinstance(arr, Compressed):
            # HYBRID path: the lossy stage already ran on device; only the
            # lossless stage happens here.
            blob, st = lossy.frame_compressed(arr, lossless, pool)
            is_lossy, raw_bytes, is_bf16 = True, st.raw_bytes, False
        else:
            is_lossy = bool(lossy_policy and lossy_policy(key))
            is_bf16 = bool(bf16_keys and key in bf16_keys)
            raw_bytes = int(arr.nbytes)
            if is_lossy:
                # lossy path needs real float values; bf16-as-u16 goes via f32
                a = arr
                if is_bf16:
                    a = np.asarray(jnp.asarray(arr.view(np.uint16))
                                   .view(jnp.bfloat16).astype(jnp.float32))
                blob, _ = lossy.compress_tensor(a, eps=eps, lossless=lossless,
                                                pool=pool)
            else:
                blob, _ = codecs.encode(arr, lossless, pool=pool)
        encoded[key] = (blob, {"bytes": len(blob), "lossy": is_lossy,
                               "raw_bytes": raw_bytes, "bf16": is_bf16})
    return encoded


def write_encoded(directory: str,
                  encoded: dict[str, tuple[bytes, dict]]) -> dict[str, dict]:
    """Write stage: one file per encoded leaf; returns manifest leaf entries."""
    os.makedirs(directory, exist_ok=True)
    entries: dict[str, dict] = {}
    for key, (blob, ent) in encoded.items():
        fn = _fname(key)
        with open(os.path.join(directory, fn), "wb") as f:
            f.write(blob)
        entries[key] = {"file": fn, **ent}
    return entries


def write_blobs(host_state: dict[str, np.ndarray], directory: str, *,
                lossless: str = "zlib", eps: float = 1e-2,
                lossy_policy: Optional[Callable[[str], bool]] = None,
                bf16_keys: Optional[set] = None) -> dict[str, dict]:
    """Encode + write in one call (the pipeline splits the two stages)."""
    return write_encoded(directory, encode_blobs(
        host_state, lossless=lossless, eps=eps, lossy_policy=lossy_policy,
        bf16_keys=bf16_keys))


def write_manifest(directory: str, step: int, entries: dict[str, dict],
                   meta: Optional[dict] = None) -> None:
    manifest = {"step": step, "leaves": entries, "meta": meta or {}}
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, "manifest.json"))


def commit(tmp_dir: str, final_dir: str) -> None:
    """Atomic publish: a crashed save leaves only an invisible tmp dir."""
    if os.path.exists(final_dir):
        shutil.rmtree(final_dir)
    os.replace(tmp_dir, final_dir)


def read_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


def read_state(directory: str, template: PyTree,
               shardings: Optional[PyTree] = None,
               pool=None) -> PyTree:
    """Restore a pytree; re-place under ``shardings`` if given (elastic).

    ``pool`` fans chunk decompression of v2 frames out per leaf (v1 frames
    from old checkpoints decode on one thread, unchanged).
    """
    manifest = read_manifest(directory)
    entries = manifest["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), shd in zip(flat, shard_flat):
        if leaf is None:
            leaves.append(None)
            continue
        key = jax.tree_util.keystr(path)
        ent = entries[key]
        with open(os.path.join(directory, ent["file"]), "rb") as f:
            blob = f.read()
        arr = lossy.decompress_blob(blob, pool)
        arr = jnp.asarray(arr)
        if ent.get("bf16") and not ent["lossy"]:
            arr = arr.view(jnp.bfloat16)
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        want_shape = getattr(leaf, "shape", arr.shape)
        arr = arr.astype(want_dtype).reshape(want_shape)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
