"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]. The assignment specifies the backbone; input_specs()
provides precomputed patch embeddings (the ViT stub) prepended to the text.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vit",
    frontend_tokens=1024,   # patch embeddings per image tile set
    source="arXiv:2404.16821",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=257,
        frontend="vit",
        frontend_tokens=8,
        q_chunk=16,
        kv_chunk=16,
    )
