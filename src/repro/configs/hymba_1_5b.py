"""hymba-1.5b [hybrid] — parallel attention + mamba heads, SWA + 3 global layers.

[arXiv:2411.13676; hf]. ssm_state=16. Sub-quadratic: 29/32 layers use sliding
window attention with a ring KV cache; 3 global layers keep full attention.
Runs the long_500k decode shape.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    swa_window=1024,
    n_global_layers=3,      # first/middle/last full-attention (hymba paper)
    sub_quadratic=True,
    rules="pure_dp",
    source="arXiv:2411.13676",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=257,
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2, chunk=16),
        swa_window=32,
        n_global_layers=1,
        sub_quadratic=True,
        rules="pure_dp",
        q_chunk=16,
        kv_chunk=16,
    )
