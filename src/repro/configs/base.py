"""Config system: model configs, shape sets, and the config registry.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published numbers) and ``smoke()`` (a reduced config of
the same family for CPU tests). ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert ffn hidden
    n_shared_experts: int = 0
    first_dense: int = 0          # leading dense layers (deepseek: 3)
    dense_d_ff: int = 0           # d_ff of those dense layers
    capacity_factor: float = 1.25
    token_chunk: int = 32768      # GShard dispatch group: ~2k tokens/device x 16 DP
    router_aux_weight: float = 0.001
    router_z_weight: float = 0.0001
    # hillclimb lever: split each chunk into n_groups DP-local dispatch
    # groups (groups sharded over the dp axes) — the dispatch/combine
    # einsums then contract DP-locally and only the (g,e,c,d)->expert
    # transition crosses shards, instead of all-reducing the dispatched
    # tensor over 'data'. See EXPERIMENTS.md §Perf.
    grouped_dispatch: bool = False
    n_groups: int = 16


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # one sLSTM block per this many blocks
    m_proj_factor: float = 2.0
    s_proj_factor: float = 4.0 / 3.0
    chunk: int = 128
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mtp_weight: float = 0.0       # deepseek multi-token-prediction loss weight
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid-attention (hymba): sliding window on all but global_layers
    swa_window: int = 0           # 0 -> full attention everywhere
    n_global_layers: int = 0      # leading/trailing/middle full-attn layers
    # modality frontend stub: number of precomputed embedding tokens prepended
    frontend: Optional[str] = None   # None | 'vit' | 'audio'
    frontend_tokens: int = 0
    # attention chunking (flash) — structural VMEM/memory bound, perf lever
    q_chunk: int = 512
    kv_chunk: int = 1024
    # distribution policy
    rules: str = "default"        # default | pure_dp (see distributed/sharding)
    remat: bool = True
    scan_layers: bool = True
    # cost-exact lowering: unroll ALL internal lax.scans (attention kv loop,
    # ssm/mlstm chunk loops, moe token chunks) so XLA cost_analysis counts
    # every iteration. Used by the dry-run's depth-extrapolation variants
    # ONLY — the deployed config keeps scans (compile size).
    unroll_scans: bool = False
    # which shapes this arch supports (long_500k only for sub-quadratic)
    sub_quadratic: bool = False
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        from repro.models import transformer  # local import, avoids cycle
        from repro.models import params as P
        return P.param_count(transformer.param_spec(self))

    def n_active_params(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        total = self.n_params()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        n_moe_layers = self.n_layers - m.first_dense
        inactive = (m.n_experts - m.top_k) * per_expert * n_moe_layers
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# smoke-test shape (CPU, tiny)
SMOKE_SHAPE = ShapeConfig("smoke", "train", 64, 2)

ARCH_IDS: Sequence[str] = (
    "granite-3-2b",
    "qwen3-4b",
    "smollm-135m",
    "qwen1.5-110b",
    "musicgen-medium",
    "deepseek-v3-671b",
    "moonshot-v1-16b-a3b",
    "internvl2-26b",
    "hymba-1.5b",
    "xlstm-1.3b",
)

_MODULES = {
    "granite-3-2b": "granite_3_2b",
    "qwen3-4b": "qwen3_4b",
    "smollm-135m": "smollm_135m",
    "qwen1.5-110b": "qwen1_5_110b",
    "musicgen-medium": "musicgen_medium",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "internvl2-26b": "internvl2_26b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def get(name: str, smoke: bool = False) -> ModelConfig:
    import importlib

    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke() if smoke else mod.CONFIG


def cells(arch: str):
    """The (arch x shape) dry-run cells for one arch, honoring skips."""
    cfg = get(arch)
    out = []
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(SHAPES[s])
    return out
