"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]. GQA with kv=16 (MHA) per the assignment;
2 shared experts per the Moonlight family.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared_experts=2,
        first_dense=1,
        dense_d_ff=11264,
        capacity_factor=1.25,
        token_chunk=32768,
    ),
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=257,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_expert=96,
            n_shared_experts=2,
            first_dense=1,
            dense_d_ff=128,
            capacity_factor=2.0,
            token_chunk=64,
        ),
        q_chunk=16,
        kv_chunk=16,
    )
