"""qwen1.5-110b [dense] — QKV bias, GQA. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-110B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=257,
        qkv_bias=True,
        q_chunk=16,
        kv_chunk=16,
    )
