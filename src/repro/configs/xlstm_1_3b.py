"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections instead of a separate
FFN. 48 blocks with one sLSTM per 8 (xLSTM[7:1] ratio). O(1) recurrent state:
runs the long_500k decode shape.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, m_proj_factor=2.0, s_proj_factor=4.0 / 3.0,
                      chunk=128, conv_kernel=4),
    sub_quadratic=True,
    rules="pure_dp",
    source="arXiv:2405.04517",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=257,
        xlstm=XLSTMConfig(slstm_every=2, chunk=16, conv_kernel=4),
        sub_quadratic=True,
        rules="pure_dp",
        q_chunk=16,
        kv_chunk=16,
    )
