"""qwen3-4b [dense] — qk_norm, GQA, explicit head_dim=128. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,          # Qwen3 family decouples head_dim from d_model/n_heads
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=257,
        head_dim=24,       # decoupled head_dim exercised in smoke too
        qk_norm=True,
        q_chunk=16,
        kv_chunk=16,
    )
