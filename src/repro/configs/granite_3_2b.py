"""granite-3-2b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=257,
        tie_embeddings=True,
        q_chunk=16,
        kv_chunk=16,
    )
