"""smollm-135m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    rules="pure_dp",       # 135M: TP would waste the 'model' axis; run 256-way DP
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=96,
        vocab_size=257,
        tie_embeddings=True,
        rules="pure_dp",
        q_chunk=16,
        kv_chunk=16,
    )
