"""musicgen-medium [audio] — decoder-only backbone over EnCodec tokens.

The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (conditioning prefix) + codebook token ids. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,         # MHA
    d_ff=6144,
    vocab_size=2048,       # EnCodec codebook size
    frontend="audio",
    frontend_tokens=64,    # conditioning frames prepended as embeddings
    source="arXiv:2306.05284",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        frontend="audio",
        frontend_tokens=4,
        q_chunk=16,
        kv_chunk=16,
    )
