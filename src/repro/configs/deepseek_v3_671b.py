"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

[arXiv:2412.19437; hf]. d_ff=2048 is the per-expert (moe) intermediate; the
first 3 layers are dense with d_ff 18432 (the published first_k_dense_replace).
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: per-head KV derived from the shared latent
    d_ff=2048,
    vocab_size=129280,
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_expert=2048,
        n_shared_experts=1,
        first_dense=3,
        dense_d_ff=18432,
        capacity_factor=1.25,
        token_chunk=32768,
    ),
    mtp_weight=0.3,
    source="arXiv:2412.19437",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke",
        family="moe",
        n_layers=3,            # 1 dense + 2 moe
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=257,
        mla=MLAConfig(q_lora=32, kv_lora=24, qk_nope=16, qk_rope=8, v_head=16),
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_expert=96,
            n_shared_experts=1,
            first_dense=1,
            dense_d_ff=128,
            capacity_factor=2.0,
            token_chunk=64,
        ),
        mtp_weight=0.3,
        q_chunk=16,
        kv_chunk=16,
    )
