"""Functional parameter system with logical sharding axes.

Every model in this framework describes its parameters as a pytree of
``ParamSpec`` (shape + dtype + logical axis names + initializer). From one
spec tree we derive:

  * ``materialize(rng, spec)``   -> real jnp arrays (smoke tests, examples)
  * ``abstract(spec)``           -> jax.ShapeDtypeStruct tree (dry-run, no alloc)
  * ``logical_axes(spec)``       -> pytree of logical-axis tuples
  * with ``distributed.sharding.mesh_rules`` -> PartitionSpec tree for pjit.

Logical axis vocabulary (see distributed/sharding.py for the mesh mapping):
  'vocab'    embedding rows / logits columns          (TP)
  'embed'    model dimension                          (FSDP)
  'heads'    query heads                              (TP)
  'kv_heads' key/value heads                          (TP if divisible)
  'head_dim' per-head feature dim                     (never sharded)
  'mlp'      feed-forward hidden                      (TP)
  'expert'   MoE expert index                         (EP -> TP axis)
  'e_mlp'    per-expert hidden                        (unsharded; EP covers it)
  'layers'   scan-stacked layer index                 (never sharded)
  'lora'     MLA low-rank bottleneck                  (never sharded)
  'state'    SSM / recurrent state dim                (never sharded)
  'conv'     conv kernel taps                         (never sharded)
  None       explicitly replicated dim
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len(axes) == len(shape)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | embed | head
    scale: float | None = None  # overrides the default fan-in scale

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec rank mismatch: shape={self.shape} axes={self.axes}"
            )


def _fan_in(shape: tuple, axes: tuple) -> int:
    """Fan-in ignoring a leading stacked-layers dim."""
    dims = [s for s, a in zip(shape, axes) if a != "layers"]
    if len(dims) <= 1:
        return max(dims[0] if dims else 1, 1)
    # all but the last dim count as inputs for a dense kernel
    return max(int(np.prod(dims[:-1])), 1)


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        scale = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(
            spec.dtype
        )
    # dense kernels: truncated-normal, 1/sqrt(fan_in)
    scale = spec.scale
    if scale is None:
        scale = 1.0 / math.sqrt(_fan_in(spec.shape, spec.axes))
    x = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    return (x * scale).astype(spec.dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def materialize(rng: jax.Array, spec_tree: PyTree) -> PyTree:
    """Initialize real parameters from a spec tree."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract(spec_tree: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins — used by the dry-run; allocates nothing."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def logical_axes(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def param_count(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves
    )


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)
