"""Multi-head Latent Attention (DeepSeek-V3) with absorbed decode path.

Training/prefill materializes per-head K/V from the shared 512-d latent (the
published training recipe). Decode uses the *absorbed* formulation: queries are
projected into latent space (q^T W_UK folded), attention runs directly against
the cached latent + shared rope key, and W_UV is folded into the output
projection — so the KV cache is (kv_lora + qk_rope) = 576 floats/token/layer
instead of heads*(nope+rope+v) = 40960. That 71x cache shrink is the
arch-level analog of the paper's in-situ data reduction, and is why this arch
is the technique-representative hillclimb cell.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import apply_rope, rmsnorm
from repro.models.params import ParamSpec


def mla_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    m = cfg.mla
    h, d = cfg.n_heads, cfg.d_model
    qk = m.qk_nope + m.qk_rope

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    return {
        "wq_a": mk((d, m.q_lora), ("embed", "lora")),
        "q_norm": mk((m.q_lora,), ("lora",), dtype=jnp.float32, init="ones"),
        "wq_b": mk((m.q_lora, h, qk), ("lora", "heads", "head_dim")),
        "wkv_a": mk((d, m.kv_lora + m.qk_rope), ("embed", "lora")),
        "kv_norm": mk((m.kv_lora,), ("lora",), dtype=jnp.float32, init="ones"),
        "wk_b": mk((m.kv_lora, h, m.qk_nope), ("lora", "heads", "head_dim")),
        "wv_b": mk((m.kv_lora, h, m.v_head), ("lora", "heads", "head_dim")),
        "wo": mk((h, m.v_head, d), ("heads", "head_dim", "embed")),
    }


def _project_q(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    q_lat = jnp.einsum("bsd,dl->bsl", x, p["wq_a"])
    q_lat = rmsnorm({"scale": p["q_norm"]}, q_lat, cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., : m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    c_kv, k_rope = kv[..., : m.kv_lora], kv[..., m.kv_lora:]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv, cfg.norm_eps)
    # shared (MQA-style) rope key: one head
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_attention(p, x, cfg: ModelConfig, positions, *, q_chunk=None,
                  kv_chunk=None) -> jax.Array:
    """Training/prefill path: materialized per-head K/V, chunked flash."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _project_kv_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.n_heads, m.qk_rope))], axis=-1)
    o = attn_lib.flash_attention(
        q, k, v, causal=True,
        q_chunk=q_chunk or cfg.q_chunk, kv_chunk=kv_chunk or cfg.kv_chunk,
        unroll=cfg.unroll_scans)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_decode(p, x, cfg: ModelConfig, cache_ckv, cache_krope, length):
    """Absorbed decode: attention in latent space over the compressed cache.

    cache_ckv:   (B, S, kv_lora)  — already contains the current token's entry.
    cache_krope: (B, S, qk_rope)
    length:      (B,) valid prefix length including the current token.
    """
    m = cfg.mla
    b = x.shape[0]
    pos = (length - 1)[:, None]  # current absolute position, (B,1)
    q_nope, q_rope = _project_q(p, x, cfg, pos)
    # absorb W_UK: q_lat[h] = q_nope[h] @ W_UK[h]^T  -> latent-space query
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["wk_b"])
    scale = 1.0 / math.sqrt(m.qk_nope + m.qk_rope)
    s_lat = jnp.einsum("bshl,btl->bhst", q_lat, cache_ckv)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, cache_krope)
    scores = (s_lat + s_rope).astype(jnp.float32) * scale  # (B,H,1,S)
    valid = jnp.arange(cache_ckv.shape[1])[None, :] < length[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, attn_lib.NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btl->bshl", pr.astype(cache_ckv.dtype), cache_ckv)
    # absorb W_UV into the output projection
    o = jnp.einsum("bshl,lhk->bshk", o_lat, p["wv_b"])
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_new_cache_entry(p, x, cfg: ModelConfig, positions):
    """(c_kv, k_rope) for the token(s) in x — what decode appends to the cache."""
    return _project_kv_latent(p, x, cfg, positions)
