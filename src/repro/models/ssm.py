"""Selective SSM (Mamba-style) mixer: chunked parallel scan + O(1) decode.

Training/prefill uses a chunked associative scan: the sequence is cut into
`chunk`-sized pieces; within a chunk the linear recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
is evaluated with lax.associative_scan (log-depth, VPU-friendly), and a single
(d_inner, d_state) state is carried across chunks — so live memory is
O(chunk * d_inner * d_state), never O(seq * ...). Decode is the exact
single-step recurrence on the carried state (this is what makes the long_500k
shape viable for the hybrid/ssm archs).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.params import ParamSpec


def _dt_rank(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def ssm_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = d_inner(cfg)
    r = _dt_rank(cfg)

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    return {
        "in_proj": mk((d, 2 * di), ("embed", "mlp")),
        "conv_w": mk((s.d_conv, di), ("conv", "mlp")),
        "conv_b": mk((di,), ("mlp",), init="zeros"),
        "x_proj": mk((di, r + 2 * s.d_state), ("mlp", "lora")),
        "dt_proj": mk((r, di), ("lora", "mlp")),
        "dt_bias": mk((di,), ("mlp",), dtype=jnp.float32, init="zeros"),
        "a_log": mk((di, s.d_state), ("mlp", "state"), dtype=jnp.float32,
                    init="embed", scale=0.5),
        "d_skip": mk((di,), ("mlp",), dtype=jnp.float32, init="ones"),
        "out_proj": mk((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b, init_state=None):
    """x: (B,L,di); depthwise causal conv with kernel taps w: (K,di)."""
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):]  # (B,L,di), new conv state


def _ssm_inputs(p, xc, cfg: ModelConfig):
    """Projections shared by the parallel and decode paths."""
    s = cfg.ssm
    r = _dt_rank(cfg)
    proj = jnp.einsum("...d,de->...e", xc, p["x_proj"])
    dt_r, bmat, cmat = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jnp.einsum("...r,rd->...d", dt_r, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])               # (..., di)
    a = -jnp.exp(p["a_log"])                              # (di, S)
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32), a


def _scan_chunk(h0, dt, bmat, cmat, a, xc):
    """One chunk of the selective scan. h0: (B,di,S); xc: (B,L,di)."""
    da = jnp.exp(dt[..., None] * a)                        # (B,L,di,S)
    db = (dt * xc.astype(jnp.float32))[..., None] * bmat[:, :, None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    cum_a, cum_b = jax.lax.associative_scan(combine, (da, db), axis=1)
    h = cum_a * h0[:, None] + cum_b                        # (B,L,di,S)
    y = jnp.einsum("blds,bls->bld", h, cmat)
    return y, h[:, -1]


def ssm_mixer(p, x, cfg: ModelConfig, return_state: bool = False):
    """x: (B, L, d_model) -> (B, L, d_model). Parallel (train/prefill) path."""
    s = cfg.ssm
    b, l, _ = x.shape
    di = d_inner(cfg)
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    chunk = min(s.chunk, l)
    if l % chunk:
        chunk = l
    n_chunks = l // chunk
    xcs = xc.reshape(b, n_chunks, chunk, di).transpose(1, 0, 2, 3)

    def body(h, xck):
        dt, bmat, cmat, a = _ssm_inputs(p, xck, cfg)
        y, h_new = _scan_chunk(h, dt, bmat, cmat, a, xck)
        return h_new, y

    h0 = jnp.zeros((b, di, s.d_state), jnp.float32)
    if cfg.unroll_scans:
        h_final, ys_l = h0, []
        for i in range(n_chunks):
            h_final, y_i = body(h_final, xcs[i])
            ys_l.append(y_i)
        ys = jnp.stack(ys_l)
    else:
        h_final, ys = jax.lax.scan(body, h0, xcs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, l, di)
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    if return_state:
        return out, {"h": h_final, "conv": conv_state}
    return out


def ssm_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = d_inner(cfg)
    return {
        "h": (batch, di, s.d_state),
        "conv": (batch, s.d_conv - 1, di),
    }


def ssm_decode(p, x, cfg: ModelConfig, state):
    """Single-token recurrence. x: (B,1,d); state: {'h','conv'}."""
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"], state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, bmat, cmat, a = _ssm_inputs(p, xc, cfg)
    da = jnp.exp(dt[:, 0, :, None] * a)                    # (B,di,S)
    db = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * bmat[:, 0, None, :]
    h = da * state["h"] + db
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    return out, {"h": h, "conv": conv_state}


def ssm_mixer_reference(p, x, cfg: ModelConfig):
    """Naive per-step recurrence oracle (tests)."""
    b, l, _ = x.shape
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xc, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, bmat, cmat, a = _ssm_inputs(p, xc, cfg)
    di = d_inner(cfg)
    h = jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)
    ys = []
    for t in range(l):
        da = jnp.exp(dt[:, t, :, None] * a)
        db = (dt[:, t] * xc[:, t].astype(jnp.float32))[..., None] * bmat[:, t, None, :]
        h = da * h + db
        ys.append(jnp.einsum("bds,bs->bd", h, cmat[:, t]))
    y = jnp.stack(ys, axis=1)
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"])
