"""Shared layers: RMSNorm, SwiGLU MLP, embeddings, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int, layers: Optional[int] = None) -> dict:
    shape = (dim,) if layers is None else (layers, dim)
    axes = ("embed",) if layers is None else ("layers", "embed")
    return {"scale": ParamSpec(shape, axes, jnp.float32, init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, layers: Optional[int] = None) -> dict:
    def mk(shape, axes):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes)

    return {
        "w_gate": mk((d_model, d_ff), ("embed", "mlp")),
        "w_up": mk((d_model, d_ff), ("embed", "mlp")),
        "w_down": mk((d_ff, d_model), ("mlp", "embed")),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding (padded vocab; pad logits masked to -inf)
# ---------------------------------------------------------------------------

def embed_spec(padded_vocab: int, d_model: int, tie: bool) -> dict:
    out = {"embedding": ParamSpec((padded_vocab, d_model), ("vocab", "embed"),
                                  init="embed", scale=0.02)}
    if not tie:
        out["unembed"] = ParamSpec((d_model, padded_vocab), ("embed", "vocab"))
    return out


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: dict, x: jax.Array, vocab_size: int) -> jax.Array:
    if "unembed" in params:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, params["embedding"])
    padded = logits.shape[-1]
    if padded != vocab_size:
        mask = jnp.arange(padded) < vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  gather_free: bool = False) -> jax.Array:
    """Mean token cross-entropy; labels < vocab_size always (pad rows masked).

    ``gather_free`` selects a compare+reduce formulation (no gather op) —
    required inside partially-manual shard_map regions, where XLA's SPMD
    partitioner cannot partition gathers with sharded operands (hard CHECK
    in spmd_partitioner_util as of XLA 2025-xx); the compare+sum fuses into
    a single reduction loop and never materializes the one-hot.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    if gather_free:
        vocab_iota = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                       axis=-1)
    else:
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
