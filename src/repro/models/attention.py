"""Chunked (flash-style) attention in pure JAX, GQA/MHA/SWA + decode paths.

Why chunked: the 32k-prefill and 4k-train shapes would otherwise materialize
S x S score tensors per head (e.g. 32768^2 x heads), which no 16 GB chip holds.
The classic online-softmax recurrence over KV chunks bounds live memory to
(q_chunk x kv_chunk) per head group, which is also the structure a TPU flash
kernel tiles into VMEM. Causality is exact *and* flop-exact: q-chunks are a
python loop (unrolled in HLO), and the inner lax.scan for q-chunk i only runs
over the kv-chunks it can actually see — no masked-out flops are issued, so
cost_analysis() reflects true causal work (roofline honesty).

GQA never materializes repeated KV heads: scores are computed in grouped
layout (batch, kv_head, group, q, k).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _grouped(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,Hq,D) -> (B,S,N,G,D) with N=kv heads, G=Hq//N."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _chunk_scores(q5, k, scale):
    # q5: (B,Sq,N,G,D), k: (B,Sk,N,D) -> (B,N,G,Sq,Sk) fp32
    return jnp.einsum("bsngd,btnd->bngst", q5, k).astype(jnp.float32) * scale


def flash_attention(
    q: jax.Array,          # (B, Sq, Hq, D)
    k: jax.Array,          # (B, Sk, N, D)
    v: jax.Array,          # (B, Sk, N, Dv)
    *,
    causal: bool = True,
    q_offset: int = 0,     # absolute position of q[0] (prefill continuation)
    window: int = 0,       # 0 = full; else sliding window (causal only)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    unroll: bool = False,  # python-loop the kv chunks (cost-exact lowering)
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, sk, n_kv, dv = v.shape
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    if sq % q_chunk or sk % kv_chunk:
        # fall back to one chunk when shapes don't tile (smoke configs)
        q_chunk, kv_chunk = sq, sk

    g = hq // n_kv
    out = []
    n_q_chunks = sq // q_chunk
    for i in range(n_q_chunks):
        qs = i * q_chunk                       # chunk start (relative)
        q_abs = q_offset + qs                  # absolute start
        qi = _grouped(q[:, qs:qs + q_chunk], n_kv)
        # visible kv range for this q chunk
        hi_abs = q_abs + q_chunk if causal else sk
        hi = min(sk, hi_abs) if causal else sk
        lo = 0
        if window:
            # earliest key visible to the FIRST q row of this chunk
            lo = max(0, q_abs - (window - 1))
            lo = (lo // kv_chunk) * kv_chunk   # align down to chunk grid
        n_kv_chunks = max(1, math.ceil((hi - lo) / kv_chunk))

        q_pos = q_abs + jnp.arange(q_chunk)

        def body(carry, j, qi=qi, lo=lo, q_pos=q_pos):
            m, l, acc = carry
            start = lo + j * kv_chunk
            kj = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            s = _chunk_scores(qi, kj, scale)   # (B,N,G,Sq,KV)
            kv_pos = start + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= q_pos[:, None] - kv_pos[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bngst,btnd->bngsd", p.astype(vj.dtype), vj)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n_kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_chunk, dv), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(n_kv_chunks):
                carry, _ = body(carry, j)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), jnp.arange(n_kv_chunks))
        o = acc / jnp.maximum(l[..., None], 1e-37)
        # (B,N,G,Sq,Dv) -> (B,Sq,Hq,Dv)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, dv)
        out.append(o.astype(v.dtype))
    return jnp.concatenate(out, axis=1) if len(out) > 1 else out[0]


def decode_attention(
    q: jax.Array,            # (B, 1, Hq, D)
    k_cache: jax.Array,      # (B, S, N, D)
    v_cache: jax.Array,      # (B, S, N, Dv)
    length: jax.Array,       # (B,) valid prefix length (after current insert)
    *,
    window: int = 0,
    ring: bool = False,      # cache is a ring buffer (SWA): all slots valid
) -> jax.Array:
    """Single-token attention over a (possibly ring) KV cache."""
    b, s, n_kv, dv = v_cache.shape
    hq = q.shape[2]
    g = hq // n_kv
    scale = 1.0 / math.sqrt(q.shape[-1])
    q5 = q.reshape(b, 1, n_kv, g, -1)
    scores = jnp.einsum("bsngd,btnd->bngst", q5, k_cache).astype(jnp.float32)
    scores = scores * scale                       # (B,N,G,1,S)
    pos = jnp.arange(s)
    if ring:
        valid = pos[None, :] < jnp.minimum(length, s)[:, None]
    else:
        valid = pos[None, :] < length[:, None]
        if window:
            valid &= pos[None, :] >= (length[:, None] - window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bngst,btnd->bngsd", p.astype(v_cache.dtype), v_cache)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, dv)


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize per-request sequences from a block-indexed page pool.

    ``pages``: (num_pages, page_size, ...) — one pool shared by every
    request; ``page_table``: (B, P) int32 page ids (unused entries point at
    the reserved scratch page 0 and are masked by ``length`` downstream).
    Returns (B, P*page_size, ...) in token order: position ``t`` of row
    ``b`` lives in page ``page_table[b, t // page_size]`` at offset
    ``t % page_size`` — the same token ordering as a dense slab, which is
    what keeps paged logits bit-identical to the slab path.
    """
    b, p = page_table.shape
    g = pages[page_table]                 # (B, P, page_size, ...)
    return g.reshape(b, p * pages.shape[1], *pages.shape[2:])


def scatter_token(pages: jax.Array, new: jax.Array, page_table: jax.Array,
                  lengths: jax.Array, page_size: int) -> jax.Array:
    """Write one new token per row into its page: position ``lengths[b]``.

    ``new``: (B, ...) — the freshly projected k/v (or MLA latent) rows.
    Rows whose length exceeds the table (inactive/finished requests) clamp
    to their last table entry, which the engine keeps pointed at the
    scratch page — the write lands in garbage no reader ever attends to.
    """
    b, p = page_table.shape
    idx = jnp.minimum(lengths // page_size, p - 1)
    page = page_table[jnp.arange(b), idx]
    return pages.at[page, lengths % page_size].set(new.astype(pages.dtype))


def paged_decode_attention(
    q: jax.Array,             # (B, 1, Hq, D)
    k_pages: jax.Array,       # (num_pages, page_size, N, D)
    v_pages: jax.Array,       # (num_pages, page_size, N, Dv)
    page_table: jax.Array,    # (B, P) int32
    length: jax.Array,        # (B,) valid prefix length (after insert)
    *,
    use_kernel: Optional[bool] = None,
) -> jax.Array:
    """Single-token attention over a paged KV cache.

    The default path gathers each row's pages into token order and reuses
    :func:`decode_attention` — positions past ``length`` gather scratch or
    stale pages but are masked to NEG_INF exactly like the dense slab's
    zero padding, so the result is bit-identical to a dense cache holding
    the same tokens. ``use_kernel`` (default: TPU only) switches to the
    fused Pallas gather-attention kernel in
    :mod:`repro.kernels.paged_attention`, which never materializes the
    gathered (B, S, N, D) copy.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels import paged_attention as PK
        return PK.paged_decode_attention(q, k_pages, v_pages, page_table,
                                         length)
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    return decode_attention(q, k, v, length)


def reference_attention(q, k, v, *, causal=True, q_offset=0, window=0):
    """O(S^2) oracle used by tests."""
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    q5 = _grouped(q, n_kv)
    s = _chunk_scores(q5, k, 1.0 / math.sqrt(d))
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnd->bngsd", p.astype(v.dtype), v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, v.shape[-1])
