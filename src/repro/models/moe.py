"""Capacity-based top-k MoE (GShard-style dispatch/combine einsums).

Expert weights live stacked on an 'expert' axis that the sharding rules map to
the 'model' mesh axis (expert parallelism); tokens stay sharded over the DP
axes. XLA SPMD then materializes the all-to-all style exchange between the two
shardings. Token streams are processed in fixed-size chunks (scan) so the
(chunk, experts, capacity) dispatch tensor is bounded regardless of the global
batch — e.g. deepseek train_4k: (2048, 256, 80) bf16 = 84 MB live, not the
multi-GB unchunked version.

Routing: softmax router, top-k, per-chunk capacity C = ceil(chunk*k/E * cf).
Overflow tokens drop (standard); the combine weights renormalize over the
surviving experts. Aux load-balance + router-z losses are returned for the
train loss.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.params import ParamSpec


def moe_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    m = cfg.moe
    d = cfg.d_model

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    spec = {
        "router": mk((d, m.n_experts), ("embed", "expert"), dtype=jnp.float32),
        "w_gate": mk((m.n_experts, d, m.d_expert), ("expert", "embed", "e_mlp")),
        "w_up": mk((m.n_experts, d, m.d_expert), ("expert", "embed", "e_mlp")),
        "w_down": mk((m.n_experts, m.d_expert, d), ("expert", "e_mlp", "embed")),
    }
    if m.n_shared_experts:
        ds = m.d_expert * m.n_shared_experts
        spec.update({
            "shared_gate": mk((d, ds), ("embed", "mlp")),
            "shared_up": mk((d, ds), ("embed", "mlp")),
            "shared_down": mk((ds, d), ("mlp", "embed")),
        })
    return spec


def _expert_ffn(p, x_d: jax.Array) -> jax.Array:
    """x_d: (E, C, D) -> (E, C, D), per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", x_d, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_d, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_d.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def route(router_w, x, m: MoEConfig):
    """Returns (top-k expert ids, renormalized top-k weights, aux losses)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)          # (T,k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    e = m.n_experts
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=0)
    prob_frac = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(dispatch_frac * prob_frac)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return top_e, top_w, aux * m.router_aux_weight + z * m.router_z_weight


def _dispatch_combine(top_e, top_w, m: MoEConfig, chunk: int):
    """Build (chunk, E, C) dispatch one-hot and combine weights."""
    e = m.n_experts
    cap = max(1, math.ceil(chunk * m.top_k / e * m.capacity_factor))
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)       # (T,k,E)
    flat = onehot.reshape(-1, e)                              # (T*k, E) row order: t*k+s
    pos = jnp.cumsum(flat, axis=0) - flat                     # slots before this one
    pos = jnp.sum(pos * flat, axis=-1).reshape(chunk, m.top_k)
    keep = pos < cap
    disp = (
        jax.nn.one_hot(top_e, e, dtype=jnp.float32)
        * keep[..., None]
    )                                                          # (T,k,E)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=jnp.float32)[..., :cap]      # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec", disp, pos_oh)        # (T,E,C) 0/1
    combine = jnp.einsum("tke,tkc,tk->tec", disp, pos_oh,
                         top_w.astype(jnp.float32))
    return dispatch, combine, cap


def _grouped_body(p, xc: jax.Array, cfg: ModelConfig):
    """DP-local grouped dispatch (hillclimb variant).

    xc: (T, D) one chunk. Tokens reshape to (G, T/G, D) with the group axis
    pinned to the dp mesh axes; routing, position assignment and the
    dispatch/combine einsums all carry the g axis, so their contractions are
    group-LOCAL — no cross-'data' reduction of (E, C, D) tensors. Only the
    (g, e, c, d) -> expert-sharded transition moves data (all-to-all-like),
    and the combine all-reduce is token-sized, not capacity-sized.
    """
    m = cfg.moe
    t, d = xc.shape
    g = min(m.n_groups, t)
    while t % g:
        g -= 1
    gs = t // g
    e = m.n_experts
    xg = jnp.reshape(xc, (g, gs, d))
    xg = _dp_constraint(xg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)            # (g,t,k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    disp_frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    prob_frac = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(disp_frac * prob_frac) * m.router_aux_weight
    aux = aux + jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) \
        * m.router_z_weight

    cap = max(1, math.ceil(gs * m.top_k / e * m.capacity_factor))
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)      # (g,t,k,E)
    flat = onehot.reshape(g, gs * m.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                   # per-group queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, gs, m.top_k)
    keep = pos < cap
    # NOTE (§Perf iter 2, refuted): building these one-hots in bf16 with
    # explicit dp constraints REGRESSED both terms (+54%/+34%) — the
    # constraints forced materialized reshards XLA otherwise avoided.
    # Keeping the f32 formulation that measured best (tag hc_grouped).
    disp = jax.nn.one_hot(top_e, e, dtype=jnp.float32) * keep[..., None]
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=jnp.float32)[..., :cap]   # (g,t,k,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", disp, pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", disp, pos_oh,
                         top_w.astype(jnp.float32))
    x_d = jnp.einsum("gtec,gtd->gecd", dispatch.astype(xc.dtype), xg)
    x_d = _gep_constraint(x_d)                              # g->dp, e->model
    gg = jnp.einsum("gecd,edf->gecf", x_d, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", x_d, p["w_up"])
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(x_d.dtype) * uu
    y_d = jnp.einsum("gecf,efd->gecd", hh, p["w_down"])
    y_d = _gep_constraint(y_d)
    yg = jnp.einsum("gtec,gecd->gtd", combine.astype(xc.dtype), y_d)
    return jnp.reshape(yg, (t, d)), aux


def moe_ffn(p, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss). Token stream chunk-scanned."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    chunk = min(m.token_chunk, t)
    if t % chunk:
        chunk = t  # smoke shapes
    n_chunks = t // chunk
    tokens = tokens.reshape(n_chunks, chunk, d)

    def body(aux, xc):
        if m.grouped_dispatch:
            yc, aux_c = _grouped_body(p, xc, cfg)
            return aux + aux_c, yc
        top_e, top_w, aux_c = route(p["router"], xc, m)
        dispatch, combine, cap = _dispatch_combine(top_e, top_w, m, chunk)
        x_d = jnp.einsum("tec,td->ecd", dispatch.astype(xc.dtype), xc)
        x_d = _ep_constraint(x_d)
        y_d = _expert_ffn(p, x_d)
        y_d = _ep_constraint(y_d)
        yc = jnp.einsum("tec,ecd->td", combine.astype(xc.dtype), y_d)
        return aux + aux_c, yc

    if cfg.unroll_scans:
        aux = jnp.zeros((), jnp.float32)
        ys = []
        for i in range(n_chunks):
            aux, yc = body(aux, tokens[i])
            ys.append(yc)
        y = jnp.stack(ys)
    else:
        aux, y = jax.lax.scan(body, jnp.zeros((), jnp.float32), tokens)
    y = y.reshape(b, s, d)
    if m.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, p["shared_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", h, p["shared_down"])
    return y, aux / n_chunks


def _ep_constraint(x_ecd):
    """Pin the expert dim to the 'model' axis (EP) when inside a mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x_ecd, P("model", None, None))
    except (ValueError, RuntimeError):
        return x_ecd


def _mesh_axis_names():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.get_abstract_mesh()
        return tuple(m.axis_names) if m is not None else ()
    except Exception:  # noqa: BLE001
        return ()


def _dp_constraint(x_gtd):
    """Groups over the dp axes (grouped dispatch)."""
    try:
        from jax.sharding import PartitionSpec as P
        names = _mesh_axis_names()
        dp = tuple(a for a in ("pod", "data") if a in names)
        if not dp:
            return x_gtd
        return jax.lax.with_sharding_constraint(
            x_gtd, P(dp if len(dp) > 1 else dp[0], None, None))
    except (ValueError, RuntimeError):
        return x_gtd


def _dp_constraint4(x_gtec):
    """(g,t,e,c): groups over dp, rest local (dispatch/combine tensors)."""
    try:
        from jax.sharding import PartitionSpec as P
        names = _mesh_axis_names()
        dp = tuple(a for a in ("pod", "data") if a in names)
        if not dp:
            return x_gtec
        return jax.lax.with_sharding_constraint(
            x_gtec, P(dp if len(dp) > 1 else dp[0], None, None, None))
    except (ValueError, RuntimeError):
        return x_gtec


def _gep_constraint(x_gecd):
    """(g,e,c,d): groups over dp, experts over 'model'."""
    try:
        from jax.sharding import PartitionSpec as P
        names = _mesh_axis_names()
        dp = tuple(a for a in ("pod", "data") if a in names)
        if not dp or "model" not in names:
            return x_gecd
        return jax.lax.with_sharding_constraint(
            x_gecd, P(dp if len(dp) > 1 else dp[0], "model", None, None))
    except (ValueError, RuntimeError):
        return x_gecd


def moe_ffn_reference(p, x: jax.Array, cfg: ModelConfig):
    """Per-token loop oracle with the same capacity/drop semantics (tests)."""
    import numpy as np

    m = cfg.moe
    b, s, d = x.shape
    tokens = np.asarray(x.reshape(-1, d), np.float32)
    t = tokens.shape[0]
    chunk = min(m.token_chunk, t)
    if t % chunk:
        chunk = t
    logits = tokens @ np.asarray(p["router"], np.float32)
    out = np.zeros_like(tokens)
    e = m.n_experts
    for c0 in range(0, t, chunk):
        attempts = np.zeros(e, np.int64)  # GShard positions count overflow too
        cap = max(1, math.ceil(chunk * m.top_k / e * m.capacity_factor))
        for i in range(c0, c0 + chunk):
            lg = logits[i]
            probs = np.exp(lg - lg.max())
            probs /= probs.sum()
            top = np.argsort(-probs, kind="stable")[: m.top_k]
            w = probs[top] / max(probs[top].sum(), 1e-9)
            for ee, ww in zip(top, w):
                position = attempts[ee]
                attempts[ee] += 1
                if position >= cap:
                    continue
                xi = tokens[i]
                g = xi @ np.asarray(p["w_gate"][ee], np.float32)
                u = xi @ np.asarray(p["w_up"][ee], np.float32)
                h = (g / (1 + np.exp(-g))) * u
                out[i] += ww * (h @ np.asarray(p["w_down"][ee], np.float32))
    y = out.reshape(b, s, d)
    if m.n_shared_experts:
        xs = np.asarray(x, np.float32)
        g = xs @ np.asarray(p["shared_gate"], np.float32)
        u = xs @ np.asarray(p["shared_up"], np.float32)
        h = (g / (1 + np.exp(-g))) * u
        y = y + h @ np.asarray(p["shared_down"], np.float32)
    return y
