"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM.

mLSTM (matrix memory, exponential gating) is evaluated in an exact chunkwise
form. With log-gates lf_t = log sigmoid(f~_t), li_t = i~_t and within-chunk
cumulative decay b_t = sum_{s<=t} lf_s, the stepwise stabilizer unrolls to

    m_t = b_t + max(m_in, cummax_{s<=t}(li_s - b_s))

and every stepwise quantity becomes an einsum against the (L,L) intra-chunk
weight matrix W_{ts} = exp(b_t - b_s + li_s - m_t) [s<=t] plus one inter-chunk
term exp(b_t + m_in - m_t) carried by the chunk state (C, n, m). Tests verify
chunkwise == stepwise to float tolerance. Chunk size bounds live memory at
O(L^2 + d_head^2) per head — the structure a TPU kernel would tile.

sLSTM (scalar memory, block-diagonal recurrence) is inherently sequential and
runs as a lax.scan over time; xLSTM-1.3b places one sLSTM block per 8 blocks,
so the scan cost is amortized 1:7 against parallel mLSTM blocks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.params import ParamSpec

NEG = -1e30


def _dims(cfg: ModelConfig):
    x = cfg.xlstm
    d = cfg.d_model
    di = int(x.m_proj_factor * d)      # mLSTM inner width
    nh = cfg.n_heads
    dh = di // nh
    return d, di, nh, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    d, di, nh, dh = _dims(cfg)
    k = cfg.xlstm.conv_kernel

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    return {
        "ln": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "up": mk((d, 2 * di), ("embed", "mlp")),
        "conv_w": mk((k, di), ("conv", "mlp")),
        "conv_b": mk((di,), ("mlp",), init="zeros"),
        "wq": mk((di, di), ("mlp", "mlp")),
        "wk": mk((di, di), ("mlp", "mlp")),
        "wv": mk((di, di), ("mlp", "mlp")),
        "wif": mk((di, 2 * nh), ("mlp", "heads"), dtype=jnp.float32),
        "b_if": mk((2 * nh,), ("heads",), dtype=jnp.float32, init="zeros"),
        "gn": mk((di,), ("mlp",), dtype=jnp.float32, init="ones"),
        "down": mk((di, d), ("mlp", "embed")),
    }


def _mlstm_qkvgates(p, x_in, cfg, conv_state=None):
    """Shared projections. x_in: (B,L,d) already layer-normed."""
    from repro.models.ssm import _causal_conv

    d, di, nh, dh = _dims(cfg)
    xz = jnp.einsum("bld,de->ble", x_in, p["up"])
    xm, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x_in.dtype)
    b, l = x_in.shape[:2]
    q = jnp.einsum("ble,ef->blf", xc, p["wq"]).reshape(b, l, nh, dh)
    k = jnp.einsum("ble,ef->blf", xc, p["wk"]).reshape(b, l, nh, dh)
    v = jnp.einsum("ble,ef->blf", xm, p["wv"]).reshape(b, l, nh, dh)
    gates = jnp.einsum("ble,eg->blg", xm.astype(jnp.float32), p["wif"])
    gates = gates + p["b_if"]
    li = gates[..., :nh]                                   # (B,L,nh) log input
    lf = jax.nn.log_sigmoid(gates[..., nh:])               # (B,L,nh) log forget
    k = k / math.sqrt(dh)
    return q, k, v, li, lf, z, new_conv


def _mlstm_chunk(q, k, v, li, lf, state):
    """Exact chunkwise mLSTM. q,k,v: (B,L,nh,dh); li,lf: (B,L,nh).

    state: (C (B,nh,dh,dh), n (B,nh,dh), m (B,nh)) stabilized.
    Returns (h (B,L,nh,dh), new state).
    """
    c_in, n_in, m_in = state
    bsz, l, nh, dh = q.shape
    b = jnp.cumsum(lf, axis=1)                             # (B,L,nh)
    # per-position stabilizer
    intra_max = jax.lax.cummax(li - b, axis=1)
    m_t = b + jnp.maximum(m_in[:, None, :], intra_max)     # (B,L,nh)
    # intra-chunk weights W[t,s] = exp(b_t - b_s + li_s - m_t), s<=t
    lw = (b[:, :, None, :] - b[:, None, :, :]
          + li[:, None, :, :] - m_t[:, :, None, :])        # (B,t,s,nh)
    causal = jnp.tril(jnp.ones((l, l), bool))
    w = jnp.exp(jnp.where(causal[None, :, :, None], lw, NEG))
    scores = jnp.einsum("blhd,bshd->blsh", q, k)           # (B,t,s,nh)
    h_intra = jnp.einsum("blsh,blsh,bshd->blhd",
                         scores.astype(jnp.float32), w,
                         v.astype(jnp.float32))
    den_intra = jnp.einsum("blsh,blsh->blh", scores.astype(jnp.float32), w)
    # inter-chunk term
    w_inter = jnp.exp(b + m_in[:, None, :] - m_t)          # (B,L,nh)
    h_inter = jnp.einsum("blhd,bhde->blhe", q.astype(jnp.float32),
                         c_in) * w_inter[..., None]
    den_inter = jnp.einsum("blhd,bhd->blh", q.astype(jnp.float32),
                           n_in) * w_inter
    num = h_intra + h_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # chunk-final state (stepwise state at t=L)
    m_out = m_t[:, -1, :]                                  # (B,nh)
    wc = jnp.exp(b[:, -1:, :] - b + li - m_out[:, None, :])  # (B,s,nh)
    c_out = (jnp.exp(b[:, -1, :] + m_in - m_out)[:, :, None, None] * c_in
             + jnp.einsum("bsh,bshd,bshe->bhde", wc,
                          k.astype(jnp.float32), v.astype(jnp.float32)))
    n_out = (jnp.exp(b[:, -1, :] + m_in - m_out)[:, :, None] * n_in
             + jnp.einsum("bsh,bshd->bhd", wc, k.astype(jnp.float32)))
    return h.astype(q.dtype), (c_out, n_out, m_out)


def mlstm_mixer(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence mLSTM via chunk scan. x: (B,L,d) pre-norm residual input."""
    d, di, nh, dh = _dims(cfg)
    x_in = rmsnorm({"scale": p["ln"]}, x, cfg.norm_eps)
    q, k, v, li, lf, z, conv_state = _mlstm_qkvgates(p, x_in, cfg)
    bsz, l = x.shape[:2]
    chunk = min(cfg.xlstm.chunk, l)
    if l % chunk:
        chunk = l
    n_chunks = l // chunk

    def split(t):
        return t.reshape(bsz, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lis, lfs = map(split, (q, k, v, li, lf))

    def body(state, xs):
        qc, kc, vc, lic, lfc = xs
        h, state = _mlstm_chunk(qc, kc, vc, lic, lfc, state)
        return state, h

    state0 = (
        jnp.zeros((bsz, nh, dh, dh), jnp.float32),
        jnp.zeros((bsz, nh, dh), jnp.float32),
        jnp.full((bsz, nh), NEG, jnp.float32),
    )
    if cfg.unroll_scans:
        state_f, hs_l = state0, []
        for i in range(n_chunks):
            state_f, h_i = body(state_f, (qs[i], ks[i], vs[i], lis[i],
                                          lfs[i]))
            hs_l.append(h_i)
        hs = jnp.stack(hs_l)
    else:
        state_f, hs = jax.lax.scan(body, state0, (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(bsz, l, di)
    h = _groupnorm(h, p["gn"], nh, cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = x + jnp.einsum("ble,ed->bld", h, p["down"])
    if return_state:
        c, n, m = state_f
        return out, {"c": c, "n": n, "m": m, "conv": conv_state}
    return out


def mlstm_state_shape(cfg: ModelConfig, batch: int):
    d, di, nh, dh = _dims(cfg)
    k = cfg.xlstm.conv_kernel
    return {
        "c": (batch, nh, dh, dh),
        "n": (batch, nh, dh),
        "m": (batch, nh),
        "conv": (batch, k - 1, di),
    }


def mlstm_decode(p, x, cfg: ModelConfig, state):
    """Single-step mLSTM. x: (B,1,d)."""
    d, di, nh, dh = _dims(cfg)
    x_in = rmsnorm({"scale": p["ln"]}, x, cfg.norm_eps)
    q, k, v, li, lf, z, conv = _mlstm_qkvgates(
        p, x_in, cfg, conv_state=state["conv"])
    h, (c, n, m) = _mlstm_chunk(
        q, k, v, li, lf, (state["c"], state["n"], state["m"]))
    h = h.reshape(x.shape[0], 1, di)
    h = _groupnorm(h, p["gn"], nh, cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = x + jnp.einsum("ble,ed->bld", h, p["down"])
    return out, {"c": c, "n": n, "m": m, "conv": conv}


def _groupnorm(h, scale, nh, eps):
    bsz, l, di = h.shape
    dh = di // nh
    hf = h.astype(jnp.float32).reshape(bsz, l, nh, dh)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.var(hf, axis=-1, keepdims=True)
    hf = (hf - mu) * jax.lax.rsqrt(var + eps)
    return (hf.reshape(bsz, l, di) * scale).astype(h.dtype)


def mlstm_mixer_reference(p, x, cfg: ModelConfig):
    """Stepwise oracle for the chunkwise form (tests)."""
    d, di, nh, dh = _dims(cfg)
    x_in = rmsnorm({"scale": p["ln"]}, x, cfg.norm_eps)
    q, k, v, li, lf, z, _ = _mlstm_qkvgates(p, x_in, cfg)
    bsz, l = x.shape[:2]
    c = jnp.zeros((bsz, nh, dh, dh), jnp.float32)
    n = jnp.zeros((bsz, nh, dh), jnp.float32)
    m = jnp.full((bsz, nh), NEG, jnp.float32)
    hs = []
    for t in range(l):
        m_new = jnp.maximum(lf[:, t] + m, li[:, t])
        fs = jnp.exp(lf[:, t] + m - m_new)
        is_ = jnp.exp(li[:, t] - m_new)
        kv = jnp.einsum("bhd,bhe->bhde", k[:, t].astype(jnp.float32),
                        v[:, t].astype(jnp.float32))
        c = fs[..., None, None] * c + is_[..., None, None] * kv
        n = fs[..., None] * n + is_[..., None] * k[:, t].astype(jnp.float32)
        m = m_new
        num = jnp.einsum("bhd,bhde->bhe", q[:, t].astype(jnp.float32), c)
        den = jnp.einsum("bhd,bhd->bh", q[:, t].astype(jnp.float32), n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        hs.append(h.reshape(bsz, di))
    h = jnp.stack(hs, axis=1).astype(x.dtype)
    h = _groupnorm(h, p["gn"], nh, cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    return x + jnp.einsum("ble,ed->bld", h, p["down"])


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    df = int(cfg.xlstm.s_proj_factor * d)

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    return {
        "ln": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "w_gates": mk((d, 4 * d), ("embed", "mlp")),       # i,f,z,o input proj
        "r_gates": mk((4, nh, dh, dh), (None, "heads", "head_dim", "head_dim"),
                      scale=1.0 / math.sqrt(dh)),
        "b_gates": mk((4 * d,), ("mlp",), dtype=jnp.float32, init="zeros"),
        "gn": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        # post-mixer gated FFN (factor 4/3)
        "ffn_ln": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "ffn_gate": mk((d, df), ("embed", "mlp")),
        "ffn_up": mk((d, df), ("embed", "mlp")),
        "ffn_down": mk((df, d), ("mlp", "embed")),
    }


def _slstm_step(p, cfg, gx, state):
    """gx: (B,4d) PRE-PROJECTED gate inputs; state: dict(c,n,m,h) (B,nh,dh).

    The input projection x_t @ W_gates is hoisted OUT of the time scan (it
    has no state dependence): one big (B,L,d)@(d,4d) matmul feeds the MXU
    before the recurrence, and only the per-head recurrent term + gating
    elementwise stay sequential. This is the TPU-correct formulation and
    keeps the in-loop flops to the irreducible recurrent part.
    """
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    gx = gx.astype(jnp.float32)
    gr = jnp.einsum("bhd,ghde->gbhe", h.astype(p["r_gates"].dtype),
                    p["r_gates"]).astype(jnp.float32)
    gi, gf, gz, go = [gx[:, i * d:(i + 1) * d].reshape(-1, nh, dh) + gr[i]
                      for i in range(4)]
    m_new = jnp.maximum(gf + m, gi)
    fs = jnp.exp(gf + m - m_new)
    is_ = jnp.exp(gi - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = fs * c + is_ * z
    n_new = fs * n + is_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new,
            "h": h_new.astype(state["h"].dtype)}


def slstm_state_shape(cfg: ModelConfig, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    s = (batch, nh, dh)
    return {"c": s, "n": s, "m": s, "h": s}


def slstm_mixer(p, x, cfg: ModelConfig, state=None):
    """Sequential sLSTM over (B,L,d); returns (y, final state)."""
    bsz, l, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    x_in = rmsnorm({"scale": p["ln"]}, x, cfg.norm_eps)
    if state is None:
        z = jnp.zeros((bsz, nh, dh), jnp.float32)
        state = {"c": z, "n": z, "m": z - 1e30, "h": z.astype(x.dtype)}

    # hoisted input projection: one matmul for all timesteps (MXU-friendly)
    gx_all = jnp.einsum("bld,de->ble", x_in, p["w_gates"]) + p["b_gates"]

    def body(st, gx_t):
        st = _slstm_step(p, cfg, gx_t, st)
        return st, st["h"]

    state, hs = jax.lax.scan(body, state, gx_all.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(bsz, l, d).astype(x.dtype)
    h = _groupnorm(h, p["gn"], nh, cfg.norm_eps)
    y = x + h
    # post FFN
    yn = rmsnorm({"scale": p["ffn_ln"]}, y, cfg.norm_eps)
    g = jnp.einsum("bld,df->blf", yn, p["ffn_gate"])
    u = jnp.einsum("bld,df->blf", yn, p["ffn_up"])
    hf = jax.nn.gelu(g.astype(jnp.float32)).astype(y.dtype) * u
    y = y + jnp.einsum("blf,fd->bld", hf, p["ffn_down"])
    return y, state


def slstm_decode(p, x, cfg: ModelConfig, state):
    y, state = slstm_mixer(p, x, cfg, state)
    return y, state
