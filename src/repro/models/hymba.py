"""Hymba-style hybrid block: parallel attention + SSM heads, fused output.

Each block runs a (sliding-window or global) attention branch and a Mamba-style
SSM branch on the same normed input; the two branch outputs are normalized and
mean-fused with learned per-channel gains (the Hymba fusion), then a SwiGLU FFN
follows. 29/32 layers use sliding-window attention; 3 are global — which is
what makes the long_500k decode shape viable (bounded ring KV for SWA layers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import rmsnorm
from repro.models.params import ParamSpec


def global_layer_ids(cfg: ModelConfig) -> tuple:
    """First / middle / last layers are global-attention (Hymba placement)."""
    n = cfg.n_layers
    g = cfg.n_global_layers
    if g <= 0:
        return ()
    if g == 1:
        return (0,)
    if g == 2:
        return (0, n - 1)
    return (0, n // 2, n - 1) if g == 3 else tuple(
        round(i * (n - 1) / (g - 1)) for i in range(g))


def fusion_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    d = cfg.d_model

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    return {
        "attn_norm": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "ssm_norm": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "attn_gain": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "ssm_gain": mk((d,), ("embed",), dtype=jnp.float32, init="ones"),
    }


def fuse(pf, attn_out, ssm_out, cfg: ModelConfig):
    a = rmsnorm({"scale": pf["attn_norm"]}, attn_out, cfg.norm_eps)
    s = rmsnorm({"scale": pf["ssm_norm"]}, ssm_out, cfg.norm_eps)
    out = 0.5 * (a.astype(jnp.float32) * pf["attn_gain"]
                 + s.astype(jnp.float32) * pf["ssm_gain"])
    return out.astype(attn_out.dtype)
