"""Modality frontend STUBS (per the assignment brief).

The [audio] and [vlm] archs specify the transformer BACKBONE only; the EnCodec
frame encoder / InternViT patch encoder are not reproduced. ``input_specs()``
therefore provides *precomputed* frame/patch embeddings of shape
(batch, frontend_tokens, d_model), and these modules only splice them into the
token-embedding stream (prefix position) and keep the loss off prefix slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def splice_prefix(token_embeds: jax.Array, prefix_embeds: jax.Array):
    """Prepend modality embeddings; returns (hidden, loss_mask)."""
    b, s_tok, d = token_embeds.shape
    s_pre = prefix_embeds.shape[1]
    h = jnp.concatenate([prefix_embeds.astype(token_embeds.dtype),
                         token_embeds], axis=1)
    mask = jnp.concatenate(
        [jnp.zeros((b, s_pre), jnp.float32), jnp.ones((b, s_tok), jnp.float32)],
        axis=1)
    return h, mask


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    return (batch, cfg.frontend_tokens, cfg.d_model)
