"""Decoder-LM assembly for all assigned families.

Layers are scan-stacked (one compiled block body regardless of depth — this is
what keeps the 61-layer/671B dry-run compilable) and rematerialized under grad.
Families:
  dense / audio / vlm : [GQA attn + SwiGLU] x N
  moe                 : [attn (MLA or GQA) + (dense | MoE) ffn], deepseek MTP head
  hybrid (hymba)      : [parallel attn+SSM fused + SwiGLU], SWA + global layers
  ssm (xlstm)         : super-blocks of 7 mLSTM + 1 sLSTM
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import frontends, hymba, mla as mla_lib, moe as moe_lib
from repro.models import ssm as ssm_lib, xlstm as xlstm_lib
from repro.models.layers import (cross_entropy, embed, embed_spec, mlp,
                                 mlp_spec, rmsnorm, rmsnorm_spec, unembed,
                                 apply_rope)
from repro.models.params import ParamSpec

PyTree = Any


# ---------------------------------------------------------------------------
# GQA attention params + apply
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, layers: Optional[int] = None) -> dict:
    d, h, n, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    def mk(shape, axes, **kw):
        if layers is not None:
            shape = (layers,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, **kw)

    spec = {
        "wq": mk((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": mk((d, n, hd), ("embed", "kv_heads", "head_dim")),
        "wv": mk((d, n, hd), ("embed", "kv_heads", "head_dim")),
        "wo": mk((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = mk((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = mk((n, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = mk((n, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = mk((hd,), ("head_dim",), dtype=jnp.float32, init="ones")
        spec["k_norm"] = mk((hd,), ("head_dim",), dtype=jnp.float32, init="ones")
    return spec


def project_qkv(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, x, cfg: ModelConfig, positions, *, window=0,
                  q_chunk=None, kv_chunk=None):
    q, k, v = project_qkv(p, x, cfg, positions)
    o = attn_lib.flash_attention(
        q, k, v, causal=True, window=window,
        q_chunk=q_chunk or cfg.q_chunk, kv_chunk=kv_chunk or cfg.kv_chunk,
        unroll=cfg.unroll_scans)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Param spec for the whole model
# ---------------------------------------------------------------------------

def _dense_block_spec(cfg: ModelConfig, layers: int, d_ff: int) -> dict:
    spec = {
        "ln1": rmsnorm_spec(cfg.d_model, layers),
        "ln2": rmsnorm_spec(cfg.d_model, layers),
        "mlp": mlp_spec(cfg.d_model, d_ff, layers),
    }
    if cfg.mla is not None:
        spec["attn"] = mla_lib.mla_spec(cfg, layers)
    else:
        spec["attn"] = attn_spec(cfg, layers)
    return spec


def _moe_block_spec(cfg: ModelConfig, layers: int) -> dict:
    spec = {
        "ln1": rmsnorm_spec(cfg.d_model, layers),
        "ln2": rmsnorm_spec(cfg.d_model, layers),
        "moe": moe_lib.moe_spec(cfg, layers),
    }
    if cfg.mla is not None:
        spec["attn"] = mla_lib.mla_spec(cfg, layers)
    else:
        spec["attn"] = attn_spec(cfg, layers)
    return spec


def _hybrid_block_spec(cfg: ModelConfig, layers: int) -> dict:
    return {
        "ln1": rmsnorm_spec(cfg.d_model, layers),
        "ln2": rmsnorm_spec(cfg.d_model, layers),
        "attn": attn_spec(cfg, layers),
        "ssm": ssm_lib.ssm_spec(cfg, layers),
        "fusion": hymba.fusion_spec(cfg, layers),
        "mlp": mlp_spec(cfg.d_model, cfg.d_ff, layers),
    }


def param_spec(cfg: ModelConfig) -> dict:
    spec: dict = {"embed": embed_spec(cfg.padded_vocab, cfg.d_model,
                                      cfg.tie_embeddings)}
    if cfg.family in ("dense", "audio", "vlm"):
        spec["blocks"] = _dense_block_spec(cfg, cfg.n_layers, cfg.d_ff)
    elif cfg.family == "moe":
        m = cfg.moe
        if m.first_dense:
            spec["dense_blocks"] = _dense_block_spec(
                cfg, m.first_dense, m.dense_d_ff or cfg.d_ff)
        spec["moe_blocks"] = _moe_block_spec(cfg, cfg.n_layers - m.first_dense)
        if cfg.mtp_weight > 0:
            spec["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", "embed")),
                "block": _dense_block_spec(
                    cfg, 1, m.dense_d_ff or 4 * cfg.d_model),
                "ln": rmsnorm_spec(cfg.d_model),
            }
    elif cfg.family == "hybrid":
        n_global = len(hymba.global_layer_ids(cfg))
        spec["global_blocks"] = _hybrid_block_spec(cfg, n_global)
        spec["swa_blocks"] = _hybrid_block_spec(cfg, cfg.n_layers - n_global)
    elif cfg.family == "ssm":
        x = cfg.xlstm
        n_super = cfg.n_layers // x.slstm_every
        spec["super"] = {
            "mlstm": xlstm_lib.mlstm_spec(cfg, layers=None),
            "slstm": xlstm_lib.slstm_spec(cfg, layers=None),
        }
        # stack: (n_super, per_super-1) for mlstm, (n_super,) for slstm
        spec["super"]["mlstm"] = jax.tree.map(
            lambda s: ParamSpec((n_super, x.slstm_every - 1) + s.shape,
                                ("layers", "layers") + s.axes, s.dtype, s.init,
                                s.scale),
            spec["super"]["mlstm"],
            is_leaf=lambda t: isinstance(t, ParamSpec))
        spec["super"]["slstm"] = jax.tree.map(
            lambda s: ParamSpec((n_super,) + s.shape, ("layers",) + s.axes,
                                s.dtype, s.init, s.scale),
            spec["super"]["slstm"],
            is_leaf=lambda t: isinstance(t, ParamSpec))
    else:
        raise ValueError(f"unknown family {cfg.family}")
    spec["final_norm"] = rmsnorm_spec(cfg.d_model)
    return spec


# ---------------------------------------------------------------------------
# Blocks (shared by train forward and serving prefill)
# ---------------------------------------------------------------------------

def _attn_branch(p, xn, cfg, positions, window, q_chunk, kv_chunk):
    if cfg.mla is not None:
        return mla_lib.mla_attention(p, xn, cfg, positions,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk)
    return gqa_attention(p, xn, cfg, positions, window=window,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)


def dense_block(p, x, cfg, positions, *, window=0, q_chunk=None, kv_chunk=None):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + _attn_branch(p["attn"], xn, cfg, positions, window, q_chunk, kv_chunk)
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn)


def moe_block(p, x, cfg, positions, *, q_chunk=None, kv_chunk=None):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + _attn_branch(p["attn"], xn, cfg, positions, 0, q_chunk, kv_chunk)
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    y, aux = moe_lib.moe_ffn(p["moe"], xn, cfg)
    return x + y, aux


def hybrid_block(p, x, cfg, positions, *, window=0, q_chunk=None, kv_chunk=None):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a = gqa_attention(p["attn"], xn, cfg, positions, window=window,
                      q_chunk=q_chunk, kv_chunk=kv_chunk)
    s = ssm_lib.ssm_mixer(p["ssm"], xn, cfg)
    x = x + hymba.fuse(p["fusion"], a, s, cfg)
    xn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], xn)


def _scan_blocks(stacked, x, body, cfg, n: int):
    """Scan a stacked param tree over the sequence axis 0; remat per block."""
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(carry, layer_params):
        return fn(carry, layer_params), None

    if not cfg.scan_layers:
        for i in range(n):
            x = fn(x, jax.tree.map(lambda t: t[i], stacked))
        return x
    x, _ = jax.lax.scan(step, x, stacked)
    return x


def _scan_blocks_aux(stacked, x, body, cfg, n: int):
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(carry, layer_params):
        x, aux = carry
        x, a = fn(x, layer_params)
        return (x, aux + a), None

    if not cfg.scan_layers:
        aux = jnp.zeros((), jnp.float32)
        for i in range(n):
            x, a = fn(x, jax.tree.map(lambda t: t[i], stacked))
            aux = aux + a
        return x, aux
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(params: PyTree, cfg: ModelConfig, tokens: jax.Array, *,
            prefix_embeds: Optional[jax.Array] = None,
            q_chunk: Optional[int] = None, kv_chunk: Optional[int] = None,
            bspec=None, h0: Optional[jax.Array] = None):
    """tokens: (B, S_tok) int32. Returns (logits, aux_loss, loss_mask).

    ``h0`` (optional) is a precomputed token embedding — used by the pod-ring
    train step, which hoists the embedding gather out of its manual region.
    """
    h = embed(params["embed"], tokens) if h0 is None else h0
    loss_mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.frontend is not None and prefix_embeds is not None:
        h, loss_mask = frontends.splice_prefix(h, prefix_embeds)
    if bspec is not None:
        h = jax.lax.with_sharding_constraint(h, bspec)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "audio", "vlm"):
        body = functools.partial(
            lambda x, p: dense_block(p, x, cfg, positions,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk))
        h = _scan_blocks(params["blocks"], h, body, cfg, cfg.n_layers)

    elif cfg.family == "moe":
        m = cfg.moe
        if m.first_dense:
            body_d = lambda x, p: dense_block(p, x, cfg, positions,
                                              q_chunk=q_chunk, kv_chunk=kv_chunk)
            h = _scan_blocks(params["dense_blocks"], h, body_d, cfg,
                             m.first_dense)
        body_m = lambda x, p: moe_block(p, x, cfg, positions,
                                        q_chunk=q_chunk, kv_chunk=kv_chunk)
        h, aux = _scan_blocks_aux(params["moe_blocks"], h, body_m, cfg,
                                  cfg.n_layers - m.first_dense)

    elif cfg.family == "hybrid":
        h = _hybrid_forward(params, cfg, h, positions, q_chunk, kv_chunk)

    elif cfg.family == "ssm":
        h = _xlstm_forward(params, cfg, h)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg.vocab_size)
    return logits, aux, loss_mask


def _hybrid_forward(params, cfg, h, positions, q_chunk, kv_chunk):
    """Interleave global (full-attn) and SWA block groups in layer order."""
    gids = hymba.global_layer_ids(cfg)
    body_g = lambda x, p: hybrid_block(p, x, cfg, positions, window=0,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk)
    body_s = lambda x, p: hybrid_block(p, x, cfg, positions,
                                       window=cfg.swa_window,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk)
    g_idx, s_idx = 0, 0
    # group consecutive layers of the same kind, scanning each group
    kinds = ["g" if i in gids else "s" for i in range(cfg.n_layers)]
    i = 0
    while i < cfg.n_layers:
        j = i
        while j < cfg.n_layers and kinds[j] == kinds[i]:
            j += 1
        count = j - i
        if kinds[i] == "g":
            part = jax.tree.map(lambda t: t[g_idx:g_idx + count],
                                params["global_blocks"])
            h = _scan_blocks(part, h, body_g, cfg, count)
            g_idx += count
        else:
            part = jax.tree.map(lambda t: t[s_idx:s_idx + count],
                                params["swa_blocks"])
            h = _scan_blocks(part, h, body_s, cfg, count)
            s_idx += count
        i = j
    return h


def _xlstm_forward(params, cfg, h):
    x = cfg.xlstm
    per = x.slstm_every - 1
    n_super = cfg.n_layers // x.slstm_every

    def super_body(carry, p_super):
        def m_body(c, p_layer):
            return xlstm_lib.mlstm_mixer(p_layer, c, cfg), None

        m_fn = jax.checkpoint(lambda c, p: m_body(c, p)[0]) if cfg.remat else (
            lambda c, p: m_body(c, p)[0])

        def m_step(c, p_layer):
            return m_fn(c, p_layer), None

        if cfg.scan_layers:
            carry, _ = jax.lax.scan(m_step, carry, p_super["mlstm"])
        else:
            for i in range(per):
                carry = m_fn(carry,
                             jax.tree.map(lambda t: t[i], p_super["mlstm"]))
        s_fn = (jax.checkpoint(lambda c: xlstm_lib.slstm_mixer(
            p_super["slstm"], c, cfg)[0]) if cfg.remat else
            (lambda c: xlstm_lib.slstm_mixer(p_super["slstm"], c, cfg)[0]))
        return s_fn(carry), None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(super_body, h, params["super"])
    else:
        for i in range(n_super):
            h, _ = super_body(h, jax.tree.map(lambda t: t[i],
                                              params["super"]))
    return h


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------

def train_loss(params: PyTree, cfg: ModelConfig, batch: dict, *, bspec=None,
               q_chunk=None, kv_chunk=None, h0=None, mtp_pre=None,
               gather_free: bool = False) -> jax.Array:
    logits, aux, fmask = forward(
        params, cfg, batch["tokens"], prefix_embeds=batch.get("prefix"),
        q_chunk=q_chunk, kv_chunk=kv_chunk, bspec=bspec, h0=h0)
    mask = fmask
    labels = batch["labels"]
    # with a modality prefix, the hidden sequence is longer than the token
    # sequence; left-pad labels (and any user mask) into the prefix region,
    # whose loss_mask is already zero.
    s_pre = logits.shape[1] - labels.shape[1]
    if s_pre:
        labels = jnp.pad(labels, ((0, 0), (s_pre, 0)))
    if "mask" in batch:
        m = batch["mask"]
        if s_pre:
            m = jnp.pad(m, ((0, 0), (s_pre, 0)))
        mask = mask * m
    loss = cross_entropy(logits, labels, mask, gather_free=gather_free)
    if cfg.family == "moe" and cfg.mtp_weight > 0:
        loss = loss + cfg.mtp_weight * _mtp_loss(
            params, cfg, logits, batch, mtp_pre=mtp_pre,
            gather_free=gather_free)
    return loss + aux


def _mtp_loss(params, cfg, logits, batch, mtp_pre=None, gather_free=False):
    """DeepSeek-style multi-token prediction: one extra block predicts t+2.

    Simplified MTP module: concat(hidden-proxy, next-token embedding) ->
    projection -> one dense block -> shared unembed. Faithful in structure
    (shared embedding/head, sequential conditioning), reduced to depth 1.
    """
    # proxy hidden: embedding of the *current* labels (teacher forcing)
    if mtp_pre is not None:
        cur, emb = mtp_pre
    else:
        emb = embed(params["embed"], batch["labels"])
        cur = embed(params["embed"], batch["tokens"])
    h = jnp.concatenate([cur, emb], axis=-1)
    h = jnp.einsum("bse,ed->bsd", h, params["mtp"]["proj"])
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    blk = jax.tree.map(lambda t: t[0], params["mtp"]["block"])
    h = dense_block(blk, h, cfg, positions)
    h = rmsnorm(params["mtp"]["ln"], h, cfg.norm_eps)
    logits2 = unembed(params["embed"], h, cfg.vocab_size)
    labels2 = jnp.roll(batch["labels"], -1, axis=1)
    mask2 = jnp.ones(labels2.shape, jnp.float32).at[:, -1].set(0.0)
    if "mask" in batch:
        mask2 = mask2 * batch["mask"]
    return cross_entropy(logits2, labels2, mask2, gather_free=gather_free)
