"""Two-process replica-hydration smoke: serve, mirror, hydrate, compare.

    PYTHONPATH=src python tools/hydrate_smoke.py

Process A (child, ``--replica``): a cold replica. It listens on a free
TCP port via :class:`repro.launch.hydrate.ReplicaHydrator`, ingests the
producer's mirrored snapshot chain until a restorable snapshot with
in-flight requests arrives, rebuilds the paged engine from it MID-SERVE
(the producer never pauses), decodes a few steps with zero prefill, and
prints each request's continuation tokens plus a digest.

Process B (this process): the serving loop from ``repro.launch.serve``
with a shared 16-token prefix registered for COW sharing and
``snapshot_to=tcp://...`` pointed at the replica.

Passes when:
  * the replica hydrates from the live chain (>= 1 frame ingested,
    >= 1 registered prefix restored, > 0 in-flight requests);
  * every token the replica decodes equals the token the producer
    decoded at the same position of the same request — greedy decode
    from bit-identical state, so the digests must match exactly;
  * the replica ran no prefill at all after hydration.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCH = "smollm-135m"
MARKER = "HYDRATE_RESULT "


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _digest(records: list[dict]) -> str:
    blob = json.dumps(sorted(records, key=lambda r: r["rid"]),
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


# ---------------------------------------------------------------------------
# child: the cold replica
# ---------------------------------------------------------------------------

def replica_main(port: int, seed: int, steps: int) -> int:
    import jax
    import numpy as np

    from repro.configs import base as configs
    from repro.launch.hydrate import ReplicaHydrator
    from repro.models import params as P_lib
    from repro.models import transformer

    cfg = configs.get(ARCH, smoke=True)
    params = P_lib.materialize(jax.random.PRNGKey(seed),
                               transformer.param_spec(cfg))
    hyd = ReplicaHydrator(f"tcp://127.0.0.1:{port}")

    def ready() -> bool:
        # restorable is not enough: wait for a snapshot with work in
        # flight, so the decode comparison below has something to decode
        if not hyd.store.restorable(hyd.stream):
            return False
        _, leaves = hyd.store.restore(hyd.stream)
        meta = json.loads(np.asarray(leaves["['meta']"],
                                     np.uint8).tobytes())
        return any(a is not None for a in meta["active"])

    engine, info = hyd.hydrate(cfg, params, ready=ready,
                               idle_timeout_s=30.0, start_grace_s=240.0)
    live = [a for a in engine.active if a is not None]
    offsets = {r.rid: len(r.out) for r in live}
    prefill_before = engine.prefill_tokens
    for _ in range(steps):
        if any(a is not None for a in engine.active):
            engine.step()
    records = [{"rid": r.rid, "offset": offsets[r.rid],
                "tokens": r.out[offsets[r.rid]:]} for r in live]
    out = {"records": records, "digest": _digest(records),
           "frames_ingested": info["frames_ingested"],
           "prefixes": info["prefixes"], "step": info["step"],
           "prefill_after_hydration": engine.prefill_tokens
                                      - prefill_before}
    print(MARKER + json.dumps(out))
    return 0


# ---------------------------------------------------------------------------
# parent: the producer + the assertions
# ---------------------------------------------------------------------------

def main() -> int:
    port = _free_port()
    child = subprocess.Popen(
        [sys.executable, "-u", os.path.abspath(__file__), "--replica",
         "--port", str(port), "--seed", "0", "--steps", "3"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)

    lines: list[str] = []
    listening = threading.Event()

    def pump():
        for line in child.stdout:          # type: ignore[union-attr]
            lines.append(line)
            if "listening" in line:
                listening.set()

    t = threading.Thread(target=pump, daemon=True)
    t.start()
    if not listening.wait(timeout=240):
        child.kill()
        print("".join(lines))
        print("FAIL: replica never started listening")
        return 1
    print(f"replica listening on tcp://127.0.0.1:{port} (pid {child.pid})")

    from repro.launch.serve import default_serve_plan, serve_loop

    plan = default_serve_plan(insitu_mode="async", snapshot_every=2,
                              base_every=4,
                              snapshot_to=f"tcp://127.0.0.1:{port}")
    out = serve_loop(ARCH, n_requests=8, max_new=16, prefix_len=16,
                     insitu_mode="async", plan=plan)

    try:
        child.wait(timeout=240)
    except subprocess.TimeoutExpired:
        child.kill()
        print("".join(lines))
        print("FAIL: replica did not exit")
        return 1
    t.join(timeout=10)
    stdout = "".join(lines)
    print("--- replica output ---")
    print(stdout.strip())
    print("----------------------")
    if child.returncode != 0:
        print(f"FAIL: replica exited {child.returncode}")
        return 1

    marker = [l for l in stdout.splitlines() if l.startswith(MARKER)]
    if not marker:
        print("FAIL: replica printed no result")
        return 1
    res = json.loads(marker[0][len(MARKER):])

    failures = []
    if res["frames_ingested"] < 1:
        failures.append("replica ingested no frames")
    if res["prefixes"] < 1:
        failures.append("replica restored no registered prefix")
    if not res["records"]:
        failures.append("replica hydrated with no in-flight requests")
    if res["prefill_after_hydration"] != 0:
        failures.append(f"replica ran {res['prefill_after_hydration']} "
                        f"prefill tokens after hydration (want 0)")

    # token-for-token: replica continuation == what the producer decoded
    # at the same positions (greedy decode from bit-identical state)
    by_rid = {r.rid: r.out for r in out["requests"]}
    expected = []
    for rec in res["records"]:
        want = by_rid[rec["rid"]][rec["offset"]:
                                  rec["offset"] + len(rec["tokens"])]
        expected.append({"rid": rec["rid"], "offset": rec["offset"],
                         "tokens": want})
        if not rec["tokens"]:
            failures.append(f"request {rec['rid']}: replica decoded "
                            f"nothing")
        elif rec["tokens"] != want:
            failures.append(f"request {rec['rid']} diverged at offset "
                            f"{rec['offset']}: replica {rec['tokens']} "
                            f"vs producer {want}")
    want_digest = _digest(expected)
    if res["digest"] != want_digest:
        failures.append(f"digest mismatch: replica {res['digest'][:16]}... "
                        f"vs producer {want_digest[:16]}...")
    else:
        print(f"digest OK: {res['digest'][:16]}... on both sides "
              f"({len(res['records'])} in-flight requests, "
              f"snapshot step {res['step']})")

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("hydrate smoke passed: cold replica hydrated over TCP mid-serve, "
          "decoded in lockstep with zero prefill")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--replica", action="store_true")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()
    if args.replica:
        sys.exit(replica_main(args.port, args.seed, args.steps))
    sys.exit(main())
