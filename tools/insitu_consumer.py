"""Tail a live in-situ run over TCP and optionally steer it back.

    PYTHONPATH=src python tools/insitu_consumer.py --port 9100 \\
        --steer '{"task": "kv_snapshot", "every": 2}' --restore kv_pages

Point any producer transport at the printed address: a plan option
``"to": "tcp://127.0.0.1:9100"``, a ``CheckpointConfig.mirror``, or
``repro.launch.serve --snapshot-to tcp://127.0.0.1:9100``. Snapshot chain
frames build a local replica (``--restore`` proves bit-identical state),
checkpoint shards land under ``--out-dir``, and analysis artifacts are
decoded with the shared registry. This is a thin CLI over
``repro.launch.consume.consume_loop``.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.consume import main  # noqa: E402

if __name__ == "__main__":
    main()
