"""Generate the EXPERIMENTS.md §Roofline table from dry-run artifacts.

    PYTHONPATH=src python tools/report.py [--tag TAG]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def fmt_bytes(x):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(tag=""):
    rows = []
    for fn in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(fn) as f:
            d = json.load(f)
        if d.get("tag", "") != tag:
            continue
        rows.append(d)
    return rows


def table(rows):
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | step s | useful | roofline frac | cost |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        exact = "exact" if "raw_scanned_cost" in d else "scanned*"
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['compute_s']:.4f} | {d['memory_s']:.4f} "
            f"| {d['collective_s']:.4f} | {d['bottleneck']} "
            f"| {d['step_s']:.4f} | {d['useful_flops_ratio']:.2f} "
            f"| {d['roofline_fraction']:.3f} | {exact} |")
    return "\n".join(out)


def memtable(rows):
    out = ["| arch | shape | mesh | args/device | temps/device | model-mem/device |",
           "|" + "---|" * 6]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = d.get("memory_per_device_bytes") or {}
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {fmt_bytes(m.get('argument_bytes') or 0)} "
            f"| {fmt_bytes(m.get('temp_bytes') or 0)} "
            f"| {fmt_bytes(d.get('model_bytes_per_device') or 0)} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--mem", action="store_true")
    args = ap.parse_args()
    rows = load(args.tag)
    print(f"<!-- {len(rows)} cells, tag={args.tag!r} -->")
    print(table(rows))
    if args.mem:
        print()
        print(memtable(rows))


if __name__ == "__main__":
    main()
