"""Two-process localhost streaming smoke: trainer publishes, consumer steers.

    PYTHONPATH=src python tools/stream_smoke.py

Process A (child): ``tools/insitu_consumer.py`` listening on a free port,
building a replica snapshot chain, pushing one steering command
(``{"task": "kv_snapshot", "every": 2}``) back up the wire, and printing
the digest of its restored state.

Process B (this process): the serving loop from ``repro.launch.serve``
with ``snapshot_to=tcp://...`` — every chain frame the ``SnapshotStore``
publishes is mirrored over TCP while the loop keeps serving.

Passes when:
  * the consumer's restored snapshot digest is BIT-IDENTICAL to a restore
    from the producer's on-disk chain (same step, same leaves);
  * the producer's session report shows the steering command was applied
    mid-run (``report["steering"]``);
  * neither process crashed and the producer never raised a task error.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.consume import restore_report  # noqa: E402
from repro.launch.serve import default_serve_plan, serve_loop  # noqa: E402
from repro.serving.snapshot import SnapshotStore  # noqa: E402


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def main() -> int:
    port = _free_port()
    tmp = tempfile.mkdtemp(prefix="stream_smoke_")
    chain_dir = os.path.join(tmp, "producer_chain")
    steer = json.dumps({"task": "kv_snapshot", "every": 2})

    consumer = subprocess.Popen(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "insitu_consumer.py"),
         "--port", str(port), "--idle-timeout", "5",
         "--start-grace", "240",
         "--steer", steer, "--restore", "kv_pages"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    print(f"consumer listening on tcp://127.0.0.1:{port} "
          f"(pid {consumer.pid})")

    plan = default_serve_plan(insitu_mode="sync", snapshot_every=4,
                              base_every=4, snapshot_dir=chain_dir,
                              snapshot_to=f"tcp://127.0.0.1:{port}")
    out = serve_loop("smollm-135m", n_requests=16, max_new=16,
                     insitu_mode="sync", plan=plan)
    rep = out["session_report"]

    try:
        stdout, _ = consumer.communicate(timeout=120)
    except subprocess.TimeoutExpired:
        consumer.kill()
        stdout, _ = consumer.communicate()
        print(stdout)
        print("FAIL: consumer did not exit after the stream drained")
        return 1
    print("--- consumer output ---")
    print(stdout.strip())
    print("-----------------------")
    if consumer.returncode != 0:
        print(f"FAIL: consumer exited {consumer.returncode}")
        return 1

    failures = []

    # 1. bit-identical restore: replica digest == producer's on-disk chain
    local = restore_report({"store": SnapshotStore(chain_dir)}, "kv_pages")
    marker = f"digest {local['digest']}"
    if marker not in stdout:
        failures.append(
            f"consumer restore digest != producer chain digest "
            f"(expected {local['digest'][:16]}..., consumer printed: "
            f"{[l for l in stdout.splitlines() if 'digest' in l]})")
    else:
        print(f"restore parity OK: step {local['step']}, "
              f"digest {local['digest'][:16]}... on both sides")

    # 2. steering applied mid-run on the producer
    steering = rep.get("steering", {})
    commands = steering.get("commands", [])
    applied = [s for s in commands if s.get("applied", {}).get("every") == 2]
    if not applied:
        failures.append(f"steering not applied by the producer: {steering}")
    elif steering.get("steering_rejected", 0):
        failures.append(f"valid steering counted as rejected: {steering}")
    else:
        print(f"steering OK: {applied[0]}")

    # 3. the producer streamed and never raised
    snap = rep["tasks"].get("kv_snapshot", {})
    if rep.get("errors"):
        failures.append(f"producer task errors: {rep['errors']}")
    if snap.get("mirror_frames", 0) < 1:
        failures.append(f"no frames mirrored: {snap}")
    else:
        print(f"streamed {snap.get('mirror_frames')} chain frames, "
              f"{snap.get('mirror_failures', 0)} failures")

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("stream smoke passed: two processes, live chain replication, "
          "bit-identical restore, mid-run steering")
    return 0


if __name__ == "__main__":
    sys.exit(main())
