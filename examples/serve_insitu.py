"""Serve a small model with batched requests + in-situ serving analytics.

    PYTHONPATH=src python examples/serve_insitu.py --requests 8
"""
import argparse

from repro.launch.serve import serve_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--insitu", default="async",
                    choices=["sync", "async", "hybrid"])
    args = ap.parse_args()
    out = serve_loop(args.arch, n_requests=args.requests,
                     max_new=args.max_new, insitu_mode=args.insitu)
    for r in out["requests"][:4]:
        print(f"request {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
