"""Quickstart: the declarative in-situ API in 60 lines.

Runs a tiny jitted "simulation" (a training step stand-in), declares the
same compression probe under the paper's three placements, and prints the
telemetry the paper reads off NSight: sync stalls the loop, async hides the
work behind the device, hybrid runs a device stage that ships 4-8x less
data across the device->host boundary.

The workflow is *declared* as an ``InSituPlan`` (streams + triggers +
tasks) and driven through a ``Session`` — the application's only in-situ
call is ``session.emit``.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.insitu import Every, InSituPlan, Placement, Session, TaskSpec
from repro.core import codecs
from repro.kernels import ops


def main() -> None:
    # the "application": any jitted device step
    w = jnp.asarray(np.random.default_rng(0).standard_normal((512, 512)),
                    jnp.float32)

    @jax.jit
    def sim_step(x):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    def compress(step, payload):
        blob, st = codecs.encode(np.asarray(payload), "zlib")
        return st.ratio

    # hybrid's deeply-coupled device stage: lossy-compress ON DEVICE so the
    # hand-off ships the small int8 residue (the NEKO pattern)
    def device_lossy(step, x):
        return ops.spectral_compress(x, 1e-2).q

    for mode in (Placement.SYNC, Placement.ASYNC, Placement.HYBRID):
        plan = InSituPlan(
            streams=["field"],
            tasks=[TaskSpec(
                name="compress", stream="field", trigger=Every(2),
                placement=mode, sink=compress,
                device_stage=device_lossy if mode is Placement.HYBRID
                else None)],
            workers=2)
        state = jnp.ones((512, 512), jnp.float32)
        t0 = time.perf_counter()
        with Session(plan, raise_on_error=True) as session:
            for i in range(10):
                with session.step_span(i):
                    state = sim_step(state)
                    state.block_until_ready()
                session.emit("field", i, lambda: state)
        wall = time.perf_counter() - t0
        rep = session.report()
        print(f"{mode.value:6s}: wall={wall:.3f}s "
              f"stall={rep['sync_stall_s']:.3f}s "
              f"overlapped={rep['async_overlapped_s']:.3f}s "
              f"handoff={rep['handoff_s']:.4f}s "
              f"results={rep['n_results']}")


if __name__ == "__main__":
    main()
