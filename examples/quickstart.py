"""Quickstart: the in-situ engine in 60 lines.

Runs a tiny jitted "simulation" (a training step stand-in), attaches the
three in-situ modes from the paper, and prints the telemetry that the paper
reads off NSight: sync stalls the loop, async hides the work behind the
device, hybrid ships 25-50x less data across the device->host boundary.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import InSituEngine, InSituMode, InSituTask, run_workflow
from repro.core import codecs
from repro.kernels import ops


def main() -> None:
    # the "application": any jitted device step
    w = jnp.asarray(np.random.default_rng(0).standard_normal((512, 512)),
                    jnp.float32)

    @jax.jit
    def sim_step(x):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    state = {"x": jnp.ones((512, 512), jnp.float32)}

    def app_step(i):
        state["x"] = sim_step(state["x"])
        state["x"].block_until_ready()
        return {
            "raw": lambda: np.asarray(state["x"]),
            # hybrid: the lossy stage runs on DEVICE; host gets int8 residue
            "residue": lambda: np.asarray(
                ops.spectral_compress(state["x"], 1e-2).q),
        }

    def compress(step, payload):
        blob, st = codecs.encode(payload, "zlib")
        return st.ratio

    for mode, source in ((InSituMode.SYNC, "raw"),
                         (InSituMode.ASYNC, "raw"),
                         (InSituMode.HYBRID, "residue")):
        engine = InSituEngine(
            [InSituTask("compress", source, compress, mode=mode, every=2)],
            p_i=2)
        t0 = time.perf_counter()
        run_workflow(10, app_step, engine)
        wall = time.perf_counter() - t0
        rep = engine.report()
        print(f"{mode.value:6s}: wall={wall:.3f}s "
              f"stall={rep['sync_stall_s']:.3f}s "
              f"overlapped={rep['async_overlapped_s']:.3f}s "
              f"handoff={rep['handoff_s']:.4f}s "
              f"results={rep['n_results']}")


if __name__ == "__main__":
    main()
