"""The paper's compression story on training state, end to end.

Shows (1) Table II: plain lossless barely compresses float tensors,
(2) §IV-B: spectral lossy + lossless removes ~98% on smooth fields with a
hard error bound, (3) the checkpoint-manager integration: lossy moments +
lossless weights, written asynchronously, restored elastically.

    PYTHONPATH=src python examples/compression_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import codecs
from repro.insitu import InSituPlan, Session
from repro.kernels import ops, ref


def main() -> None:
    rng = np.random.default_rng(0)

    print("== Table II analog: lossless CR on float data ==")
    t = np.linspace(0, 40, 1 << 18)
    field = (np.sin(t) + 0.3 * np.sin(7.3 * t)
             + 0.01 * rng.standard_normal(t.size)).astype(np.float32)
    for codec in ("zlib", "bz2", "lzma"):
        cr = codecs.compression_ratio(field, codec).ratio
        print(f"  {codec:5s}: CR = {cr * 100:5.2f}%  (paper: 1.5-10%)")

    print("\n== §IV-B: spectral lossy + lossless at eps=1e-2 ==")
    x = jnp.asarray(field)
    c = ops.spectral_compress(x, 1e-2)
    xh = ops.spectral_decompress(c)
    blob, _ = codecs.encode(np.asarray(c.q), "zlib")
    stored = len(blob) + int(np.asarray(c.scale).nbytes)
    print(f"  removed {(field.nbytes - stored) / field.nbytes * 100:.2f}% "
          f"(paper: ~98%), rel-L2 error {ref.rel_l2_error(x, xh):.4f}, "
          f"kept coeffs {ref.kept_fraction(c) * 100:.2f}%")

    print("\n== checkpoint integration (hybrid in-situ) ==")
    params = {"w": jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
              .astype(jnp.bfloat16)}
    st = optim.init(params, optim.AdamWConfig())
    state = {"params": params, "mu": st.mu, "nu": st.nu}
    d = tempfile.mkdtemp()
    plan = InSituPlan.from_dict({
        "streams": ["train_state"],
        "tasks": {"checkpoint": {"stream": "train_state",
                                 "preset": "checkpoint",
                                 "placement": "hybrid", "every": 1,
                                 "options": {"directory": d}}},
    })
    with Session(plan, raise_on_error=True) as session:
        session.emit("train_state", 100, lambda: state)
    rep = session.checkpoint.reports[-1]
    print(f"  checkpoint: {rep.raw_bytes} B raw -> {rep.stored_bytes} B "
          f"stored (CR {rep.ratio * 100:.1f}%), "
          f"{rep.lossy_leaves}/{rep.n_leaves} leaves lossy")
    step, restored = session.restore(state)
    exact = bool(jnp.all(restored["params"]["w"] == params["w"]))
    print(f"  restored step {step}: weights bit-exact = {exact}")


if __name__ == "__main__":
    main()
