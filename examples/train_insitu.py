"""End-to-end driver: train a ~100M-param model with the full in-situ stack.

smollm-135m at REDUCED width on CPU (pass --full-135m on real hardware), a
few hundred steps, with the whole in-situ workflow — analytics and
compressed checkpointing — declared as one plain-dict ``InSituPlan``
(exactly what a TOML/JSON launcher config would contain):

  * async grad-health analytics every 10 steps on the ``grads`` stream
  * async compressed checkpointing every 50 steps (lossy moments) on the
    ``train_state`` stream
  * restart support: rerun the same command after an interruption and it
    resumes from the latest atomic checkpoint.

    PYTHONPATH=src python examples/train_insitu.py --steps 300
"""
import argparse

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--insitu", default="async",
                    choices=["sync", "async", "hybrid"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_insitu")
    ap.add_argument("--full-135m", action="store_true",
                    help="use the full config (needs accelerator memory)")
    args = ap.parse_args()

    # the whole in-situ workflow, declared as data (TOML/JSON-loadable)
    plan = {
        "streams": ["grads", "train_state"],
        "workers": 2,
        "tasks": {
            "analytics": {"stream": "grads", "preset": "grad_health",
                          "every": 10, "placement": args.insitu},
            "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                           "every": 50, "placement": args.insitu,
                           "options": {"directory": args.ckpt_dir}},
        },
    }
    out = train_loop(args.arch, steps=args.steps, smoke=not args.full_135m,
                     plan=plan)

    losses = out["losses"]
    print(f"\nfirst loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
          f"({len(losses)} steps)")
    print(f"in-situ artifacts produced: {out['insitu_results']}")
    rep = out["session_report"]
    print(f"device compute {rep['step_compute_s']:.2f}s | "
          f"sync stalls {rep['sync_stall_s']:.2f}s | "
          f"async overlapped {rep['async_overlapped_s']:.2f}s | "
          f"hand-off {rep['handoff_s']:.2f}s")
    if "checkpoint" in rep:
        ck = rep["checkpoint"]
        print(f"checkpoints: {ck['saves']} saves, "
              f"{ck['raw_bytes'] / 1e6:.1f}MB raw -> "
              f"{ck['stored_bytes'] / 1e6:.1f}MB stored, "
              f"kept steps {ck['kept_steps']}")
    print(f"stragglers: {out['straggler_report']['stragglers']}")


if __name__ == "__main__":
    main()
