"""End-to-end driver: train a ~100M-param model with the full in-situ stack.

smollm-135m at REDUCED width on CPU (pass --full-135m on real hardware), a
few hundred steps, with the whole in-situ workflow — analytics and
compressed checkpointing — declared as one plain-dict ``InSituPlan``
(exactly what a TOML/JSON launcher config would contain):

  * async grad-health analytics every 10 steps on the ``grads`` stream
  * async compressed checkpointing every 50 steps (lossy moments) on the
    ``train_state`` stream
  * restart support: rerun the same command after an interruption and it
    resumes from the latest atomic checkpoint.

    PYTHONPATH=src python examples/train_insitu.py --steps 300

``--inject-sink-faults`` is the transient-IO drill: the analytics sink
fails with ``TransientError`` on a schedule (recovers under retry early,
exhausts retries later), and the run must complete anyway with the
degradation named in the session report.

``--stream-drill`` is the network version of the same drill: analytics
stream over a real TCP transport (``"to": "tcp://..."`` in the plan) to an
in-process consumer, with the connection severed mid-run — the sink must
reconnect transparently, the consumer must keep receiving frames, and the
train loop must never crash. The same ``inject_sink_fault`` hook drives
both drills; transport sinks are just sinks.
"""
import argparse
import threading

from repro.core.runtime import TransientError
from repro.launch.train import train_loop


def make_analytics_fault():
    """Deterministic transient-failure schedule for the analytics sink.

    Firings at steps < 10 fail twice then succeed (retry-with-backoff
    recovers); firings at steps >= 10 always fail (retries exhaust, the
    task degrades and later firings are dropped, not raised).
    """
    attempts: dict = {}

    def fault(step: int) -> None:
        attempts[step] = attempts.get(step, 0) + 1
        if step < 10:
            if attempts[step] <= 2:
                raise TransientError(f"injected transient IO @ step {step}")
            return
        raise TransientError(f"injected persistent IO outage @ step {step}")

    return fault


def run_stream_drill(args) -> None:
    """Network-fault drill: analytics over TCP with a mid-run connection cut.

    An in-process consumer (``repro.launch.consume``) listens on localhost;
    the analytics preset forwards every report through a ``StreamSink``.
    A fault hook severs the TCP connection on the drill step — NOT by
    raising, but by ``drop_connection()`` on the live transport sink, the
    same thing a consumer crash or network blip does — and the next write
    must reconnect transparently. The run passes when the loop completes,
    the sink reports a reconnect, and the consumer received frames on both
    sides of the cut.
    """
    from repro.core import transport
    from repro.launch.consume import consume_loop

    source = transport.StreamSource(port=0)
    done: dict = {}

    def consume() -> None:
        # long start grace: the producer only connects after jit compile
        done["report"] = consume_loop(source, idle_timeout_s=3.0,
                                      start_grace_s=300.0,
                                      log=lambda *_: None)

    consumer = threading.Thread(target=consume, daemon=True)
    consumer.start()

    plan = {
        "streams": ["grads"],
        "workers": 2,
        "tasks": {
            "analytics": {"stream": "grads", "preset": "grad_health",
                          "every": 5, "placement": "sync",
                          "retries": 3, "retry_backoff_s": 0.01,
                          "options": {"to": source.address}},
        },
    }

    drill_step = 5 * (args.steps // 10 or 1)  # an analytics firing mid-run
    grabbed: dict = {}

    def grab_transport(session) -> None:
        grabbed["sink"] = session.transport_of("analytics")

    def cut_connection(step: int) -> None:
        if step == drill_step:
            grabbed["sink"].drop_connection()

    out = train_loop(args.arch, steps=args.steps, smoke=not args.full_135m,
                     plan=plan, on_session=grab_transport,
                     sink_faults={"analytics": cut_connection})
    consumer.join(timeout=10.0)

    rep = out["session_report"]
    tr = rep["tasks"]["analytics"]["transport"]
    got = done.get("report", {})
    print(f"\nstream drill: {tr['frames']} frames "
          f"({tr['bytes'] / 1e3:.1f}KB) over {tr['sink']}, "
          f"{tr['reconnects']} connects; consumer saw "
          f"{got.get('frames', 0)} frames")
    assert not rep["errors"], f"no task may raise: {rep['errors']}"
    assert tr["reconnects"] >= 2, (
        f"expected a reconnect after the cut, got {tr['reconnects']}")
    assert got.get("frames", 0) >= tr["frames"] - 1, (
        "consumer missed frames that were reported sent")
    print("stream drill passed: connection cut healed, no frames lost, "
          "loop never stalled")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--insitu", default="async",
                    choices=["sync", "async", "hybrid"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_insitu")
    ap.add_argument("--full-135m", action="store_true",
                    help="use the full config (needs accelerator memory)")
    ap.add_argument("--inject-sink-faults", action="store_true",
                    help="transient-IO drill on the analytics sink")
    ap.add_argument("--stream-drill", action="store_true",
                    help="network drill: analytics over TCP with a mid-run "
                         "connection cut (must reconnect, never crash)")
    args = ap.parse_args()

    if args.stream_drill:
        run_stream_drill(args)
        return

    # the drill pins analytics SYNC so the fail/degrade/drop schedule is
    # deterministic (async workers may lag the loop by a few steps)
    analytics_placement = "sync" if args.inject_sink_faults else args.insitu
    # the whole in-situ workflow, declared as data (TOML/JSON-loadable)
    plan = {
        "streams": ["grads", "train_state"],
        "workers": 2,
        "tasks": {
            "analytics": {"stream": "grads", "preset": "grad_health",
                          "every": 10, "placement": analytics_placement,
                          "retries": 3, "retry_backoff_s": 0.01},
            "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                           "every": 50, "placement": args.insitu,
                           "options": {"directory": args.ckpt_dir}},
        },
    }
    sink_faults = ({"analytics": make_analytics_fault()}
                   if args.inject_sink_faults else None)
    out = train_loop(args.arch, steps=args.steps, smoke=not args.full_135m,
                     plan=plan, sink_faults=sink_faults)

    losses = out["losses"]
    print(f"\nfirst loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
          f"({len(losses)} steps)")
    print(f"in-situ artifacts produced: {out['insitu_results']}")
    rep = out["session_report"]
    print(f"device compute {rep['step_compute_s']:.2f}s | "
          f"sync stalls {rep['sync_stall_s']:.2f}s | "
          f"async overlapped {rep['async_overlapped_s']:.2f}s | "
          f"hand-off {rep['handoff_s']:.2f}s")
    if "checkpoint" in rep:
        ck = rep["checkpoint"]
        print(f"checkpoints: {ck['saves']} saves, "
              f"{ck['raw_bytes'] / 1e6:.1f}MB raw -> "
              f"{ck['stored_bytes'] / 1e6:.1f}MB stored, "
              f"kept steps {ck['kept_steps']}")
    print(f"stragglers: {out['straggler_report']['stragglers']}")
    if args.inject_sink_faults:
        retries = rep.get("retries", {}).get("analytics", 0)
        deg = rep.get("degraded", {}).get("analytics")
        print(f"sink-fault drill: {retries} retries, degraded={deg}")
        assert retries > 0, "expected retried transient sink failures"
        assert deg is not None and deg["dropped"] >= 1, (
            "expected the analytics task to degrade and drop firings")
        assert not rep["errors"], f"no task may raise: {rep['errors']}"
        print("sink-fault drill passed: run completed, degradation reported")


if __name__ == "__main__":
    main()
