"""Fig. 6 (F2): original vs sync vs async across nodes — REAL mode split.

One node measured for real (device=sleep, task=real); multi-node totals
extend via the image-generation Amdahl curve. Shows the paper's three
panels: app time ~flat per step, sync stall persists (poor vis scaling),
async adds only the hand-off until the task outgrows the app (4+ nodes).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import analysis
from repro.core.insitu import InSituMode


def task(step, payload):
    return analysis.tensor_summary("field", payload, step, work=2)


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 19)
    t1 = common.calibrate_task(task, field)
    step_s = t1 * 1.2
    n, every = (10, 2) if quick else (50, 5)
    measured = common.run_modes(
        task, field, n_steps=n, step_s=step_s, every=every, p_i=2,
        modes=(InSituMode.SYNC, InSituMode.ASYNC))
    none_wall = n * step_s
    common.row("fig06/nodes2/none", none_wall * 1e6 / n, "measured")
    for mode in ("sync", "async"):
        r = measured[mode]
        common.row(f"fig06/nodes2/{mode}", r["wall_s"] * 1e6 / n,
                   f"measured;stall={r['sync_stall_s']:.3f};"
                   f"handoff={r['handoff_s']:.3f}")
    # F2 core claims, real: sync stalls by ~the task time; async does not.
    # The stall scales with *firings*, not steps — a fixed 1.3x multiplier
    # only holds when every step fires (quick mode: fires/n = 1/2); in full
    # mode (fires/n = 1/5) the added stall is ~t1/5 per step, so the bound
    # must be relative to fires * t1.
    fires = n // every
    assert measured["sync"]["wall_s"] > none_wall + 0.5 * fires * t1
    assert measured["async"]["wall_s"] < measured["sync"]["wall_s"]
    assert measured["async"]["sync_stall_s"] == 0.0

    img = common.amdahl_from_calibration(t1, sigma=0.15)
    out = {"nodes": [], "sync": [], "async": []}
    for nodes in (2, 3, 4, 6, 8):
        app = none_wall                           # same GPUs per node ratio
        sync = app + fires * img.predict(12 * nodes // 2)
        asyn = max(app, fires * img.predict(12 * nodes // 2)) \
            + img.predict(12 * nodes // 2)
        common.row(f"fig06/nodes{nodes}/sync_model", sync * 1e6 / n, "model")
        common.row(f"fig06/nodes{nodes}/async_model", asyn * 1e6 / n, "model")
        out["nodes"].append(nodes)
        out["sync"].append(sync)
        out["async"].append(asyn)
    assert all(a <= s for a, s in zip(out["async"], out["sync"]))
    return out


if __name__ == "__main__":
    run()
