"""Fig. 3: GPU-accelerated app + SYNC image generation vs host cores.

The device (sleep) runs the simulation; the synchronous in-situ task stalls
the loop. More host cores shrink the stall (internally-parallel task).
Measured at p=1 (container limit), model curve for the paper's 4..36 cores.
Validates: total time decreases with cores while the device time is flat.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import analysis
from repro.core.insitu import InSituMode


def task(step, payload):
    return analysis.tensor_summary("field", payload, step, work=2)


def run(quick: bool = True) -> list[dict]:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)
    step_s = 0.01 if quick else 0.05
    n_steps, every = (10, 2) if quick else (100, 10)

    # REAL measurement, 1 worker, sync
    res = common.run_modes(task, field, n_steps=n_steps, step_s=step_s,
                           every=every, p_i=1,
                           modes=(InSituMode.SYNC,))["sync"]
    t_task = common.calibrate_task(task, field)
    img = common.amdahl_from_calibration(t_task, sigma=0.15)
    fires = (n_steps + every - 1) // every
    device_s = n_steps * step_s
    out = []
    common.row("fig03/cores1/measured_total", res["wall_s"] * 1e6 / n_steps,
               f"sync_stall_s={res['sync_stall_s']:.3f}")
    for cores in (4, 8, 12, 24, 36):
        total = device_s + fires * img.predict(cores)
        common.row(f"fig03/cores{cores}/total", total * 1e6 / n_steps,
                   "model")
        out.append({"cores": cores, "total_s": total})
    # device time flat; totals decrease monotonically
    assert all(out[i]["total_s"] >= out[i + 1]["total_s"]
               for i in range(len(out) - 1))
    return out


if __name__ == "__main__":
    run()
