"""Serving snapshots: delta-encoded base+delta chain vs full-slab zlib.

The serving loop's KV slab is append-mostly: between two snapshot firings a
handful of slots gain a few freshly decoded tokens each and everything else
is byte-identical. The pre-delta ``serve_snapshot`` path paid full lossless
compression of the slab on *every* firing; the versioned
:class:`~repro.serving.snapshot.SnapshotStore` pays it only on base frames
(every ``base_every``-th publish) and ships per-chunk XOR/COPY deltas in
between — Huebl et al.'s point that the *reduction ratio*, not bandwidth,
is the binding constraint at scale.

This benchmark drives an append-mostly decode workload (a warm slab; each
firing appends a few tokens to the active slots, with slot turnover) and
measures, over the same sequence of snapshots:

  * the **effective compression ratio** (total raw bytes / total stored
    bytes) of the delta chain vs compressing the full slab with plain zlib
    each firing — the acceptance gate is delta >= 2x zlib (full mode;
    quick mode gates >= 1x),
  * publish latency (us per firing) for both paths,
  * **bit-identical restore** through the base+delta chain: the newest
    snapshot and a mid-chain prefix both replay exactly, from a *fresh*
    store instance reading the on-disk frames.

The metrics dict lands in ``BENCH_runtime.json`` under ``snapshot_delta``
on ``--full`` runs of ``benchmarks.run``. CI smoke-runs quick mode.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import codecs
from repro.serving.snapshot import SnapshotStore


def _warm_slab(slots: int, tokens: int, width: int,
               fill: float, seed: int = 0) -> dict[str, np.ndarray]:
    """A warm serving slab: ``fill`` of each slot's token rows hold data
    (turbulence-flavoured, compressible like real activations), the rest
    are zeros — the unwritten tail of each page."""
    out = {}
    for name in ("k", "v"):
        arr = np.zeros((slots, tokens, width), np.float32)
        filled = int(tokens * fill)
        data = common.turbulence_field(slots * filled * width,
                                       seed=seed + (name == "v"))
        arr[:, :filled, :] = data.reshape(slots, filled, width)
        out[name] = arr
    return out


def _append_step(slab: dict[str, np.ndarray], lengths: np.ndarray,
                 active: np.ndarray, new_tokens: int, rng) -> None:
    """One firing's worth of decode mutation: the active slots append
    ``new_tokens`` rows each; a slot that fills up is re-admitted (its page
    resets — the worst case for the delta, a whole page rewrite)."""
    slots, tokens, width = slab["k"].shape
    for s in np.flatnonzero(active):
        if lengths[s] + new_tokens > tokens:
            for name in ("k", "v"):
                slab[name][s] = 0.0
                slab[name][s, :tokens // 2] = common.turbulence_field(
                    (tokens // 2) * width,
                    seed=int(rng.integers(1 << 30))).reshape(-1, width)
            lengths[s] = tokens // 2
            continue
        for name in ("k", "v"):
            slab[name][s, lengths[s]:lengths[s] + new_tokens] = (
                common.turbulence_field(
                    new_tokens * width,
                    seed=int(rng.integers(1 << 30))).reshape(-1, width))
        lengths[s] += new_tokens


def run(quick: bool = True) -> dict:
    slots, width = 8, (64 if quick else 128)
    tokens = 1024 if quick else 4096
    n_firings = 12 if quick else 24
    base_every = 4 if quick else 8
    new_tokens = 16
    rng = np.random.default_rng(0)

    slab = _warm_slab(slots, tokens, width, fill=0.5)
    lengths = np.full((slots,), tokens // 2, np.int64)
    raw_mb = sum(a.nbytes for a in slab.values()) / 1e6

    mid = n_firings // 2
    mid_snapshot = None
    delta_s = zlib_s = 0.0
    zlib_stored = 0
    with tempfile.TemporaryDirectory() as d:
        store = SnapshotStore(d, base_every=base_every)
        for i in range(n_firings):
            active = rng.random(slots) < 0.5
            _append_step(slab, lengths, active, new_tokens, rng)

            t0 = time.perf_counter()
            store.publish("kv_pages", i, slab)
            delta_s += time.perf_counter() - t0

            t0 = time.perf_counter()
            for arr in slab.values():
                blob, _ = codecs.encode(arr, "zlib",
                                        pool=codecs.codec_pool())
                zlib_stored += len(blob)
            zlib_s += time.perf_counter() - t0

            if i == mid:
                mid_snapshot = {k: a.copy() for k, a in slab.items()}

        st = store.stats("kv_pages")
        # restore through the chain from a FRESH store over the same dir:
        # newest snapshot and a published mid-chain prefix, bit-identical
        reader = SnapshotStore(d, base_every=base_every)
        _, restored = reader.restore("kv_pages", template=slab)
        for key, arr in slab.items():
            np.testing.assert_array_equal(restored[key], arr)
        _, restored_mid = reader.restore("kv_pages", upto=mid,
                                         template=slab)
        for key, arr in mid_snapshot.items():
            np.testing.assert_array_equal(restored_mid[key], arr)

    raw_total = st["raw_bytes"]
    delta_x = st["effective_compression_x"]
    zlib_x = raw_total / zlib_stored
    win = delta_x / zlib_x

    common.row("snapshot/delta/publish", delta_s / n_firings * 1e6,
               f"measured;{delta_x:.1f}x;chain_depth={st['chain_depth']}")
    common.row("snapshot/zlib_full/publish", zlib_s / n_firings * 1e6,
               f"measured;{zlib_x:.1f}x")
    common.row("snapshot/delta_over_zlib_ratio", 0.0, f"{win:.2f}x")

    # acceptance: the delta chain's effective ratio must beat compressing
    # the full slab every firing — by >= 2x on the full workload (the
    # tracked number), and never lose even in the small quick/CI config
    floor = 1.0 if quick else 2.0
    assert win >= floor, (
        f"delta effective ratio only {win:.2f}x plain zlib "
        f"(want >= {floor}x): delta {delta_x:.2f}x vs zlib {zlib_x:.2f}x")

    return {
        "slab_mb": raw_mb,
        "n_firings": n_firings,
        "base_every": base_every,
        "delta_effective_x": delta_x,
        "zlib_effective_x": zlib_x,
        "delta_over_zlib": win,
        "delta_publish_us": delta_s / n_firings * 1e6,
        "zlib_publish_us": zlib_s / n_firings * 1e6,
        "stored_bytes_delta": st["stored_bytes"],
        "stored_bytes_zlib": zlib_stored,
        "frames": {"bases": st["bases"], "deltas": st["deltas"],
                   "noops": st["noops"]},
        "quick": quick,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; CI smoke)")
    ap.add_argument("--out", default=None,
                    help="write the metrics dict as JSON to this path")
    args = ap.parse_args()
    m = run(quick=not args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {os.path.abspath(args.out)}")
