"""Fault recovery: elastic-restore latency and fault-preset overhead.

Two measurements, both tied to the elastic-fault-tolerance arc:

  * **elastic vs full blocking restore.** After a host failure the naive
    recovery path restores the v2 shard checkpoint onto the *original*
    mesh (blocking on placements for devices that no longer exist in a
    real deployment) and then re-shards the whole tree onto the survivors.
    ``Session.restore(elastic=True)`` instead plans the shrunken mesh with
    ``plan_elastic_remesh`` and re-places leaves under it in one read —
    the TP-shard merge is implicit because v2 leaves are stored logically
    complete. This part needs a multi-device platform, so it re-execs in a
    subprocess with ``--xla_force_host_platform_device_count=8`` (the same
    pattern as the kill-point test; the in-process device count must stay
    untouched for the rest of the suite).

  * **fault-preset steady-state overhead.** The same jitted step loop with
    and without the ``fault`` task (sync, every=1: heartbeat + EWMA +
    mitigation evaluation per step). The acceptance gate is < 2 % of step
    time on the no-failure path (full mode; quick mode only records).

The metrics dict lands in ``BENCH_runtime.json`` under ``fault`` on
``--full`` runs of ``benchmarks.run``. CI smoke-runs quick mode.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_CHILD_ENV = "REPRO_FAULT_BENCH_CHILD"


# ---------------------------------------------------------------------------
# child: restore comparison on a multi-device platform
# ---------------------------------------------------------------------------

def _child_restore_bench(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import Session

    n_leaves = 4 if quick else 8
    dim = (256, 1024) if quick else (1024, 2048)

    state = {f"w{i}": jnp.asarray(
        np.random.RandomState(i).rand(*dim).astype(np.float32))
        for i in range(n_leaves)}
    template = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in state.items()}

    mesh_full = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))

    def shardings_for(mesh):
        return {k: NamedSharding(mesh, P(None, "model"))
                for k in template}

    ckpt_dir = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                            f"repro_fault_bench_{os.getpid()}")
    plan = {"streams": ["state"], "tasks": {
        "checkpoint": {"stream": "state", "preset": "checkpoint",
                       "every": 1, "placement": "sync",
                       "options": {"directory": ckpt_dir}}}}
    with Session(plan) as s:
        s.set_checkpoint_meta(mesh=mesh_full)
        s.emit("state", 0, state)

    # full blocking restore: read onto the ORIGINAL mesh, then re-shard
    # the whole tree onto the survivors' mesh (the naive recovery path)
    survivors = list(jax.devices()[:2])
    with Session(plan) as s:
        t0 = time.perf_counter()
        _, st_full = s.restore(template, shardings=shardings_for(mesh_full))
        _, rm = _elastic(s, template, survivors, shardings_for,
                         plan_only=True)
        st_moved = jax.device_put(st_full, shardings_for(rm.mesh))
        jax.block_until_ready(st_moved)
        t_full = time.perf_counter() - t0

    # elastic restore: one read, re-placed directly under the shrunken mesh
    with Session(plan) as s:
        t0 = time.perf_counter()
        _, st_el = _elastic(s, template, survivors, shardings_for)
        jax.block_until_ready(st_el)
        t_elastic = time.perf_counter() - t0
        rm = s.remesh

    for k in template:
        np.testing.assert_array_equal(np.asarray(st_el[k]),
                                      np.asarray(st_moved[k]))

    import shutil
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    raw_mb = sum(v.size * 4 for v in state.values()) / 1e6
    return {"full_restore_s": t_full, "elastic_restore_s": t_elastic,
            "restore_speedup": t_full / t_elastic,
            "new_shape": list(rm.plan.new_shape),
            "merge_factor": rm.plan.model_merge_factor,
            "state_mb": raw_mb}


def _elastic(session, template, survivors, shardings_for, plan_only=False):
    if plan_only:
        # resolve the remesh geometry without paying a second read
        import jax
        from repro.distributed.fault import plan_elastic_remesh
        import numpy as np
        meta = session.checkpoint.read_meta()
        plan = plan_elastic_remesh(tuple(meta["mesh"]["shape"]),
                                   tuple(meta["mesh"]["axes"]),
                                   len(survivors))
        mesh = jax.sharding.Mesh(
            np.asarray(survivors[:plan.new_device_count],
                       dtype=object).reshape(plan.new_shape),
            plan.axis_names)

        class _RM:
            pass

        rm = _RM()
        rm.mesh = mesh
        rm.plan = plan
        return None, rm
    step, st = session.restore(template, elastic=True, devices=survivors,
                               make_shardings=shardings_for)
    return step, st


def _spawn_child(quick: bool) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env[_CHILD_ENV] = "1"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"), repo,
                    env.get("PYTHONPATH", "")] if p)
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__)]
        + (["--quick"] if quick else []),
        env=env, capture_output=True, text=True, timeout=540)
    if proc.returncode != 0:
        raise RuntimeError(f"fault bench child failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# in-process: fault-preset steady-state overhead
# ---------------------------------------------------------------------------

def _overhead_bench(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Session

    # the step must be training-sized (a few ms) for the 2% gate to mean
    # anything — the preset's absolute cost is tens of microseconds
    steps = 60 if quick else 300
    batch, dim = (64, 512) if quick else (128, 1024)
    w = jnp.asarray(np.random.RandomState(0).rand(dim, dim)
                    .astype(np.float32) / dim)

    @jax.jit
    def step_fn(x):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    def drive(plan, emit_health):
        x = jnp.ones((batch, dim), jnp.float32)
        times = []
        with Session(plan) as session:
            for i in range(steps + 10):
                t0 = time.perf_counter()
                x = step_fn(x)
                jax.block_until_ready(x)
                dt = time.perf_counter() - t0
                if emit_health:
                    session.emit("health", i, {"host": 0, "step_s": dt})
                if i >= 10:                     # warmup excluded
                    times.append(time.perf_counter() - t0)
        return float(np.median(times))

    base_plan = {"streams": [], "tasks": {}}
    fault_plan = {"streams": ["health"], "tasks": {
        "fault": {"stream": "health", "preset": "fault", "every": 1,
                  "placement": "sync", "pipelined": False,
                  "options": {"hosts": [0], "grace_s": 30.0}}}}
    base_s = drive(base_plan, emit_health=False)
    fault_s = drive(fault_plan, emit_health=True)
    overhead = (fault_s - base_s) / base_s
    return {"base_step_s": base_s, "fault_step_s": fault_s,
            "overhead_frac": overhead, "steps": steps}


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def run(quick: bool = True) -> dict:
    out = _spawn_child(quick)
    out.update(_overhead_bench(quick))
    print(f"fault.full_restore,{out['full_restore_s'] * 1e6:.0f},"
          f"{out['state_mb']:.0f}MB")
    print(f"fault.elastic_restore,{out['elastic_restore_s'] * 1e6:.0f},"
          f"speedup={out['restore_speedup']:.2f}x "
          f"shape={out['new_shape']} f={out['merge_factor']}")
    print(f"fault.preset_overhead,{out['fault_step_s'] * 1e6:.0f},"
          f"overhead={out['overhead_frac'] * 100:.2f}%")
    if not quick:
        assert out["overhead_frac"] < 0.02, (
            f"fault preset costs {out['overhead_frac'] * 100:.2f}% of step "
            "time (gate: < 2%)")
    return out


if __name__ == "__main__":
    if os.environ.get(_CHILD_ENV) == "1":
        print(json.dumps(_child_restore_bench("--quick" in sys.argv)))
    else:
        run(quick="--quick" in sys.argv)
