"""Fig. 8 (F4): HYBRID compression — lossy on device, async lossless.

REAL head-to-head at equal resources: the hybrid hand-off ships the int8
spectral residue (~25x smaller than raw f32), and its lossless stage (on the
small payload) hides behind the device. Sync-on-raw stalls. Validates F4:
hybrid beats fully-synchronous compression.
"""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks import common
from repro.core.insitu import InSituMode
from repro.kernels import ops


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)
    c = ops.spectral_compress(field, 1e-2)
    q = np.asarray(c.q).reshape(-1)

    def lossless(step, payload):
        return len(zlib.compress(payload.tobytes(), 6))

    t_raw = common.calibrate_task(lossless, field)
    t_q = common.calibrate_task(lossless, q)
    n, every = (12, 3) if quick else (40, 5)
    step_s = max(t_raw * 0.8, 0.005)

    sync_raw = common.run_modes(lossless, field, n_steps=n, step_s=step_s,
                                every=every, p_i=1,
                                modes=(InSituMode.SYNC,))["sync"]
    # HYBRID placement: async host scheduling over the device-reduced
    # payload (the residue is precomputed once — on hardware the device
    # stage is compiled into the step and costs no host time)
    hybrid = common.run_modes(lossless, q, n_steps=n, step_s=step_s,
                              every=every, p_i=1,
                              modes=(InSituMode.HYBRID,))["hybrid"]
    common.row("fig08/sync_raw/wall", sync_raw["wall_s"] * 1e6 / n,
               "measured")
    common.row("fig08/hybrid/wall", hybrid["wall_s"] * 1e6 / n,
               f"measured;payload_shrink={field.nbytes / q.nbytes:.1f}x;"
               f"t_lossless {t_raw * 1e3:.1f}ms->{t_q * 1e3:.1f}ms")
    assert hybrid["wall_s"] < sync_raw["wall_s"]      # F4
    assert t_q < t_raw                                 # smaller payload

    comp = common.amdahl_from_calibration(t_q, sigma=0.02)
    fires = n // every
    out = []
    for cores in (4, 8, 16, 28, 64):
        tot = max(n * step_s, fires * comp.predict(cores)) \
            + comp.predict(cores)
        common.row(f"fig08/hybrid_cores{cores}", tot * 1e6 / n, "model")
        out.append(tot)
    assert all(a >= b - 1e-12 for a, b in zip(out, out[1:]))
    return {"sync_raw": sync_raw, "hybrid": hybrid}


if __name__ == "__main__":
    run()
