"""Checkpoint I/O: v2 packed-shard layout vs the v1 file-per-leaf layout.

The paper's core argument is that checkpointing dominates full-workflow
time because IO bandwidth and storage lag compute. The v1 layout spent that
budget on *metadata*: one open/write/fsync per leaf, and a serial leaf walk
inside one encode worker. The v2 layout packs every framed blob into a few
large ``shard_NNN.bin`` files bound by the manifest's offset table
(openPMD/ADIOS2-style aggregation) and fans the encode out per leaf across
the runtime pool; restore readaheads each shard once and fans per-leaf
decode out on the codec pool.

This benchmark measures, on a many-small-leaf tree (the MoE-expert /
per-layer-moment shape):

  * save and restore throughput (MB/s of raw tensor bytes) for both layouts
  * the number of ``open`` calls each issues — v2's must be independent of
    leaf count (asserted: opens for a 64-leaf tree == opens for a 16-leaf
    tree, and far below the leaf count)

Emits CSV rows like every benchmark; the metrics dict lands in
``BENCH_runtime.json`` under ``checkpoint_io`` on ``--full`` runs of
``benchmarks.run``. CI smoke-runs this module in quick mode.
"""
from __future__ import annotations

import builtins
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import InSituMode


class OpenCounter:
    """Counts ``builtins.open`` calls (the per-leaf syscall pressure)."""

    def __init__(self) -> None:
        self.count = 0

    def __enter__(self) -> "OpenCounter":
        self._orig = builtins.open

        def counting(*args, **kwargs):
            self.count += 1
            return self._orig(*args, **kwargs)

        builtins.open = counting
        return self

    def __exit__(self, *exc) -> None:
        builtins.open = self._orig


def _tree(n_leaves: int, elems: int) -> dict[str, np.ndarray]:
    """Many-small-leaf state: n_leaves float32 leaves of elems elements."""
    return {f"layer_{i:03d}": common.turbulence_field(elems, seed=i)
            for i in range(n_leaves)}


def _measure(tree: dict, directory: str, *, fmt: int, leaf_parallel: bool,
             repeats: int) -> dict:
    """Save/restore the tree through a manager; best-of-``repeats`` timings.

    The v1 baseline also runs with ``chunk_parallel=False``: on sub-1MiB
    leaves the chunk pool never engages, so that config matches the
    pre-shard-layout scheduling (serial leaf walk, per-leaf files, serial
    decode) without keeping dead code around. One deliberate difference:
    per-leaf files are now fsynced (the durability bugfix applies to the v1
    layout too — the pre-fix v1 skipped fsync, which was faster but could
    publish a manifest pointing at unwritten bytes), so the comparison is
    durable-v1 vs durable-v2: the per-leaf fsync cost is intrinsic to a
    file-per-leaf layout once writes are actually durable.

    The codec is ``none``: this benchmark isolates the *IO layout* (opens,
    fsyncs, readahead), so the measured MB/s is an IO number. Compression
    throughput is tracked separately (tab2_codecs, handoff_overlap), and a
    CPU-bound encode would only add scheduler noise to the layout signal.
    """
    mgr = CheckpointManager(CheckpointConfig(
        directory, mode=InSituMode.SYNC, every=1, keep=1,
        lossless="none", lossy_moments=False, format=fmt,
        leaf_parallel=leaf_parallel, chunk_parallel=leaf_parallel))
    raw_mb = sum(a.nbytes for a in tree.values()) / 1e6
    save_s, save_opens = float("inf"), 0
    for r in range(repeats):
        with OpenCounter() as oc:
            t0 = time.perf_counter()
            mgr.save(r + 1, tree)
            save_s = min(save_s, time.perf_counter() - t0)
        save_opens = oc.count
    restore_s = float("inf")
    for _ in range(repeats):
        with OpenCounter() as oc:
            t0 = time.perf_counter()
            step, restored = mgr.restore(tree)
            restore_s = min(restore_s, time.perf_counter() - t0)
        restore_opens = oc.count
    mgr.finish()
    for key, arr in tree.items():            # restores bit-identically
        np.testing.assert_array_equal(np.asarray(restored[key]), arr)
    return {"save_mb_s": raw_mb / save_s, "restore_mb_s": raw_mb / restore_s,
            "save_s": save_s, "restore_s": restore_s,
            "save_opens": save_opens, "restore_opens": restore_opens,
            "raw_mb": raw_mb}


def run(quick: bool = True) -> dict:
    # full mode scales the *leaf count* (the benchmark is about many-small-
    # leaf metadata pressure), never the leaf size: bigger leaves shift the
    # comparison toward compute and away from what v2 changes
    n_leaves, elems = (64 if quick else 256), 1 << 15       # 128 KiB per leaf
    repeats = 2 if quick else 3
    tree = _tree(n_leaves, elems)
    layouts = {"v1": dict(fmt=1, leaf_parallel=False),
               "v2": dict(fmt=2, leaf_parallel=True)}
    res = {}
    for name, kw in layouts.items():
        with tempfile.TemporaryDirectory() as d:
            res[name] = _measure(tree, d, repeats=repeats, **kw)

    # leaf-count independence: the same v2 config over a 4x smaller tree
    # must issue exactly as many opens (shards + manifest, never per leaf)
    with tempfile.TemporaryDirectory() as d:
        small = _measure(_tree(16, elems), d, repeats=1,
                         **layouts["v2"])

    for name, r in res.items():
        common.row(f"ckpt_io/{name}/save", r["save_s"] * 1e6,
                   f"measured;{r['save_mb_s']:.1f}MB/s;opens={r['save_opens']}")
        common.row(f"ckpt_io/{name}/restore", r["restore_s"] * 1e6,
                   f"measured;{r['restore_mb_s']:.1f}MB/s;"
                   f"opens={r['restore_opens']}")

    speedup = ((res["v1"]["save_s"] + res["v1"]["restore_s"])
               / max(res["v2"]["save_s"] + res["v2"]["restore_s"], 1e-9))
    common.row("ckpt_io/v2_over_v1_speedup", 0.0, f"{speedup:.2f}x")

    # acceptance: packed shards decouple file opens from the tree's shape
    assert res["v2"]["save_opens"] < n_leaves, (
        f"v2 save opened {res['v2']['save_opens']} files for {n_leaves} "
        "leaves — the shard layout must not scale opens with leaf count")
    assert res["v2"]["save_opens"] == small["save_opens"], (
        f"v2 save opens depend on leaf count: {res['v2']['save_opens']} "
        f"({n_leaves} leaves) vs {small['save_opens']} (16 leaves)")
    assert res["v2"]["restore_opens"] == small["restore_opens"], (
        f"v2 restore opens depend on leaf count: {res['v2']['restore_opens']}"
        f" ({n_leaves} leaves) vs {small['restore_opens']} (16 leaves)")
    assert res["v1"]["save_opens"] >= n_leaves   # the baseline really is v1
    # acceptance: aggregated+parallel save/restore beats the per-leaf walk
    assert speedup >= 2.0, (
        f"v2 save+restore only {speedup:.2f}x over v1 (want >= 2x)")

    return {"n_leaves": n_leaves, "leaf_bytes": elems * 4,
            "v1": res["v1"], "v2": res["v2"],
            "save_restore_speedup": speedup, "quick": quick}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write the metrics dict as JSON to this path")
    args = ap.parse_args()
    m = run(quick=not args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {os.path.abspath(args.out)}")
