"""Streaming transport vs file staging: throughput and non-blocking-ness.

The openPMD/ADIOS2 argument (PAPERS.md) for replacing file-based staging
with streaming pipelines only holds if (a) the wire path is not the
bottleneck and (b) a slow consumer cannot stall the producing loop. This
benchmark measures both for ``repro.core.transport``:

  * **throughput** — the same framed payloads through a ``FileSink``
    (atomic tmp -> fsync -> rename per frame, the file-staging baseline)
    vs a ``StreamSink`` over localhost TCP to a draining ``StreamSource``.
    Gate: stream within 2x of file throughput (it is usually far faster —
    the file path pays two fsyncs per frame).
  * **slow consumer, drop policy** — an async in-situ task whose sink
    streams to a consumer that drains *slower than the producer fires*,
    under ``backpressure="drop"``. The bounded staging ring sheds firings
    instead of blocking, so the train loop's wall clock must stay at the
    device time: gate is < 10% stall overhead, with the shed firings
    counted (dropped + degraded frames are *visible*, never silent).

The metrics dict lands in ``BENCH_runtime.json`` under ``stream_sink`` on
``--full`` runs of ``benchmarks.run``.
"""
from __future__ import annotations

import json
import socket
import tempfile
import threading
import time

import numpy as np

from benchmarks import common
from repro.core import transport
from repro.core.transport import FileSink, StreamSink, StreamSource


def _drain(source: StreamSource, stop: threading.Event,
           delay_s: float = 0.0, counter: list = None) -> None:
    while not stop.is_set():
        frame = source.recv_frame(timeout=0.2)
        if frame is None:
            continue
        if counter is not None:
            counter.append(frame.seq)
        if delay_s:
            time.sleep(delay_s)


def _throughput(quick: bool) -> dict:
    n_frames = 16 if quick else 64
    payload = {"slab": common.turbulence_field(1 << (18 if quick else 20))}

    with tempfile.TemporaryDirectory() as d:
        sink = FileSink(d, stream="bench")
        t0 = time.perf_counter()
        for i in range(n_frames):
            sink.write(i, payload)
        sink.close()
        file_s = time.perf_counter() - t0
        file_mb = sink.bytes_written / 1e6

    source = StreamSource(port=0)
    stop = threading.Event()
    drained: list = []
    th = threading.Thread(target=_drain, args=(source, stop, 0.0, drained),
                          daemon=True)
    th.start()
    sink = transport.connect(source.address, stream="bench")
    t0 = time.perf_counter()
    for i in range(n_frames):
        sink.write(i, payload)
    sink.flush()
    stream_s = time.perf_counter() - t0
    stream_mb = sink.bytes_written / 1e6
    deadline = time.time() + 10
    while len(drained) < n_frames and time.time() < deadline:
        time.sleep(0.01)
    sink.close()
    stop.set()
    th.join(timeout=2)
    source.close()
    assert len(drained) == n_frames, \
        f"consumer drained {len(drained)}/{n_frames} frames"

    file_mb_s = file_mb / file_s
    stream_mb_s = stream_mb / stream_s
    common.row("stream_sink/file_mb_s", file_s / n_frames * 1e6,
               f"{file_mb_s:.0f}MB/s")
    common.row("stream_sink/stream_mb_s", stream_s / n_frames * 1e6,
               f"{stream_mb_s:.0f}MB/s")
    return {"n_frames": n_frames, "frame_mb": file_mb / n_frames,
            "file_mb_s": file_mb_s, "stream_mb_s": stream_mb_s,
            "stream_vs_file_x": stream_mb_s / file_mb_s}


def _slow_consumer(quick: bool) -> dict:
    """Async task streaming to a consumer slower than the firing cadence,
    drop policy: the *loop body* must run at device speed, shedding
    visibly. (End-of-run drain is measured separately — waiting for
    in-flight frames at shutdown is correct, stalling the loop is not.)"""
    n_steps = 24 if quick else 80
    step_s = 0.01
    consumer_delay_s = 4 * step_s          # drains 4x slower than it fires
    payload = common.turbulence_field(1 << 16)

    source = StreamSource(port=0, check_gaps=False)
    stop = threading.Event()
    drained: list = []
    th = threading.Thread(
        target=_drain, args=(source, stop, consumer_delay_s, drained),
        daemon=True)
    th.start()
    sink = transport.connect(source.address, stream="x")

    plan = common.InSituPlan(
        streams=["x"],
        tasks=[common.TaskSpec(name="t", stream="x", sink=sink,
                               placement=common.InSituMode.ASYNC,
                               trigger=common.Every(1),
                               backpressure="drop")],
        workers=1, staging_capacity=2)
    session = common.Session(plan)
    dev = common.DeviceSim(step_s)
    with session:
        t0 = time.perf_counter()
        for i in range(n_steps):
            with session.step_span(i):
                dev()
            session.emit("x", i, lambda: payload)
        loop_s = time.perf_counter() - t0
        t0 = time.perf_counter()
    drain_s = time.perf_counter() - t0     # context exit = flush workers
    rep = session.report()
    sink.close()
    stop.set()
    th.join(timeout=2)
    source.close()

    ideal_s = n_steps * step_s
    stall_frac = max(0.0, loop_s - ideal_s) / ideal_s
    shed = rep.get("drops", {}).get("t", 0)
    common.row("stream_sink/slow_consumer_stall",
               stall_frac * ideal_s / n_steps * 1e6,
               f"stall_frac={stall_frac:.3f} shed={shed}")
    return {"n_steps": n_steps, "device_step_s": step_s,
            "consumer_delay_s": consumer_delay_s,
            "loop_s": loop_s, "drain_s": drain_s, "ideal_s": ideal_s,
            "stall_frac": stall_frac,
            "fired": rep["n_results"], "shed": shed,
            "consumer_got": len(drained)}


def run(quick: bool = True) -> dict:
    tp = _throughput(quick)
    slow = _slow_consumer(quick)

    # gates: the wire must not be the bottleneck, and a slow consumer
    # must cost the train loop (almost) nothing under the drop policy
    assert tp["stream_vs_file_x"] >= 0.5, (
        f"stream throughput fell below half of file staging: "
        f"{tp['stream_mb_s']:.0f} vs {tp['file_mb_s']:.0f} MB/s")
    limit = 0.25 if quick else 0.10   # CI-machine jitter headroom in quick
    assert slow["stall_frac"] <= limit, (
        f"slow consumer stalled the loop: loop {slow['loop_s']:.3f}s vs "
        f"ideal {slow['ideal_s']:.3f}s (stall_frac {slow['stall_frac']:.3f})")
    assert slow["shed"] + slow["consumer_got"] >= slow["fired"] or \
        slow["consumer_got"] > 0, "shedding happened but nothing arrived"

    return {"quick": quick, "throughput": tp, "slow_consumer": slow}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    m = run(quick=not args.full)
    print(json.dumps(m, indent=2, sort_keys=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
