"""Figs. 10-12: the QE case — checkpoint (restart-file) compression.

The second workload: a REAL training-state pytree (smollm smoke params +
moments) checkpointed through the CheckpointManager in SYNC vs ASYNC mode
while a sleep-device trains. Reproduces:
  Fig. 10/11 — mode behaviour at one node (REAL): async hides the
               compression+write, sync stalls.
  Fig. 12 (F6) — across nodes the per-rank state shard shrinks; when the
               task becomes cheap, SYNC wins because async's hand-off/tail
               overhead is no longer amortized (model from real calibration).
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks import common
from repro import optim
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core.insitu import InSituMode


def _state(scale: int = 1):
    from repro.configs import base
    from repro.models import params as P, transformer
    cfg = base.get("smollm-135m", smoke=True)
    params = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    st = optim.init(params, optim.AdamWConfig())
    return {"params": params, "mu": st.mu, "nu": st.nu}


def _run_mode(mode, state, n, every, step_s):
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(CheckpointConfig(
        d, mode=mode, every=every, keep=2, p_i=1, staging_capacity=1))
    dev = common.DeviceSim(step_s)
    t0 = time.perf_counter()
    for i in range(n):
        dev()
        mgr.maybe_save(i, state)
    mgr.wait_idle()
    wall = time.perf_counter() - t0
    mgr.finish()
    rep = mgr.telemetry.step_overlap_report()
    rep["wall_s"] = wall
    rep["saved"] = len(mgr.reports)
    rep["ratio"] = mgr.reports[-1].ratio if mgr.reports else 0.0
    return rep


def run(quick: bool = True) -> dict:
    state = _state()
    n, every = (8, 2) if quick else (30, 5)
    # calibrate one sync save to size the device step
    t0 = time.perf_counter()
    _run_mode(InSituMode.SYNC, state, 1, 1, 0.0)
    t_save = time.perf_counter() - t0
    step_s = max(0.8 * t_save, 0.01)

    res = {}
    for mode in (InSituMode.SYNC, InSituMode.ASYNC):
        r = _run_mode(mode, state, n, every, step_s)
        res[mode.value] = r
        common.row(f"fig10_11/{mode.value}/wall", r["wall_s"] * 1e6 / n,
                   f"measured;saved={r['saved']};CR={r['ratio']:.3f}")
    assert res["async"]["wall_s"] < res["sync"]["wall_s"]   # 1 node: async
    assert res["sync"]["sync_stall_s"] > 0

    # Fig. 12 / F6: across nodes the per-rank state shard shrinks ~1/nodes,
    # so the compression becomes cheap; meanwhile the async staging transfer
    # (the paper: "the communication overhead in the asynchronous approach
    # increases" — MPI staging crosses more node boundaries) GROWS with the
    # node count. Sync writes locally and pays no staging.
    handoff_s = max(res["async"]["handoff_s"] / max(res["async"]["saved"], 1),
                    0.06 * t_save)   # ADIOS2-staging floor (paper's QE MPMD)
    fires = n // every
    cross = None
    out = {"nodes": [], "sync": [], "async": []}
    for nodes in (1, 2, 3, 4, 5):
        t_task = t_save / nodes            # per-rank shard shrinks
        stage = handoff_s * nodes          # staging overhead grows (paper)
        app = n * step_s
        sync = app + fires * t_task
        asyn = max(app, fires * t_task) + t_task + fires * stage
        common.row(f"fig12/nodes{nodes}/sync", sync * 1e6 / n, "model")
        common.row(f"fig12/nodes{nodes}/async", asyn * 1e6 / n, "model")
        out["nodes"].append(nodes)
        out["sync"].append(sync)
        out["async"].append(asyn)
        if cross is None and sync <= asyn:
            cross = nodes
    # F6: async wins at 1 node; sync catches up as the task gets cheap
    assert out["async"][0] < out["sync"][0]
    assert cross is not None, "sync never catches up — F6 not reproduced"
    common.row("fig12/f6_crossover_nodes", float(cross) * 1e6, "derived")
    return {"modes": res, "scaling": out, "crossover": cross}


if __name__ == "__main__":
    run()
