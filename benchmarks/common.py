"""Shared benchmark harness.

Reproduction methodology on this container (1 CPU core, no accelerator):

  * The *device* (GPU in the paper / TPU here) is represented by a
    ``DeviceSim`` step that sleeps: a dispatched accelerator step occupies
    no host CPU, exactly like the paper's GPU phases. Host-side in-situ
    work (real numpy / zlib / bz2, GIL-released) then genuinely overlaps
    with it — the sync-stall vs async-overlap vs hand-off attribution is a
    REAL measurement.
  * The *p_o / p_i allocation sweeps* (paper Fig. 2/4, Table I) need
    multiple cores to measure directly; we calibrate the REAL single-thread
    task cost, then extend with the Amdahl model of core/allocator.py
    (serial fractions: image-generation-like analytics sigma=0.15 — the
    paper's "worse scalability ... because of collective communication";
    compression sigma=0.02 — embarrassingly parallel per-tensor). Sweep
    rows are labelled ``model`` vs ``measured`` accordingly.

Every benchmark prints CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.core import InSituMode, Telemetry
from repro.core.allocator import AmdahlModel
from repro.insitu import Adaptive, Every, InSituPlan, Session, TaskSpec

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def flush_rows() -> None:
    ROWS.clear()


@dataclass
class DeviceSim:
    """An accelerator step: host-idle wait (the GPU/TPU is busy elsewhere)."""
    step_s: float

    def __call__(self) -> None:
        time.sleep(self.step_s)


def turbulence_field(n: int = 1 << 18, seed: int = 0) -> np.ndarray:
    """Smooth multi-scale field (TGV-flavoured) — the compressible payload."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 8 * np.pi, n)
    x = (np.sin(t) + 0.5 * np.sin(3.1 * t + 1.0) + 0.22 * np.sin(9.7 * t)
         + 0.08 * np.sin(31.4 * t) + 0.01 * rng.standard_normal(n))
    return x.astype(np.float32)


def run_modes(task_fn: Callable[[int, Any], Any], payload: np.ndarray, *,
              n_steps: int, step_s: float, every: int, p_i: int = 2,
              modes=(InSituMode.SYNC, InSituMode.ASYNC),
              shards: int = 1, capacity: int = 4,
              backpressure: str = "block") -> dict[str, dict]:
    """Run the same declared plan under each placement policy; timings."""
    out = {}
    for mode in modes:
        trigger = (Adaptive(every) if backpressure == "adapt"
                   else Every(every))
        plan = InSituPlan(
            streams=["x"],
            tasks=[TaskSpec(name="t", stream="x", sink=task_fn,
                            placement=mode, trigger=trigger,
                            shards=shards,
                            backpressure=(None if backpressure == "adapt"
                                          else backpressure))],
            workers=p_i, staging_capacity=capacity)
        session = Session(plan)
        dev = DeviceSim(step_s)

        def app_step(i):
            dev()
            return {"x": lambda: payload}

        t0 = time.perf_counter()
        session.run(n_steps, app_step)
        wall = time.perf_counter() - t0
        rep = session.report()
        rep["wall_s"] = wall
        rep["results"] = len(session.results)
        assert not session.errors(), session.errors()[:1]
        out[mode.value] = rep
    return out


def calibrate_task(task_fn: Callable[[int, Any], Any], payload: Any,
                   repeats: int = 3) -> float:
    """Real single-thread seconds per firing."""
    task_fn(0, payload)  # warmup
    t0 = time.perf_counter()
    for i in range(repeats):
        task_fn(i, payload)
    return (time.perf_counter() - t0) / repeats


def amdahl_from_calibration(t1: float, sigma: float) -> AmdahlModel:
    """Task-time model t(p) = t1*(sigma + (1-sigma)/p) from a real t1."""
    m = AmdahlModel(serial=t1 * sigma, parallel=t1 * (1 - sigma))
    m.observations.extend([(1, t1)])
    return m
