"""Benchmark entrypoint: one function per paper table/figure.

``python -m benchmarks.run [--full]`` prints CSV rows name,us_per_call,derived.
The ``runtime`` bench additionally emits ``BENCH_runtime.json`` — the perf
artifact (critical-path hand-off, overlap fraction, codec MB/s) tracked
across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger payloads / more steps")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (checkpoint_io, fault_recovery,
                            fig02_cpu_sync_vs_async,
                            fig03_sync_cores, fig04_async_allocation,
                            fig05_insitu_frequency, fig06_scaling_nodes,
                            fig07_sync_compression, fig08_hybrid_compression,
                            fig09_compression_scaling,
                            fig10_12_qe_checkpoint, handoff_overlap,
                            kernel_roofline, lossy_ratio, prefix_sharing,
                            roofline, serving_throughput, snapshot_delta,
                            stream_sink, tab2_codecs)

    benches = [
        ("fig02", fig02_cpu_sync_vs_async.run),
        ("fig03", fig03_sync_cores.run),
        ("fig04", fig04_async_allocation.run),
        ("fig05", fig05_insitu_frequency.run),
        ("fig06", fig06_scaling_nodes.run),
        ("fig07", fig07_sync_compression.run),
        ("fig08", fig08_hybrid_compression.run),
        ("fig09", fig09_compression_scaling.run),
        ("fig10_12", fig10_12_qe_checkpoint.run),
        ("tab2", tab2_codecs.run),
        ("lossy_ratio", lossy_ratio.run),
        ("roofline", roofline.run),
        ("kernel_roofline", kernel_roofline.run),
        ("runtime", handoff_overlap.run),
        ("checkpoint_io", checkpoint_io.run),
        ("snapshot_delta", snapshot_delta.run),
        ("serving", serving_throughput.run),
        ("prefix_sharing", prefix_sharing.run),
        ("fault", fault_recovery.run),
        ("stream_sink", stream_sink.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    results: dict[str, dict] = {}
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            results[name] = fn(quick=quick)
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            print(f"# {name} FAILED: {e}")
    tracked = ("runtime", "checkpoint_io", "snapshot_delta", "serving",
               "prefix_sharing", "fault", "stream_sink", "kernel_roofline")
    if not quick and not args.only and "runtime" in results:
        # only an unfiltered --full run refreshes the tracked perf
        # artifact (quick-mode numbers are not comparable across PRs, and
        # a --only subset would silently drop another bench's tracked
        # section). Sections whose bench failed this run keep their
        # previously recorded numbers instead of blocking the whole
        # refresh — one flaky perf gate must not silently drop every
        # other bench's fresh numbers — and are named as stale below;
        # the nonzero exit still reports the failures themselves.
        try:
            with open(handoff_overlap.ARTIFACT) as f:
                artifact = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            artifact = {}
        artifact.update(results["runtime"])
        stale = []
        for name in tracked:
            if name == "runtime":
                continue
            if name in results:
                artifact[name] = results[name]
            elif name in artifact:
                stale.append(name)
        handoff_overlap.write_artifact(artifact)
        note = f" (kept stale: {', '.join(stale)})" if stale else ""
        print(f"# wrote {handoff_overlap.ARTIFACT}{note}")
    elif not quick and args.only:
        print(f"# --only filter active: {handoff_overlap.ARTIFACT} "
              f"not refreshed (needs an unfiltered --full run)")
    if failures:
        sys.exit(f"{len(failures)} benchmarks failed")


if __name__ == "__main__":
    main()
