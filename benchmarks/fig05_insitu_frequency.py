"""Fig. 5 (F3): raise the in-situ frequency until the task dominates.

REAL measurement: device=sleep, task=real analytics. At every=5 the async
task hides behind the device; at every=1 even all workers can't keep up —
the staging ring backpressures and the task side dominates total time.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import analysis
from repro.core.insitu import InSituMode


def task(step, payload):
    return analysis.tensor_summary("field", payload, step, work=3)


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 19)
    t1 = common.calibrate_task(task, field)
    # device step < task time: at every=5 the host keeps up (task CPU need
    # = t1/5 per step), at every=1 it cannot (t1 > step_s) — the F3 regime.
    step_s = t1 * 0.6
    n = 20 if quick else 60
    out = {}
    for every in (5, 1):
        res = common.run_modes(task, field, n_steps=n, step_s=step_s,
                               every=every, p_i=2,
                               modes=(InSituMode.ASYNC,), capacity=2)["async"]
        label = "low_freq" if every == 5 else "high_freq"
        common.row(f"fig05/{label}/wall", res["wall_s"] * 1e6 / n,
                   f"measured;bp_s={res['staging_backpressure_s']:.3f}")
        out[label] = res
    ideal = n * step_s
    # F3: at high frequency the in-situ task outgrows the host and dominates
    # the workflow; the producer visibly backpressures on the staging ring.
    # (margins allow for CPU contention on the shared single-core container)
    assert out["low_freq"]["wall_s"] < ideal * 1.6, \
        (out["low_freq"]["wall_s"], ideal)
    assert out["high_freq"]["wall_s"] > out["low_freq"]["wall_s"] * 1.1
    assert (out["high_freq"]["staging_backpressure_s"]
            >= out["low_freq"]["staging_backpressure_s"])

    # F3 mitigation (runtime 'adapt' policy): same pressure, but the
    # scheduler lengthens the task's effective firing period instead of
    # letting the producer stall indefinitely — starved down to 1 worker so
    # the ring pressure is sustained.
    adapted = common.run_modes(task, field, n_steps=n, step_s=step_s,
                               every=1, p_i=1,
                               modes=(InSituMode.ASYNC,), capacity=1,
                               backpressure="adapt")["async"]
    common.row("fig05/adapt/wall", adapted["wall_s"] * 1e6 / n,
               f"measured;effective_every={adapted['effective_every']['t']}")
    assert adapted["effective_every"]["t"] > 1     # the runtime backed off
    out["adapt"] = adapted
    return out


if __name__ == "__main__":
    run()
