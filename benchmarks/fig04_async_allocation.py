"""Fig. 4: async allocation sweep, three experiment groups (GPU nodes).

 (left)   vary app cores, task cores fixed  -> total ~flat (device-bound)
 (middle)  app cores fixed, vary task cores  -> total drops until task ≈ app,
           then flat
 (right)   equal cores both sides           -> drops then slight rise
Model-extrapolated from a REAL task calibration (1-core container).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import analysis


def task(step, payload):
    return analysis.tensor_summary("field", payload, step, work=2)


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)
    t1 = common.calibrate_task(task, field)
    img = common.amdahl_from_calibration(t1, sigma=0.15)
    steps, every = 2000, 50
    fires = steps // every
    device_total = steps * 0.6 * t1   # NEKO on 8 GPUs, device-side
    handoff = 0.01 * t1

    def total_async(p_task):
        app = device_total + fires * handoff
        tsk = fires * img.predict(p_task)
        return max(app, tsk) + img.predict(p_task)  # + non-overlapped tail

    out = {"left": [], "middle": [], "right": []}
    for p_app in (8, 16, 32, 48, 128):     # left: task cores fixed at 16
        t = total_async(16)
        common.row(f"fig04/left/app{p_app}", t * 1e6 / steps, "model")
        out["left"].append(t)
    for p_task in (8, 16, 32, 48, 128):    # middle: app cores fixed at 16
        t = total_async(p_task)
        common.row(f"fig04/mid/task{p_task}", t * 1e6 / steps, "model")
        out["middle"].append(t)
    for p in (8, 16, 24, 32, 72):          # right: equal split
        t = total_async(p)
        common.row(f"fig04/equal/p{p}", t * 1e6 / steps, "model")
        out["right"].append(t)
    # left group ~flat (same GPUs, same task cores)
    assert max(out["left"]) - min(out["left"]) < 1e-9
    # middle group monotone non-increasing, then flat at device bound
    assert all(a >= b - 1e-12 for a, b in zip(out["middle"], out["middle"][1:]))
    return out


if __name__ == "__main__":
    run()
