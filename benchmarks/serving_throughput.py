"""Serving throughput: paged continuous batching vs the dense-slot engine.

Both engines get the *same KV token budget* (``slots * max_len`` for the
dense baseline == ``(num_pages - 1) * page_size`` for the paged pool) and
the same seeded Poisson request stream with mixed prompt/output lengths.
The dense engine pays one full ``max_len`` stripe per request regardless of
its actual length, so concurrency is capped at ``slots`` and short requests
queue behind long ones; the paged engine reserves only each request's
``ceil((prompt + max_new) / page_size)`` pages, so the same memory serves
~4x the concurrent requests and admission happens the moment pages free up.

Measured per engine, over identical request streams:

  * decoded tokens/s (wall clock, prefill + decode + admission included),
  * batch occupancy (mean active requests / capacity),
  * admission latency p50/p99 in decode steps (arrival -> admitted).

Acceptance: paged tokens/s >= 2x dense on the full workload (the tracked
number in ``BENCH_runtime.json``'s ``serving`` section); the quick/CI
configuration gates >= 1x (paged must never lose). Both engines are greedy
and batch-deterministic, so total decoded tokens are identical — the
speedup is pure scheduling, not shorter outputs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common

# (prompt_len, max_new, weight): a short-dominated mix with rare long
# requests — the serving shape that makes fixed slots hurt, since the dense
# engine sizes every slot for the 64-token worst case while the typical
# request needs a single 16-token page.
SIZE_MIX = ((4, 12, 8), (8, 24, 3), (16, 48, 1))


def _make_requests(n: int, vocab: int, seed: int):
    """Mixed-length stream: weighted sizes cycle (so every jit variant is
    hit early) with the order shuffled deterministically."""
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    pattern = [(s, m) for s, m, w in SIZE_MIX for _ in range(w)]
    sizes = [pattern[i % len(pattern)] for i in range(n)]
    rng.shuffle(sizes)
    return [Request(i, rng.integers(0, vocab, size=s), max_new=m)
            for i, (s, m) in enumerate(sizes)]


def _arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    """Poisson arrival steps (cumulative exponential inter-arrivals)."""
    rng = np.random.default_rng(seed + 1)
    return np.floor(np.cumsum(rng.exponential(1.0 / rate, n))).astype(int)


def _drive(engine, requests, arrivals, *, capacity: int,
           max_steps: int = 20000) -> dict:
    """Feed the arrival process; admit greedily; decode while anyone is
    active. Returns wall time, occupancy, and per-request admit latency."""
    queue: list = []
    admit_step: dict[int, int] = {}
    occ = []
    i = 0
    step = 0
    t0 = time.perf_counter()
    while i < len(requests) or queue or any(
            a is not None for a in engine.active):
        while i < len(requests) and arrivals[i] <= step:
            queue.append(requests[i])
            i += 1
        while queue and engine.admit(queue[0]):
            admit_step[queue.pop(0).rid] = step
        if any(a is not None for a in engine.active):
            occ.append(sum(a is not None for a in engine.active))
            engine.step()
        step += 1
        if step > max_steps:
            raise RuntimeError(f"stream did not drain in {max_steps} steps")
    wall = time.perf_counter() - t0
    lat = np.array([admit_step[r.rid] - arrivals[r.rid] for r in requests],
                   float)
    tokens = sum(len(r.out) for r in requests)
    assert all(r.done for r in requests)
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tokens_per_s": tokens / wall,
        "occupancy": float(np.mean(occ) / capacity) if occ else 0.0,
        "admit_p50_steps": float(np.percentile(lat, 50)),
        "admit_p99_steps": float(np.percentile(lat, 99)),
        "decode_steps": len(occ),
    }


def run(quick: bool = True) -> dict:
    import jax

    from repro.configs import base
    from repro.models import params as P
    from repro.models import transformer
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.pages import PagedServingEngine

    arch = "smollm-135m"
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))

    slots, max_len, prompt_len, page_size = 4, 64, 16, 16
    budget_tokens = slots * max_len                  # equal-memory budget
    num_pages = budget_tokens // page_size + 1       # +1 scratch page
    max_reqs = 12
    n_requests = 36 if quick else 96
    rate = 2.0                                       # requests per step

    def dense():
        return ServingEngine(cfg, prm, slots=slots, prompt_len=prompt_len,
                             max_len=max_len)

    def paged():
        return PagedServingEngine(cfg, prm, num_pages=num_pages,
                                  page_size=page_size, max_reqs=max_reqs,
                                  prompt_len=prompt_len, max_len=max_len)

    arr = _arrivals(n_requests, rate, seed=7)
    results = {}
    for name, mk in (("dense", dense), ("paged", paged)):
        cap = slots if name == "dense" else max_reqs
        eng = mk()
        # untimed pass ON THE SAME INSTANCE (jit caches are per-engine):
        # compile every prefill/decode/insert variant the mix can hit, so
        # the timed run measures scheduling, not tracing. One request per
        # SIZE_MIX entry hits every (prompt length, page count) pair.
        warm = [Request(-1 - i, np.zeros(s, np.int64), max_new=m)
                for i, (s, m, _) in enumerate(SIZE_MIX)]
        eng.run(warm)
        results[name] = _drive(eng, _make_requests(n_requests,
                                                   cfg.vocab_size, seed=3),
                               arr, capacity=cap)

    d, p = results["dense"], results["paged"]
    assert d["tokens"] == p["tokens"], (d["tokens"], p["tokens"])
    speedup = p["tokens_per_s"] / d["tokens_per_s"]

    for name, r in results.items():
        common.row(f"serving/{name}/tokens_per_s", 0.0,
                   f"{r['tokens_per_s']:.1f};occ={r['occupancy']:.2f};"
                   f"admit_p50={r['admit_p50_steps']:.0f}steps;"
                   f"p99={r['admit_p99_steps']:.0f}steps")
    common.row("serving/paged_over_dense", 0.0, f"{speedup:.2f}x")

    # acceptance: equal KV memory, identical stream — paged must win on
    # scheduling alone (>= 2x on the tracked full workload; CI gates >= 1x)
    floor = 1.0 if quick else 2.0
    assert speedup >= floor, (
        f"paged engine only {speedup:.2f}x dense tokens/s (want >= {floor}x)"
        f": paged {p['tokens_per_s']:.1f} vs dense {d['tokens_per_s']:.1f}")

    return {
        "arch": arch,
        "n_requests": n_requests,
        "arrival_rate_per_step": rate,
        "kv_budget_tokens": budget_tokens,
        "page_size": page_size,
        "num_pages": num_pages,
        "max_reqs": max_reqs,
        "slots": slots,
        "tokens_decoded": d["tokens"],
        "dense": d,
        "paged": p,
        "paged_over_dense_x": speedup,
        "quick": quick,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; CI smoke)")
    ap.add_argument("--out", default=None,
                    help="write the metrics dict as JSON to this path")
    args = ap.parse_args()
    m = run(quick=not args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {os.path.abspath(args.out)}")
