"""§Roofline: aggregate the dry-run artifacts into the per-cell table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")


def load_reports(pattern: str = "*.json") -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def run(quick: bool = True) -> list[dict]:
    reports = [r for r in load_reports() if not r.get("tag")]
    if not reports:
        common.row("roofline/no_artifacts", 0.0,
                   "run `python -m repro.launch.dryrun` first")
        return []
    for r in reports:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        common.row(
            name, r["step_s"] * 1e6,
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
            f"compute={r['compute_s']:.4f};mem={r['memory_s']:.4f};"
            f"coll={r['collective_s']:.4f};useful={r['useful_flops_ratio']:.2f}")
    return reports


if __name__ == "__main__":
    run()
