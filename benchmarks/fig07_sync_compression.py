"""Fig. 7: GPU app + SYNCHRONOUS lossy+lossless compression.

REAL: device=sleep; the lossy stage (spectral codec) runs "on device" (its
host cost measured separately and reported, like the paper's 'lossy adds
time to NEKO on GPU'); the lossless stage (bz2) stalls the loop. Total
drops with host cores (model) because lossless parallelizes per-tensor.
"""
from __future__ import annotations

import bz2
import time

import numpy as np

from benchmarks import common
from repro.core.insitu import InSituMode
from repro.kernels import ops


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)

    # device-side lossy stage, once per firing (timed separately)
    t0 = time.perf_counter()
    c = ops.spectral_compress(field, 1e-2)
    q = np.asarray(c.q)
    lossy_s = time.perf_counter() - t0

    def lossless_task(step, payload):
        return len(bz2.compress(payload.tobytes(), 9))

    t_lossless_raw = common.calibrate_task(
        lambda s, p: len(bz2.compress(p.tobytes(), 9)), field)
    n, every = (10, 2) if quick else (40, 5)
    step_s = max(0.01, t_lossless_raw)
    res = common.run_modes(
        lambda s, p: lossless_task(s, p), field, n_steps=n, step_s=step_s,
        every=every, p_i=1, modes=(InSituMode.SYNC,))["sync"]
    common.row("fig07/sync_raw_lossless/wall", res["wall_s"] * 1e6 / n,
               f"measured;stall={res['sync_stall_s']:.3f}")
    common.row("fig07/device_lossy_stage", lossy_s * 1e6, "measured_host")

    comp = common.amdahl_from_calibration(t_lossless_raw, sigma=0.02)
    fires = n // every
    out = []
    for cores in (4, 8, 12, 16, 20, 24):
        total = n * step_s + fires * comp.predict(cores)
        common.row(f"fig07/cores{cores}/total", total * 1e6 / n, "model")
        out.append(total)
    assert all(a >= b for a, b in zip(out, out[1:]))   # drops with cores
    return {"measured": res, "model_totals": out, "lossy_s": lossy_s}


if __name__ == "__main__":
    run()
