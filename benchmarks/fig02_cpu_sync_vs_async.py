"""Fig. 2 + Table I: CPU-based app, sync vs async image generation.

Strong-scaling over 1..8 "nodes" x 72 cores. The in-situ task (training-
analytics rendering, our ParaView analog) is calibrated REAL on one thread;
its scaling follows the image-generation Amdahl curve (sigma=0.15 — the
paper's 'worse scalability of image generation'); the app scales ~ideally
(SEM/NEKO-like). Validates F1: async beats sync, optimum where app time ≈
task time, and the best p_i GROWS with node count (Table I).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import analysis
from repro.core.allocator import Allocator


def task(step, payload):
    return analysis.tensor_summary("field", payload, step, work=2)


def run(quick: bool = True) -> list[dict]:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)
    t1 = common.calibrate_task(task, field)
    steps, every = 2000, 20
    fires = steps // every
    # workload ratio calibrated to the paper's 1-node optimum (p_i=2 of 72):
    # app on 70 cores ~ 100 firings of the task on 2 cores
    app_unit = 2.0 * t1     # app step time at 1 core
    out = []
    prev_best_pi = 0
    for nodes in (1, 2, 3, 4, 6, 8):
        p_t = 72 * nodes
        al = Allocator(p_total=p_t, handoff_s=t1 * 0.01)
        # app: near-ideal strong scaling; task: image-gen Amdahl
        for p in (p_t // 4, p_t // 2, p_t):
            al.observe_app(p, app_unit / p)
        img = common.amdahl_from_calibration(t1, sigma=0.15)
        for p in (1, 4, 16, 64):
            al.observe_task(p, img.predict(p))
        plan = al.plan(steps, every)
        t_sync = (steps * al.app.predict(p_t)
                  + fires * al.task.predict(p_t))
        common.row(f"fig02/nodes{nodes}/sync", t_sync * 1e6 / steps,
                   "model")
        common.row(f"fig02/nodes{nodes}/async_best",
                   plan.predicted_total_s * 1e6 / steps,
                   f"model;p_i={plan.p_insitu};balance="
                   f"{al.balance_quality(plan):.2f}")
        assert plan.mode == "async"
        assert plan.predicted_total_s < t_sync          # F1: async wins
        assert plan.p_insitu >= prev_best_pi            # Table I: p_i grows
        prev_best_pi = plan.p_insitu
        out.append({"nodes": nodes, "sync_s": t_sync,
                    "async_s": plan.predicted_total_s,
                    "best_p_i": plan.p_insitu})
    return out


if __name__ == "__main__":
    run()
