"""Microbenchmark: blocking vs pipelined (dispatch-only) hand-off.

The tentpole claim of the two-phase hand-off is that the loop "blocks only
for the send" (paper Fig. 1b): the critical path pays the D2H *dispatch*,
while the materialization drains on the consumer side, overlapped with the
next device steps. This benchmark measures that directly on a synthetic
multi-MB payload:

  * the device step is a host-idle wait (``DeviceSim`` — the accelerator is
    busy elsewhere), exactly like every other figure;
  * the transfer materialization is ONE real host memcpy of the payload
    (``payload.copy()``) — the D2H-into-pageable-memory analog. On this
    container jax's CPU backend shares buffers with numpy (a ~µs
    ``device_get``), so the copy stands in for the PCIe drain the same way
    DeviceSim stands in for the accelerator;
  * ``blocking`` runs the legacy path (``pipelined=False``): the loop
    materializes inline under ``step/handoff``;
  * ``pipelined`` runs the two-phase path: the loop records only
    ``handoff/dispatch``; the worker pays ``handoff/materialize``.

Also reports the chunk-parallel lossless codec throughput (serial vs shared
codec pool) — the host-side half of the hot path.

Emits CSV rows like every benchmark, and returns (plus writes, when run as
a script) the ``BENCH_runtime.json`` perf artifact tracked from PR 2 on.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import codecs
from repro.insitu import InSituPlan, Placement, Session, TaskSpec

ARTIFACT = "BENCH_runtime.json"


def _transfer(payload: np.ndarray) -> np.ndarray:
    """Materialize phase: one real host memcpy (the simulated D2H drain)."""
    return payload.copy()


def _run_mode(pipelined: bool, payload: np.ndarray, *, n: int,
              step_s: float) -> dict:
    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="xfer", stream="x",
                        sink=lambda s, p: p.nbytes,
                        handoff=lambda p: _transfer(p),
                        placement=Placement.ASYNC, pipelined=pipelined)],
        workers=1, staging_capacity=2)
    session = Session(plan)
    dev = common.DeviceSim(step_s)

    def app_step(i):
        dev()
        return {"x": lambda: payload}

    t0 = time.perf_counter()
    session.run(n, app_step)
    wall = time.perf_counter() - t0
    assert not session.errors(), session.errors()[:1]
    assert len(session.results) == n
    rep = session.report()
    rep["wall_s"] = wall
    return rep


def _codec_mb_s(payload: np.ndarray) -> dict:
    mb = payload.nbytes / 1e6
    t0 = time.perf_counter()
    blob, _ = codecs.encode(payload, "zlib1")
    serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    blob_p, _ = codecs.encode(payload, "zlib1", pool=codecs.codec_pool())
    parallel = time.perf_counter() - t0
    assert blob_p == blob                     # pool changes nothing but time
    t0 = time.perf_counter()
    out = codecs.decode(blob, pool=codecs.codec_pool())
    decode_par = time.perf_counter() - t0
    np.testing.assert_array_equal(out, payload)
    return {"encode_serial_mb_s": mb / serial,
            "encode_parallel_mb_s": mb / parallel,
            "decode_parallel_mb_s": mb / decode_par}


def run(quick: bool = True) -> dict:
    mb = 8 if quick else 32
    n, step_s = (6, 0.01) if quick else (16, 0.02)
    payload = common.turbulence_field(mb << 18)   # f32: mb << 18 elems = mb MB

    res = {name: _run_mode(pipelined, payload, n=n, step_s=step_s)
           for name, pipelined in (("blocking", False), ("pipelined", True))}

    crit = {name: r["handoff_s"] / n for name, r in res.items()}
    speedup = crit["blocking"] / max(crit["pipelined"], 1e-9)
    pl = res["pipelined"]
    overlap = pl["handoff_materialize_s"] / max(
        pl["handoff_materialize_s"] + pl["handoff_dispatch_s"], 1e-9)

    common.row("handoff/blocking/critical_path", crit["blocking"] * 1e6,
               f"measured;payload_mb={mb}")
    common.row("handoff/pipelined/critical_path", crit["pipelined"] * 1e6,
               f"measured;speedup={speedup:.1f}x;overlap={overlap:.3f}")
    common.row("handoff/blocking/wall", res["blocking"]["wall_s"] * 1e6 / n,
               "measured")
    common.row("handoff/pipelined/wall", res["pipelined"]["wall_s"] * 1e6 / n,
               "measured")

    codec = _codec_mb_s(payload)
    for k, v in codec.items():
        common.row(f"codec/{k}", 1e6 / max(v, 1e-9), f"{v:.1f}MB/s")

    # acceptance: the dispatch-only critical path must beat the blocking
    # baseline by >= 2x (in practice it is orders of magnitude)
    assert speedup >= 2.0, f"pipelined handoff only {speedup:.2f}x faster"

    metrics = {
        "payload_mb": mb,
        "steps": n,
        "critical_path_handoff_us": {k: v * 1e6 for k, v in crit.items()},
        "handoff_speedup": speedup,
        "overlap_fraction": overlap,
        "wall_us_per_step": {k: r["wall_s"] * 1e6 / n
                             for k, r in res.items()},
        "codec_mb_s": codec,
        "quick": quick,
    }
    return metrics


def write_artifact(metrics: dict, path: str = ARTIFACT) -> None:
    with open(path, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None,
                    help="artifact path; default: BENCH_runtime.json for "
                         "--full runs (quick numbers are not comparable "
                         "across PRs, so quick runs need an explicit --out)")
    args = ap.parse_args()
    m = run(quick=not args.full)
    out = args.out or (ARTIFACT if args.full else None)
    if out:
        write_artifact(m, out)
        print(f"# wrote {os.path.abspath(out)}")
    else:
        print("# quick run: pass --out (or --full) to write the artifact")
