"""§IV-B: physics-based lossy + lossless removes ~98% at max error 1e-2."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import codecs
from repro.kernels import ops, ref


def run(quick: bool = True) -> dict:
    n = 1 << 18 if quick else 1 << 22
    field = common.turbulence_field(n)
    x = np.asarray(field)
    out = {}
    for eps in (1e-1, 1e-2, 1e-3):
        c = ops.spectral_compress(field, eps)
        xh = ops.spectral_decompress(c)
        err = ref.rel_l2_error(field, xh)
        blob, _ = codecs.encode(np.asarray(c.q), "zlib")
        stored = len(blob) + int(np.asarray(c.scale).nbytes)
        removed = (x.nbytes - stored) / x.nbytes
        kept = ref.kept_fraction(c)
        common.row(f"lossy_ratio/eps{eps:g}", removed * 1e6,
                   f"removed={removed:.4f};err={err:.4f};kept={kept:.4f}")
        out[eps] = (removed, err)
    # the paper's claim at 1e-2: ~98% of the data removed, accuracy kept
    removed, err = out[1e-2]
    assert removed >= 0.95, removed
    assert err <= ref.error_bound(1e-2), err
    return out


if __name__ == "__main__":
    run()
