"""Prefix sharing + replica hydration: prefill saved, TTFT saved.

Two measurements, both against the same paged engine geometry (equal KV
page budget, identical request streams):

**Prefill reduction.** N requests share a 3-page system prompt and differ
only in a short unique tail. The unshared engine prefills every prompt in
full (``N * (prefix + tail)`` tokens); the sharing engine prefills the
prefix once at registration, COW-maps it into every matching admit, and
prefills only each request's tail — ``prefix + N * tail`` tokens. The
outputs must be token-for-token identical (sharing is a page-table
concern; the math never changes), so the ratio is pure avoided work:

    quick (N=8):  8 * 52 = 416  vs  48 + 8 * 4 =  80  ->  5.2x
    full (N=16): 16 * 52 = 832  vs  48 + 16 * 4 = 112  ->  7.4x

Acceptance: >= 5x fewer prefilled tokens, bitwise-identical outputs.

**Cold-replica TTFT.** A replica can reach the producer's serving state
two ways: re-prefill every in-flight request from its prompt, or rebuild
from the snapshot chain (``PagedServingEngine.from_snapshot``) and decode
immediately. Both paths are timed jit-warm (best of 3) to first decoded
token. Acceptance: hydration beats re-prefill (>= 2x on the tracked full
workload; quick/CI gates >= 1x — it must never lose).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common

PREFIX_TOKENS = 48                  # 3 pages of shared system prompt
TAIL_TOKENS = 4                     # unique per-request suffix


def _requests(n: int, vocab: int, prefix: np.ndarray, *, max_new: int,
              seed: int):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [Request(i, np.concatenate(
        [prefix, rng.integers(0, vocab, size=TAIL_TOKENS)]), max_new=max_new)
        for i in range(n)]


def _mk_engine(cfg, prm, *, num_pages: int, max_reqs: int):
    from repro.serving.pages import PagedServingEngine

    return PagedServingEngine(cfg, prm, num_pages=num_pages, page_size=16,
                              max_reqs=max_reqs,
                              prompt_len=PREFIX_TOKENS + TAIL_TOKENS + 4,
                              max_len=64)


def _ttft(once, repeats: int = 3) -> float:
    """Best-of-N wall time to first decoded token (call ``once`` warm)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        once()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True) -> dict:
    import jax

    from repro.configs import base
    from repro.models import params as P
    from repro.models import transformer
    from repro.serving.engine import Request
    from repro.serving.pages import PagedServingEngine

    arch = "smollm-135m"
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))

    n = 8 if quick else 16
    max_new, max_reqs = 8, 4
    # equal budget both ways: 4 concurrent chains of 4 pages + the 3-page
    # prefix + scratch
    num_pages = max_reqs * 4 + 3 + 1
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, size=PREFIX_TOKENS)
    mk_reqs = lambda: _requests(n, cfg.vocab_size, prefix,
                                max_new=max_new, seed=5)

    # -- prefill reduction, token-identical ---------------------------------
    a, b = mk_reqs(), mk_reqs()
    plain = _mk_engine(cfg, prm, num_pages=num_pages, max_reqs=max_reqs)
    plain.run(a, max_steps=512)
    shared = _mk_engine(cfg, prm, num_pages=num_pages, max_reqs=max_reqs)
    shared.register_prefix(prefix)
    shared.run(b, max_steps=512)

    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out == rb.out, (
            f"sharing changed request {ra.rid}: {ra.out} vs {rb.out}")
    sp, ss = plain.prefix_stats(), shared.prefix_stats()
    reduction = sp["prefill_tokens"] / ss["prefill_tokens"]
    common.row("prefix_sharing/unshared_prefill_tokens",
               float(sp["prefill_tokens"]), "measured")
    common.row("prefix_sharing/shared_prefill_tokens",
               float(ss["prefill_tokens"]),
               f"hit_rate={ss['hit_rate']:.0%};"
               f"shared_tokens={ss['shared_tokens']}")
    common.row("prefix_sharing/prefill_reduction", 0.0, f"{reduction:.1f}x")
    assert ss["hit_rate"] == 1.0, ss
    assert reduction >= 5.0, (
        f"prefix sharing only cut prefill {reduction:.1f}x "
        f"({sp['prefill_tokens']} -> {ss['prefill_tokens']}, want >= 5x)")

    # -- cold-replica TTFT: hydrate vs re-prefill ---------------------------
    producer = _mk_engine(cfg, prm, num_pages=num_pages, max_reqs=max_reqs)
    producer.register_prefix(prefix)
    live = mk_reqs()[:max_reqs]
    for r in live:
        assert producer.admit(r)
    producer.step()                              # mid-serve snapshot point
    flat, _ = jax.tree_util.tree_flatten_with_path(
        producer.snapshot_payload()["cache"])
    leaves = {jax.tree_util.keystr(p): np.asarray(l) for p, l in flat}

    # jit caches are per engine instance, so both paths run on one warm
    # engine each — the timed region is restore-vs-prefill work, not
    # retracing. (from_snapshot itself is the warm-up for the hydrator.)
    hyd = PagedServingEngine.from_snapshot(cfg, prm, leaves)
    hyd.step()

    def hydrate_once():
        hyd.load_snapshot(leaves)                # replica back to chain pt
        hyd.step()

    rep = _mk_engine(cfg, prm, num_pages=num_pages, max_reqs=max_reqs)

    def reprefill_once():
        # what a replica without the chain must do: re-admit (re-prefill)
        # every in-flight request from its prompt, then decode
        for row, a in enumerate(rep.active):
            if a is not None:
                rep.free_resource(row)
        for r in live:
            ok = rep.admit(Request(r.rid, r.prompt.copy(),
                                   max_new=r.max_new))
            assert ok
        rep.step()

    reprefill_once()                             # warm prefill/insert jits
    t_hydrate = _ttft(hydrate_once)
    t_reprefill = _ttft(reprefill_once)
    ttft_x = t_reprefill / t_hydrate
    common.row("prefix_sharing/ttft_hydrate", t_hydrate * 1e6, "measured")
    common.row("prefix_sharing/ttft_reprefill", t_reprefill * 1e6,
               "measured")
    common.row("prefix_sharing/ttft_speedup", 0.0, f"{ttft_x:.1f}x")
    floor = 1.0 if quick else 2.0
    assert ttft_x >= floor, (
        f"hydrated cold-replica TTFT only {ttft_x:.2f}x re-prefill "
        f"({t_hydrate * 1e3:.1f} ms vs {t_reprefill * 1e3:.1f} ms, "
        f"want >= {floor}x)")

    return {
        "arch": arch,
        "n_requests": n,
        "prefix_tokens": PREFIX_TOKENS,
        "tail_tokens": TAIL_TOKENS,
        "num_pages": num_pages,
        "unshared_prefill_tokens": sp["prefill_tokens"],
        "shared_prefill_tokens": ss["prefill_tokens"],
        "shared_tokens": ss["shared_tokens"],
        "prefill_reduction_x": reduction,
        "hit_rate": ss["hit_rate"],
        "ttft_hydrate_s": t_hydrate,
        "ttft_reprefill_s": t_reprefill,
        "ttft_speedup_x": ttft_x,
        "quick": quick,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; CI smoke)")
    ap.add_argument("--out", default=None,
                    help="write the metrics dict as JSON to this path")
    args = ap.parse_args()
    m = run(quick=not args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(m, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {os.path.abspath(args.out)}")
