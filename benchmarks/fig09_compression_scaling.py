"""Fig. 9 (F4 at scale): sync vs hybrid compression across nodes.

Both scale with nodes (compression is per-rank local — unlike image
generation there is no collective), hybrid stays ahead because its stall is
only the (tiny) hand-off + the device-side lossy increment.
"""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks import common
from repro.kernels import ops


def run(quick: bool = True) -> dict:
    field = common.turbulence_field(1 << 16 if quick else 1 << 20)
    q = np.asarray(ops.spectral_compress(field, 1e-2).q).reshape(-1)

    def lossless(s, p):
        return len(zlib.compress(p.tobytes(), 6))

    t_raw = common.calibrate_task(lossless, field)
    t_q = common.calibrate_task(lossless, q)
    n, every, step_s = 40, 10, max(t_raw, 0.005)
    fires = n // every
    sync_m = common.amdahl_from_calibration(t_raw, sigma=0.02)
    hyb_m = common.amdahl_from_calibration(t_q, sigma=0.02)
    out = {"nodes": [], "sync": [], "hybrid": []}
    for nodes in (2, 3, 4, 6, 8):
        p = 12 * nodes // 2
        app = n * step_s
        sync = app + fires * sync_m.predict(p)
        hyb = max(app, fires * hyb_m.predict(p)) + hyb_m.predict(p)
        common.row(f"fig09/nodes{nodes}/sync", sync * 1e6 / n, "model")
        common.row(f"fig09/nodes{nodes}/hybrid", hyb * 1e6 / n, "model")
        out["nodes"].append(nodes)
        out["sync"].append(sync)
        out["hybrid"].append(hyb)
    assert all(h < s for h, s in zip(out["hybrid"], out["sync"]))  # F4
    # both improve (or stay flat) with nodes — compression has no collective
    assert all(a >= b - 1e-12 for a, b in zip(out["sync"], out["sync"][1:]))
    return out


if __name__ == "__main__":
    run()
