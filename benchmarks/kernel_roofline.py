"""§Kernel roofline: place each Pallas kernel on the compute/memory roofline
and gate the two-level histogram speedup.

Off-TPU the Pallas kernels only execute in interpret mode, whose
``cost_analysis()`` prices the python interpreter machinery rather than the
kernel math — so FLOP/byte counts come from jnp *mirror* functions that
spell out exactly the arithmetic the kernel bodies do (DCT matmul + one-hot
binning matmuls), compiled by XLA. On TPU the real kernels are compiled and
additionally wall-timed, giving a hardware-honest achieved fraction.

The two-level gate: the coarse(32) + refine(16) histogram passes must cost
>= 3x fewer FLOPs than the flat 512-bin pass they replaced (ISSUE 10
acceptance). ``run(quick=True)`` asserts it; the full run records the
``kernel_roofline`` section of ``BENCH_runtime.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common

GATE_SPEEDUP = 3.0


# ---------------------------------------------------------------------------
# jnp mirrors of the kernel bodies (same math, XLA-compiled) — used for
# FLOP/byte accounting off-TPU where interpret-mode cost_analysis would
# price the interpreter, not the kernel.
# ---------------------------------------------------------------------------

def _mirrors():
    import jax.numpy as jnp

    from repro.kernels import ref

    def _abs_bins(y):
        a = jnp.abs(y).reshape(-1)
        return a * a, ref.bin_index(a)

    def _onehot(idx, nbins):
        return (idx[:, None] == jnp.arange(nbins)[None, :]).astype(jnp.float32)

    def flat_hist(xb):
        y = ref.dct_blocks(xb)
        a2, idx = _abs_bins(y)
        oh = _onehot(idx, ref.NBINS)
        return y, jnp.sum(oh, axis=0), a2 @ oh

    def coarse_hist(xb):
        y = ref.dct_blocks(xb)
        a2, idx = _abs_bins(y)
        oh = _onehot(idx // ref.NBINS_FINE, ref.NBINS_COARSE)
        return y, jnp.sum(oh, axis=0), a2 @ oh

    def refine_hist(y, coarse):
        a2, idx = _abs_bins(y)
        member = (idx // ref.NBINS_FINE) == coarse
        fine = jnp.where(member, idx - coarse * ref.NBINS_FINE, 0)
        oh = _onehot(fine, ref.NBINS_FINE) * member[:, None]
        return jnp.sum(oh, axis=0), a2 @ oh

    def threshold_quant(y, t):
        return ref.quantize_blocks(y, t)

    def dequant_idct(q, s):
        return ref.idct_blocks(ref.dequantize_blocks(q, s))

    return {"dct_hist": flat_hist, "dct_hist_coarse": coarse_hist,
            "hist_refine": refine_hist, "threshold_quant": threshold_quant,
            "dequant_idct": dequant_idct}


def run(quick: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref, spectral_lossy as K
    from repro.kernels import paged_attention as PK
    from repro.roofline.kernels import kernel_report

    on_tpu = jax.default_backend() == "tpu"
    n_blocks = 256 if quick else 4096          # 64K / 1M elements
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.standard_normal((n_blocks, ref.BLOCK)), jnp.float32)
    y = ref.dct_blocks(xb)
    t = jnp.full((n_blocks,), 1e-2, jnp.float32)
    q, s = ref.quantize_blocks(y, t)
    coarse = jnp.int32(17)

    reports = {}
    if on_tpu:
        # compiled Pallas kernels: cost_analysis is the real lowered cost
        # and wall time is hardware-honest.
        import functools
        cases = {
            "dct_hist": (functools.partial(K.dct_hist, interpret=False),
                         (xb,)),
            "dct_hist_tiled": (functools.partial(K.dct_hist_tiled,
                                                 interpret=False), (xb,)),
            "dct_hist_coarse": (functools.partial(K.dct_hist_coarse,
                                                  interpret=False), (xb,)),
            "hist_refine": (functools.partial(K.hist_refine,
                                              interpret=False), (y, coarse)),
            "threshold_quant": (functools.partial(K.threshold_quant,
                                                  interpret=False), (y, t)),
            "dequant_idct": (functools.partial(K.dequant_idct,
                                               interpret=False), (q, s)),
        }
        for name, (fn, fargs) in cases.items():
            reports[name] = kernel_report(fn, fargs, name=name, measure=True)
    else:
        mirrors = _mirrors()
        for name, fn in mirrors.items():
            fargs = {"hist_refine": (y, coarse),
                     "threshold_quant": (y, t),
                     "dequant_idct": (q, s)}.get(name, (xb,))
            reports[name] = kernel_report(fn, fargs, name=name, measure=True)
        # tiled flat pass does the same arithmetic per element as the
        # global-accumulation pass; mirror cost is shared.
        import dataclasses
        reports["dct_hist_tiled"] = dataclasses.replace(
            reports["dct_hist"], name="dct_hist_tiled",
            note="mirror cost shared with dct_hist (same per-element math)")

    # paged attention rides along at a decode-like shape; off-TPU this is
    # the interpret-mode artifact (cost note says so).
    b, pps, ps, n_kv, hq, d = 4, 4, 16, 2, 8, 64
    kp = jnp.asarray(rng.standard_normal((b * pps + 1, ps, n_kv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b * pps + 1, ps, n_kv, d)),
                     jnp.float32)
    qq = jnp.asarray(rng.standard_normal((b, 1, hq, d)), jnp.float32)
    table = jnp.asarray(rng.permutation(b * pps).reshape(b, pps) + 1,
                        jnp.int32)
    lengths = jnp.asarray(rng.integers(1, pps * ps, b), jnp.int32)
    import functools as _ft
    pa = kernel_report(
        _ft.partial(PK.paged_decode_attention, interpret=not on_tpu),
        (qq, kp, vp, table, lengths), name="paged_attention",
        measure=on_tpu)
    if not on_tpu:
        pa.note = ((pa.note + "; ") if pa.note else "") + \
            "interpret-mode lowering: cost reflects the emulation, not the kernel"
    reports["paged_attention"] = pa

    # -- two-level gate -----------------------------------------------------
    flat = reports["dct_hist"]
    coarse_r = reports["dct_hist_coarse"]
    refine_r = reports["hist_refine"]
    if on_tpu:
        # wall time on hardware
        speedup = flat.measured_s / (coarse_r.measured_s + refine_r.measured_s)
        basis = "measured_s"
    else:
        speedup = flat.flops / (coarse_r.flops + refine_r.flops)
        basis = "flops"
    elems = n_blocks * ref.BLOCK
    metrics = {
        "backend": jax.default_backend(),
        "quick": quick,
        "n_blocks": n_blocks,
        "kernels": {n: r.to_dict() for n, r in reports.items()},
        "two_level": {
            "basis": basis,
            "flat_cost": flat.measured_s if on_tpu else flat.flops,
            "two_level_cost": ((coarse_r.measured_s + refine_r.measured_s)
                               if on_tpu
                               else coarse_r.flops + refine_r.flops),
            "speedup": speedup,
            "flat_flops_per_elem": flat.flops / elems,
            "two_level_flops_per_elem":
                (coarse_r.flops + refine_r.flops) / elems,
        },
        "tuned_tiles": {repr(k): v for k, v in ops.tuned_tiles().items()},
    }
    for name, r in reports.items():
        common.row(f"kernel_roofline/{name}",
                   (r.measured_s or r.roofline_s) * 1e6,
                   f"bound={r.bound};intensity={r.intensity:.1f};"
                   f"flops={r.flops:.3g};bytes={r.bytes_accessed:.3g}")
    common.row("kernel_roofline/two_level_speedup", 0.0,
               f"{speedup:.2f}x ({basis})")
    assert speedup >= GATE_SPEEDUP, (
        f"two-level histogram pass only {speedup:.2f}x cheaper than the "
        f"flat 512-bin pass (gate: {GATE_SPEEDUP}x, basis: {basis})")
    return metrics


if __name__ == "__main__":
    import argparse
    import json

    from benchmarks import handoff_overlap

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="small payload; gates the two-level speedup only")
    ap.add_argument("--out", default=None,
                    help="merge the kernel_roofline section into this "
                         "artifact (default: BENCH_runtime.json on --full)")
    args = ap.parse_args()
    quick = not args.full
    metrics = run(quick=quick)
    out = args.out or (None if quick else handoff_overlap.ARTIFACT)
    if out:
        try:
            with open(out) as f:
                artifact = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            artifact = {}
        artifact["kernel_roofline"] = metrics
        handoff_overlap.write_artifact(artifact, path=out)
        print(f"# wrote kernel_roofline into {out}")
