"""Table II: lossless compression ratios on floating-point state.

Paper values on NEKO turbulence output: Bzip2 1.56%, LZ4 4.57%, LZ4HC
5.71%, ZLIB 10.19%, ZSTD 5.93% — i.e. plain lossless barely compresses
float scientific data (F5's motivation). We measure the same codecs (those
installed) on three real payload classes and confirm the paper's
qualitative finding: raw float tensors compress by only a few percent,
while the spectral-lossy int8 residue compresses drastically.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import codecs
from repro.kernels import ops


def run(quick: bool = True) -> dict:
    n = 1 << 18 if quick else 1 << 22
    field = common.turbulence_field(n)
    rng = np.random.default_rng(0)
    weights = (rng.standard_normal(n) * 0.02).astype(np.float32)
    q = np.asarray(ops.spectral_compress(field, 1e-2).q)

    out = {}
    for codec in codecs.available():
        if codec == "none":
            continue
        cr_field = codecs.compression_ratio(field, codec).ratio
        cr_w = codecs.compression_ratio(weights, codec).ratio
        cr_q = codecs.compression_ratio(q, codec).ratio
        common.row(f"tab2/{codec}/turbulence_f32", cr_field * 1e6,
                   f"CR={cr_field:.4f}")
        common.row(f"tab2/{codec}/weights_f32", cr_w * 1e6,
                   f"CR={cr_w:.4f}")
        common.row(f"tab2/{codec}/lossy_int8_residue", cr_q * 1e6,
                   f"CR={cr_q:.4f}")
        out[codec] = (cr_field, cr_w, cr_q)
        # paper's qualitative claim: raw float ~ few percent; residue huge
        assert cr_w < 0.25, f"{codec} on weights: {cr_w}"
        assert cr_q > 0.8, f"{codec} on residue: {cr_q}"
    return out


if __name__ == "__main__":
    run()
