"""Optimizer + schedules + gradient compression numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro import optim
from repro.optim import grad_compress, schedules


def test_adamw_converges_on_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, bf16_moments=False,
                            grad_clip=0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    st_ = optim.init(params, cfg)
    for _ in range(200):
        g = jax.tree.map(lambda p: 2 * p, params)   # d/dx x^2
        params, st_ = optim.update(g, st_, params, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_master_weights_allow_tiny_updates():
    cfg = optim.AdamWConfig(lr=1e-4, weight_decay=0.0, master_weights=True)
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    st_ = optim.init(params, cfg)
    g = {"x": jnp.ones((4,), jnp.bfloat16)}
    for _ in range(100):
        params, st_ = optim.update(g, st_, params, cfg)
    # master accumulates sub-bf16 deltas; params eventually move
    assert float(st_.master["x"][0]) < 1.0 - 1e-3


def test_grad_clip():
    cfg = optim.AdamWConfig(grad_clip=1.0, bf16_moments=False)
    g = {"x": jnp.asarray([100.0, 0.0])}
    assert float(optim.adamw.global_norm(g)) == pytest.approx(100.0)


def test_schedules_shapes():
    lr0 = float(schedules.warmup_cosine(0, peak=1.0, warmup=10, total=100))
    lr_w = float(schedules.warmup_cosine(10, peak=1.0, warmup=10, total=100))
    lr_end = float(schedules.warmup_cosine(100, peak=1.0, warmup=10,
                                           total=100))
    assert lr0 == 0.0 and lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, abs=1e-5)   # floor_frac


# -- gradient compression -----------------------------------------------------

def test_int8_quantization_error_bounded(rng):
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    amax = float(jnp.max(jnp.abs(x)))
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    err = float(jnp.max(jnp.abs(q.astype(jnp.float32) * scale - x)))
    assert err <= scale / 2 + 1e-7


def test_int8_ring_mean_single_device_mesh():
    """n=1 ring degenerates to quantize+dequantize (shard_map on 1 device)."""
    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(256).astype(np.float32))

    from repro.distributed import sharding
    f = sharding.shard_map(
        lambda v: grad_compress.int8_ring_mean(v, "pod", 1),
        mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)
    with sharding.mesh_context(mesh):
        out = f(x)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(out - x))) <= amax / 127.0


def test_error_feedback_invariant(rng):
    """g_pre == reduced + residual (what EF carries is exactly what was lost)."""
    g = {"w": jnp.asarray(rng.standard_normal(128).astype(np.float32))}
    res = grad_compress.ef_init(g)
    g_pre = grad_compress.ef_pre(g, res)
    # fake a lossy reduction: quantize to 1 decimal
    reduced = jax.tree.map(lambda x: jnp.round(x, 1), g_pre)
    new_res = grad_compress.ef_post(g_pre, reduced)
    recon = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                         reduced, new_res)
    np.testing.assert_allclose(np.asarray(recon["w"]),
                               np.asarray(g_pre["w"]), atol=0.01)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), n=st.integers(2, 8))
def test_int8_ring_math_property(seed, n):
    """Pure-python model of the ring: mean of quantized == quantized mean."""
    r = np.random.default_rng(seed)
    xs = r.standard_normal((n, 64)).astype(np.float32)
    amax = np.abs(xs).max()
    scale = max(amax, 1e-30) / 127.0
    qs = np.clip(np.round(xs / scale), -127, 127)
    ring_mean = qs.sum(0) * scale / n
    true_mean = xs.mean(0)
    assert np.max(np.abs(ring_mean - true_mean)) <= scale / 2 + 1e-6
