"""Model substrate: per-arch smoke steps + mixer-vs-oracle checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import attention as attn_lib
from repro.models import params as P
from repro.models import ssm as ssm_lib
from repro.models import transformer
from repro.models import xlstm as xlstm_lib
from repro.models import moe as moe_lib


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, output shapes + no NaNs."""
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend:
        batch["prefix"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)
    logits, aux, mask = transformer.forward(prm, cfg, batch["tokens"],
                                            prefix_embeds=batch.get("prefix"))
    s_total = S + (cfg.frontend_tokens if cfg.frontend else 0)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    loss, grads = jax.value_and_grad(
        lambda p: transformer.train_loss(p, cfg, batch))(prm)
    assert np.isfinite(float(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_full_config_numbers_match_brief(arch):
    """The FULL configs carry the exact published numbers."""
    cfg = base.get(arch)
    expected = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    if arch == "deepseek-v3-671b":
        assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
        assert cfg.mla is not None and cfg.mtp_weight > 0
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if arch == "qwen3-4b":
        assert cfg.qk_norm
    if arch == "qwen1.5-110b":
        assert cfg.qkv_bias
    if arch == "hymba-1.5b":
        assert cfg.ssm is not None and cfg.ssm.d_state == 16
    if arch == "xlstm-1.3b":
        assert cfg.xlstm is not None


# -- attention ---------------------------------------------------------------

@pytest.mark.parametrize("hq,n_kv", [(8, 8), (8, 2), (9, 3)])
@pytest.mark.parametrize("window", [0, 7])
def test_flash_attention_matches_reference(hq, n_kv, window, rng):
    B, S, D = 2, 64, 16
    q = jnp.asarray(rng.standard_normal((B, S, hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, n_kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, n_kv, D)).astype(np.float32))
    out = attn_lib.flash_attention(q, k, v, causal=True, window=window,
                                   q_chunk=16, kv_chunk=16)
    ref = attn_lib.reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_last_row(rng):
    B, S, H, D = 2, 32, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    full = attn_lib.reference_attention(q, k, v, causal=True)
    lengths = jnp.full((B,), S, jnp.int32)
    dec = attn_lib.decode_attention(q[:, -1:], k, v, lengths)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


# -- SSM / xLSTM oracles -------------------------------------------------------

def test_ssm_chunked_matches_stepwise():
    cfg = base.get("hymba-1.5b", smoke=True)
    spec = ssm_lib.ssm_spec(cfg)
    p = P.materialize(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3 * cfg.ssm.chunk, cfg.d_model),
                          jnp.float32)
    fast = ssm_lib.ssm_mixer(p, x, cfg)
    slow = ssm_lib.ssm_mixer_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_parallel():
    cfg = base.get("hymba-1.5b", smoke=True)
    p = P.materialize(jax.random.PRNGKey(2), ssm_lib.ssm_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, cfg.d_model),
                          jnp.float32)
    full = ssm_lib.ssm_mixer(p, x, cfg)
    di = ssm_lib.d_inner(cfg)
    state = {"h": jnp.zeros((2, di, cfg.ssm.d_state), jnp.float32),
             "conv": jnp.zeros((2, cfg.ssm.d_conv - 1, di), jnp.float32)}
    outs = []
    for t in range(12):
        y, state = ssm_lib.ssm_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunkwise_matches_stepwise():
    cfg = base.get("xlstm-1.3b", smoke=True)
    p = P.materialize(jax.random.PRNGKey(5), xlstm_lib.mlstm_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 2 * cfg.xlstm.chunk,
                                                  cfg.d_model), jnp.float32)
    fast = xlstm_lib.mlstm_mixer(p, x, cfg)
    slow = xlstm_lib.mlstm_mixer_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=5e-3, atol=5e-3)


# -- MoE ------------------------------------------------------------------------

def test_moe_matches_reference_and_routes():
    cfg = base.get("moonshot-v1-16b-a3b", smoke=True)
    p = P.materialize(jax.random.PRNGKey(7), moe_lib.moe_spec(cfg))
    # f32 routing: bf16 would flip near-tie expert choices vs the oracle
    p = jax.tree.map(lambda t: t.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    y_ref = moe_lib.moe_ffn_reference(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 0
    yf = np.asarray(y, dtype=np.float32).reshape(-1, cfg.d_model)
    yr = np.asarray(y_ref, dtype=np.float32).reshape(-1, cfg.d_model)
    # per-token relative error; allow a small tie-flip fraction
    err = (np.linalg.norm(yf - yr, axis=1)
           / np.maximum(np.linalg.norm(yr, axis=1), 1e-6))
    assert np.mean(err < 0.05) >= 0.9, f"token match rate {np.mean(err<0.05)}"
