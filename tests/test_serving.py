"""Serving: prefill+decode ≡ full forward; ring SWA; batched engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import attention as attn_lib
from repro.models import params as P
from repro.models import transformer
from repro.serving import engine as E
from repro.serving import kvcache

DECODE_ARCHS = ["smollm-135m", "qwen1.5-110b", "deepseek-v3-671b",
                "moonshot-v1-16b-a3b", "hymba-1.5b", "xlstm-1.3b",
                "musicgen-medium"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              cfg.vocab_size)
    ref, _, _ = transformer.forward(prm, cfg, toks)
    prefill = E.make_prefill(cfg, max_len=S + 4)
    decode = E.make_decode(cfg)
    lg_p, cache, lens = prefill(prm, toks[:, :S])
    np.testing.assert_allclose(
        np.asarray(lg_p[:, -1], np.float32),
        np.asarray(ref[:, S - 1], np.float32), rtol=0.1, atol=0.1)
    for t in range(2):
        lg_d, cache, lens = decode(prm, cache, toks[:, S + t:S + t + 1], lens)
        np.testing.assert_allclose(
            np.asarray(lg_d[:, 0], np.float32),
            np.asarray(ref[:, S + t], np.float32), rtol=0.15, atol=0.15)
    assert int(lens[0]) == S + 2


def test_swa_ring_cache_equals_full_within_window(rng):
    """A ring KV of size `window` must reproduce windowed attention exactly."""
    B, S, H, D, W = 1, 24, 2, 8, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    full = attn_lib.reference_attention(q, k, v, causal=True, window=W)
    ring_k = jnp.zeros((B, W, H, D))
    ring_v = jnp.zeros((B, W, H, D))
    for t in range(S):
        slot = t % W
        ring_k = ring_k.at[:, slot].set(k[:, t])
        ring_v = ring_v.at[:, slot].set(v[:, t])
        lengths = jnp.full((B,), t + 1, jnp.int32)
        o = attn_lib.decode_attention(q[:, t:t + 1], ring_k, ring_v, lengths,
                                      window=W, ring=True)
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4, err_msg=f"t={t}")


@pytest.mark.parametrize("arch", ["smollm-135m", "hymba-1.5b", "xlstm-1.3b"])
def test_cache_layout_and_bytes(arch):
    cfg = base.get(arch, smoke=True)
    cache = kvcache.init_cache(cfg, batch=2, max_len=32)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(cache))
    assert nbytes == kvcache.cache_bytes(cfg, 2, 32)
    spec = kvcache.cache_spec(cfg, 2, 32)
    assert jax.tree_util.tree_structure(spec) == \
        jax.tree_util.tree_structure(cache)


def test_mla_cache_is_compressed():
    """MLA latent cache must be ~heads*(nope+rope+v)/(kv_lora+rope) smaller."""
    cfg = base.get("deepseek-v3-671b")
    mla_bytes = kvcache.cache_bytes(cfg, 1, 1024)
    m = cfg.mla
    naive = (cfg.n_layers * 1024 *
             cfg.n_heads * (m.qk_nope + m.qk_rope + m.v_head) * 2)
    assert mla_bytes < naive / 30   # >30x reduction


def test_engine_state_version_tracks_cache_mutation():
    """The snapshot store's no-op shortcut relies on the version hint
    moving exactly when the cache does."""
    cfg = base.get("smollm-135m", smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    eng = E.ServingEngine(cfg, prm, slots=2, prompt_len=8, max_len=32)
    assert eng.state_version == 0
    v0 = eng.state_version
    rng = np.random.default_rng(0)
    req = E.Request(0, rng.integers(0, cfg.vocab_size, 8), max_new=4)
    assert eng.admit(req)
    assert eng.state_version == v0 + 1          # prefill wrote slot 0
    eng.step()
    assert eng.state_version == v0 + 2
    payload = eng.snapshot_payload()
    assert payload["version"] == eng.state_version
    assert payload["cache"] is eng.cache
    assert set(eng.insitu_providers()) >= {"serving_state", "lengths",
                                           "kv_snapshot"}
    # idle engine (no admit/step): the hint is stable
    assert eng.snapshot_payload()["version"] == payload["version"]


def test_serving_engine_batched_requests():
    cfg = base.get("smollm-135m", smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    eng = E.ServingEngine(cfg, prm, slots=2, prompt_len=8, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [E.Request(i, rng.integers(0, cfg.vocab_size, 8), max_new=4)
            for i in range(3)]
    eng.run(reqs, max_steps=40)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    # determinism: same prompt -> same completion
    r2 = E.Request(9, reqs[0].prompt, max_new=4)
    eng2 = E.ServingEngine(cfg, prm, slots=1, prompt_len=8, max_len=32)
    eng2.run([r2], max_steps=40)
    assert r2.out == reqs[0].out
