"""Grouped DP-local MoE dispatch (hillclimb lever) vs baseline semantics."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import moe as moe_lib, params as P


def _cfgs():
    cfg = base.get("moonshot-v1-16b-a3b", smoke=True)
    grouped = dc.replace(cfg, moe=dc.replace(cfg.moe, grouped_dispatch=True,
                                             n_groups=2))
    return cfg, grouped


def test_grouped_matches_baseline_modulo_capacity():
    cfg, grouped = _cfgs()
    p = P.materialize(jax.random.PRNGKey(7), moe_lib.moe_spec(cfg))
    p = jax.tree.map(lambda t: t.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model),
                          jnp.float32)
    y0, aux0 = moe_lib.moe_ffn(p, x, cfg)
    y1, aux1 = moe_lib.moe_ffn(p, x, grouped)
    assert y1.shape == x.shape
    assert np.isfinite(float(aux1)) and float(aux1) >= 0
    # same routing, different capacity granularity: outputs close
    rel = float(jnp.linalg.norm(y1 - y0) / jnp.maximum(
        jnp.linalg.norm(y0), 1e-9))
    assert rel < 0.05, rel


def test_grouped_gradients_flow():
    _, grouped = _cfgs()
    p = P.materialize(jax.random.PRNGKey(7), moe_lib.moe_spec(grouped))
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, grouped.d_model),
                          jnp.float32)

    def loss(p_):
        y, aux = moe_lib.moe_ffn(p_, x, grouped)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                      for t in jax.tree.leaves(g)))
    assert np.isfinite(float(gn)) and float(gn) > 0


def test_grouped_in_full_train_loss():
    cfg = base.get("moonshot-v1-16b-a3b", smoke=True)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, grouped_dispatch=True,
                                         n_groups=2))
    from repro.models import transformer
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    loss = transformer.train_loss(prm, cfg,
                                  {"tokens": toks[:, :-1],
                                   "labels": toks[:, 1:]})
    assert np.isfinite(float(loss))
