"""Paged KV cache + continuous batching: parity, allocator, snapshots.

The load-bearing claim is *bit-identity*: the paged engine gathers its
pages into token order and masks positions past the length with NEG_INF,
whose softmax weight underflows to exactly 0.0 — so paged logits are
bitwise equal to the dense engine's, and greedy decode produces the same
tokens. The parity suite pins that across every attention family; the
allocator and snapshot tests pin the lifecycle invariants the engine's
safety argument rests on (whole-chain reservation, no double-assign,
page-aligned delta COPY framing).

MoE caveat: expert capacity couples batch rows, so parity over MoE archs
requires the same batch width and a free/admit schedule that keeps active
rows aligned — the suite uses equal ``max_new`` so both engines retire
requests in the same order.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core import delta
from repro.models import attention as attn_lib
from repro.models import params as P
from repro.models import transformer
from repro.serving import pages as PG
from repro.serving.engine import Request, ServingEngine
from repro.serving.snapshot import SnapshotStore

PARITY_ARCHS = ["smollm-135m", "deepseek-v3-671b", "moonshot-v1-16b-a3b",
                "hymba-1.5b", "xlstm-1.3b"]


def _mk(arch):
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    return cfg, prm


# ---------------------------------------------------------------------------
# decode parity: paged engine bit-identical to dense slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_engine_matches_dense(arch):
    cfg, prm = _mk(arch)
    rng = np.random.default_rng(0)
    mk_reqs = lambda: [Request(i, rng0, max_new=4) for i, rng0 in
                       enumerate([rng.integers(0, cfg.vocab_size, 8)
                                  for _ in range(3)])]
    a, b = mk_reqs(), mk_reqs()
    for (ra, rb) in zip(a, b):
        rb.prompt = ra.prompt                    # identical streams

    dense = ServingEngine(cfg, prm, slots=2, prompt_len=8, max_len=64)
    dense.run(a, max_steps=64)
    paged = PG.PagedServingEngine(cfg, prm, num_pages=9, page_size=16,
                                  max_reqs=2, prompt_len=8, max_len=64)
    paged.run(b, max_steps=64)
    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out == rb.out, f"request {ra.rid} diverged"


def test_paged_gather_bitwise_equals_dense_attention(rng):
    """Gathered pages + length mask == contiguous decode attention, bit for
    bit — the kernel-independent core of the parity argument."""
    b, pps, ps, n_kv, hq, d = 2, 3, 8, 2, 4, 8
    s = pps * ps
    k = jnp.asarray(rng.standard_normal((b, s, n_kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n_kv, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, 1, hq, d)), jnp.float32)
    lengths = jnp.asarray([13, s], jnp.int32)

    # scatter rows into a shared pool at arbitrary (non-contiguous) pages
    table = jnp.asarray([[5, 1, 4], [2, 7, 3]], jnp.int32)
    kp = jnp.zeros((9, ps, n_kv, d), jnp.float32)
    vp = jnp.zeros((9, ps, n_kv, d), jnp.float32)
    for row in range(b):
        for j in range(pps):
            pg = int(table[row, j])
            kp = kp.at[pg].set(k[row, j * ps:(j + 1) * ps])
            vp = vp.at[pg].set(v[row, j * ps:(j + 1) * ps])
    # each row only sees its own pages, so per-row gather from the shared
    # pool must reproduce that row's contiguous sequence
    g = attn_lib.gather_pages(kp, table)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(k))

    out = attn_lib.paged_decode_attention(q, kp, vp, table, lengths,
                                          use_kernel=False)
    ref = attn_lib.decode_attention(q, k, v, lengths)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_scatter_token_lands_in_length_slot(rng):
    b, pps, ps, n_kv, d = 2, 3, 8, 2, 4
    pages = jnp.zeros((9, ps, n_kv, d), jnp.float32)
    table = jnp.asarray([[5, 1, 4], [2, 7, 3]], jnp.int32)
    new = jnp.asarray(rng.standard_normal((b, n_kv, d)), jnp.float32)
    lengths = jnp.asarray([5, 17], jnp.int32)
    out = attn_lib.scatter_token(pages, new, table, lengths, ps)
    np.testing.assert_array_equal(np.asarray(out[5, 5]),
                                  np.asarray(new[0]))   # row 0: chain idx 0
    np.testing.assert_array_equal(np.asarray(out[3, 1]),
                                  np.asarray(new[1]))   # row 1: 17 -> idx 2
    # exactly two slots written
    assert int((out != 0).sum()) == 2 * n_kv * d


def test_paged_attention_kernel_matches_gather(rng):
    """The Pallas kernel (interpret mode off-TPU) vs the gather fallback."""
    from repro.kernels import paged_attention as PK

    b, pps, ps, n_kv, hq, d = 2, 3, 8, 2, 4, 8
    kp = jnp.asarray(rng.standard_normal((9, ps, n_kv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((9, ps, n_kv, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, 1, hq, d)), jnp.float32)
    table = jnp.asarray([[5, 1, 4], [2, 7, 3]], jnp.int32)
    lengths = jnp.asarray([13, 24], jnp.int32)
    out = PK.paged_decode_attention(q, kp, vp, table, lengths,
                                    interpret=True)
    ref = attn_lib.paged_decode_attention(q, kp, vp, table, lengths,
                                          use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------

def test_allocator_never_double_assigns():
    a = PG.PageAllocator(8)                      # pages 1..7 usable
    x = a.alloc(3)
    y = a.alloc(4)
    assert x is not None and y is not None
    assert not set(x) & set(y)
    assert 0 not in x + y                        # scratch page never handed out
    assert a.free_pages == 0


def test_allocator_exhaustion_rejects_without_mutation():
    a = PG.PageAllocator(8)
    a.alloc(5)
    before = a.free_pages
    assert a.alloc(3) is None                    # 2 free < 3 wanted
    assert a.free_pages == before                # rejected alloc is a no-op
    assert a.alloc(2) is not None


def test_allocator_free_restores_and_guards():
    a = PG.PageAllocator(8)
    x = a.alloc(3)
    y = a.alloc(4)
    a.free(x)
    assert a.free_pages == 3
    z = a.alloc(2)
    assert set(z) <= set(x)                      # reuses released pages
    a.free(y)
    with pytest.raises(ValueError):
        a.free(y)                                # double free
    with pytest.raises(ValueError):
        a.free([99])                             # foreign page


def test_engine_reclaims_all_pages():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8),
                    max_new=int(m))
            for i, m in enumerate([4, 24, 8, 16, 4, 8])]
    eng = PG.PagedServingEngine(cfg, prm, num_pages=9, page_size=8,
                                max_reqs=3, prompt_len=8, max_len=32)
    eng.run(reqs, max_steps=256)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == r.max_new for r in reqs)
    assert eng.allocator.free_pages == eng.num_pages - 1    # no leak
    assert all(not c for c in eng._chains)
    assert eng.page_stats()["used_pages"] == 0


def test_admit_rejects_on_page_exhaustion_then_recovers():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(2)
    eng = PG.PagedServingEngine(cfg, prm, num_pages=3, page_size=8,
                                max_reqs=4, prompt_len=8, max_len=16)
    ra = Request(0, rng.integers(0, cfg.vocab_size, 8), max_new=8)
    rb = Request(1, rng.integers(0, cfg.vocab_size, 8), max_new=8)
    assert eng.admit(ra)                         # takes both usable pages
    assert not eng.admit(rb)                     # rows free, pages aren't
    while any(a is not None for a in eng.active):
        eng.step()
    assert ra.done
    assert eng.admit(rb)                         # reclaimed pages readmit


def test_admit_rejects_requests_that_cannot_fit():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(3)
    eng = PG.PagedServingEngine(cfg, prm, num_pages=5, page_size=8,
                                max_reqs=2, prompt_len=8, max_len=16)
    with pytest.raises(ValueError, match="max_new"):
        eng.admit(Request(0, rng.integers(0, cfg.vocab_size, 8),
                          max_new=16))           # 8 + 16 > max_len


def test_page_size_must_divide_max_len():
    cfg, prm = _mk("smollm-135m")
    with pytest.raises(ValueError, match="multiple"):
        PG.PagedServingEngine(cfg, prm, num_pages=5, page_size=12,
                              max_reqs=2, prompt_len=8, max_len=64)


# ---------------------------------------------------------------------------
# prompt truncation warns instead of silently dropping tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_long_prompt_warns_and_truncates(kind):
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(4)
    long_prompt = rng.integers(0, cfg.vocab_size, 12)
    if kind == "dense":
        eng = ServingEngine(cfg, prm, slots=1, prompt_len=8, max_len=32)
    else:
        eng = PG.PagedServingEngine(cfg, prm, num_pages=5, page_size=8,
                                    max_reqs=1, prompt_len=8, max_len=32)
    r = Request(0, long_prompt, max_new=4)
    with pytest.warns(RuntimeWarning, match="request 0.*exceeds"):
        eng.run([r], max_steps=16)
    assert r.done and len(r.out) == 4
    # the tail of the prompt is what survives: same completion as submitting
    # the truncated prompt explicitly (no warning that time)
    r2 = Request(1, long_prompt[-8:], max_new=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run([r2], max_steps=16)
    assert r2.out == r.out


# ---------------------------------------------------------------------------
# page-granular snapshots: dirty tracking + delta COPY alignment
# ---------------------------------------------------------------------------

def test_page_versions_track_exactly_the_touched_pages():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(5)
    eng = PG.PagedServingEngine(cfg, prm, num_pages=9, page_size=8,
                                max_reqs=2, prompt_len=8, max_len=32)
    assert eng.admit(Request(0, rng.integers(0, cfg.vocab_size, 8),
                             max_new=8))         # 16 tokens -> 2 pages
    chain = list(eng._chains[0])
    pv1 = eng.snapshot_payload()["page_versions"]
    assert (pv1[chain] > 0).all()                # admit stamped the chain
    untouched = np.setdiff1d(np.arange(eng.num_pages), chain)
    assert (pv1[untouched] == 0).all()

    eng.step()                                   # writes slot 8 -> chain[1]
    pv2 = eng.snapshot_payload()["page_versions"]
    assert pv2[chain[1]] > pv1[chain[1]]
    stable = np.setdiff1d(np.arange(eng.num_pages), [chain[1]])
    np.testing.assert_array_equal(pv2[stable], pv1[stable])


def test_delta_chunks_align_to_pages():
    """With the engine's chunk hints, one decode step dirties exactly one
    page, and every other (layer, page) slab frames as a zero-payload COPY."""
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(6)
    eng = PG.PagedServingEngine(cfg, prm, num_pages=9, page_size=8,
                                max_reqs=2, prompt_len=8, max_len=32)
    eng.admit(Request(0, rng.integers(0, cfg.vocab_size, 8), max_new=8))
    p1 = eng.snapshot_payload()
    eng.step()
    p2 = eng.snapshot_payload()

    flat1 = jax.tree_util.tree_flatten_with_path(
        {"pool": p1["cache"]["pool"]})[0]
    flat2 = jax.tree_util.tree_flatten_with_path(
        {"pool": p2["cache"]["pool"]})[0]
    assert flat1, "paged pool must not be empty for an attention arch"
    for (path, base_leaf), (_, cur_leaf) in zip(flat1, flat2):
        key = jax.tree_util.keystr(path)
        hint = p2["chunk_hints"][key]
        layers, num_pages = base_leaf.shape[:2]
        assert hint == int(np.prod(base_leaf.shape[2:])) * \
            base_leaf.dtype.itemsize
        _, st = delta.encode(np.asarray(cur_leaf), np.asarray(base_leaf),
                             chunk_bytes=hint)
        # the step dirties one chain page, plus the scratch page 0 where
        # the inactive row's masked write lands; every other (layer, page)
        # slab must frame as a zero-payload COPY
        assert st.n_copy >= layers * (num_pages - 2)
        assert st.n_copy < layers * num_pages


def test_snapshot_store_roundtrip_with_chunk_hints():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(7)
    eng = PG.PagedServingEngine(cfg, prm, num_pages=9, page_size=8,
                                max_reqs=2, prompt_len=8, max_len=32)
    eng.admit(Request(0, rng.integers(0, cfg.vocab_size, 8), max_new=8))
    store = SnapshotStore(base_every=4)
    p1 = eng.snapshot_payload()
    r1 = store.publish("kv", 0, p1["cache"], version=p1["version"],
                       chunk_hints=p1["chunk_hints"])
    assert r1.kind == "base"
    eng.step()
    p2 = eng.snapshot_payload()
    r2 = store.publish("kv", 1, p2["cache"], version=p2["version"],
                       chunk_hints=p2["chunk_hints"])
    assert r2.kind == "delta"
    step, tree = store.restore("kv", template=p2["cache"])
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(p2["cache"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
