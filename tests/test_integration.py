"""End-to-end integration: train loop with in-situ engine + resume; serve."""
import os

import jax
import numpy as np
import pytest

from repro.launch.train import train_loop
from repro.launch.serve import serve_loop


def test_train_loop_with_insitu_and_checkpoint(tmp_path):
    out = train_loop("smollm-135m", steps=12, smoke=True,
                     insitu_mode="async", ckpt_dir=str(tmp_path),
                     ckpt_every=5, analytics_every=4, log=lambda *_: None)
    assert len(out["losses"]) == 12
    assert all(np.isfinite(l) for l in out["losses"])
    assert out["insitu_results"] >= 3            # steps 0,4,8
    # checkpoints on steps 0,5,10
    assert len(os.listdir(tmp_path)) >= 1


def test_train_loop_resumes(tmp_path):
    train_loop("smollm-135m", steps=11, smoke=True, insitu_mode="sync",
               ckpt_dir=str(tmp_path), ckpt_every=5, log=lambda *_: None)
    logs = []
    train_loop("smollm-135m", steps=3, smoke=True, insitu_mode="sync",
               ckpt_dir=str(tmp_path), ckpt_every=5, log=logs.append)
    assert any("resumed from step 10" in str(l) for l in logs)


def test_telemetry_attribution_sync_vs_async():
    out_s = train_loop("smollm-135m", steps=8, smoke=True,
                       insitu_mode="sync", analytics_every=2,
                       log=lambda *_: None)
    out_a = train_loop("smollm-135m", steps=8, smoke=True,
                       insitu_mode="async", analytics_every=2,
                       log=lambda *_: None)
    rep_s = out_s["telemetry"].step_overlap_report()
    rep_a = out_a["telemetry"].step_overlap_report()
    assert rep_s["sync_stall_s"] > 0
    assert rep_a["sync_stall_s"] == 0
    assert rep_a["async_overlapped_s"] > 0


def test_train_loop_accepts_custom_plan_subset(tmp_path):
    """plan= replaces the default workflow wholesale — a plan declaring
    only a subset of the default streams must not crash the loop."""
    out = train_loop("smollm-135m", steps=3, smoke=True, plan={
        "streams": ["train_state"],
        "tasks": {"checkpoint": {
            "stream": "train_state", "preset": "checkpoint", "every": 2,
            "options": {"directory": str(tmp_path)}}},
    })
    assert len(out["losses"]) == 3
    assert out["insitu_results"] == 0                 # no analytics declared
    assert out["session_report"]["checkpoint"]["saves"] == 2  # steps 0, 2


def test_serve_loop_completes_requests():
    out = serve_loop("smollm-135m", n_requests=3, max_new=3, slots=2,
                     insitu_mode="async", log=lambda *_: None)
    assert all(r.done for r in out["requests"])
    assert out["insitu_results"] >= 1
