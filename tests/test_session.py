"""The declarative Session/Plan API: validation, round-trip, triggers,
error propagation, checkpoint folding, and parity of the legacy shims
(`InSituEngine`/`run_workflow`/`run_pipeline`) against a `Session` on the
fig02 (sync-vs-async placement) and fig05 (frequency/backpressure/adapt)
semantics.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InSituEngine, InSituMode, InSituTask, run_workflow
from repro.insitu import (Adaptive, Every, InSituPlan, InSituTaskError,
                          Interval, Placement, PlanError, Session, TaskSpec,
                          When, preset_names)


# -- plan validation ----------------------------------------------------------

def _plan_dict(**task_over):
    task = {"stream": "a", "preset": "grad_health", "every": 1}
    task.update(task_over)
    return {"streams": ["a"], "tasks": {"t": task}}


def test_plan_unknown_stream_names_the_task():
    with pytest.raises(PlanError, match=r"task 't'.*unknown stream 'b'"):
        InSituPlan.from_dict(_plan_dict(stream="b"))


def test_plan_duplicate_task_name():
    with pytest.raises(PlanError, match=r"duplicate task 't'"):
        InSituPlan(streams=["a"],
                   tasks=[TaskSpec(name="t", stream="a", sink=print),
                          TaskSpec(name="t", stream="a", sink=print)])


def test_plan_duplicate_stream():
    with pytest.raises(PlanError, match=r"duplicate stream 'a'"):
        InSituPlan(streams=["a", "a"])


def test_plan_every_zero():
    with pytest.raises(PlanError, match=r"task 't'.*>= 1.*every=0"):
        InSituPlan.from_dict(_plan_dict(every=0))


def test_plan_conflicting_triggers():
    with pytest.raises(PlanError, match=r"task 't'.*conflicting triggers"):
        InSituPlan.from_dict(_plan_dict(
            every=2, trigger={"kind": "interval", "seconds": 1.0}))


def test_plan_adaptive_conflicts_with_non_adapt_backpressure():
    with pytest.raises(PlanError, match=r"task 't'.*conflicting"):
        InSituPlan(streams=["a"],
                   tasks=[TaskSpec(name="t", stream="a", sink=print,
                                   trigger=Adaptive(2),
                                   backpressure="drop")])


def test_plan_unknown_preset_lists_registered():
    with pytest.raises(PlanError, match=r"unknown preset 'nope'"):
        InSituPlan.from_dict(_plan_dict(preset="nope"))
    assert {"checkpoint", "grad_health", "spectra",
            "serve_snapshot"} <= set(preset_names())


def test_plan_checkpoint_requires_directory():
    with pytest.raises(PlanError, match=r"task 't'.*directory"):
        InSituPlan.from_dict(_plan_dict(preset="checkpoint"))


def test_plan_checkpoint_rejects_unwired_knobs(tmp_path):
    """The checkpoint preset must not silently ignore declared scheduling
    knobs the manager doesn't wire through."""
    opts = {"directory": str(tmp_path)}
    with pytest.raises(PlanError, match=r"task 't'.*backpressure"):
        InSituPlan(streams=["a"], tasks=[
            TaskSpec(name="t", stream="a", preset="checkpoint",
                     options=opts, backpressure="drop")])
    with pytest.raises(PlanError, match=r"task 't'.*Adaptive"):
        InSituPlan(streams=["a"], tasks=[
            TaskSpec(name="t", stream="a", preset="checkpoint",
                     options=opts, trigger=Adaptive(2))])
    with pytest.raises(PlanError, match=r"task 't'.*shards"):
        InSituPlan(streams=["a"], tasks=[
            TaskSpec(name="t", stream="a", preset="checkpoint",
                     options=opts, shards=2)])


def test_plan_at_most_one_checkpoint_task(tmp_path):
    opts = {"directory": str(tmp_path)}
    with pytest.raises(PlanError, match="at most one"):
        InSituPlan(streams=["a"], tasks=[
            TaskSpec(name="c1", stream="a", preset="checkpoint",
                     options=opts),
            TaskSpec(name="c2", stream="a", preset="checkpoint",
                     options=opts)])


def test_plan_preset_and_sink_conflict():
    with pytest.raises(PlanError, match=r"task 't'.*not both"):
        InSituPlan(streams=["a"],
                   tasks=[TaskSpec(name="t", stream="a",
                                   preset="grad_health", sink=print)])
    with pytest.raises(PlanError, match=r"task 't'.*preset or a sink"):
        InSituPlan(streams=["a"], tasks=[TaskSpec(name="t", stream="a")])


def test_plan_unknown_fields_rejected():
    with pytest.raises(PlanError, match="unknown plan field"):
        InSituPlan.from_dict({"streams": [], "typo": 1})
    with pytest.raises(PlanError, match=r"task 't'.*unknown field"):
        InSituPlan.from_dict(_plan_dict(typo=1))
    with pytest.raises(PlanError, match=r"task 't'.*unknown placement"):
        InSituPlan.from_dict(_plan_dict(placement="warp"))


# -- dict round-trip ----------------------------------------------------------

def test_plan_dict_round_trip(tmp_path):
    d = {
        "streams": ["grads", "train_state"],
        "workers": 3,
        "staging_capacity": 2,
        "tasks": {
            "gh": {"stream": "grads", "preset": "grad_health", "every": 10,
                   "placement": "sync"},
            "spec": {"stream": "grads", "preset": "spectra",
                     "trigger": {"kind": "adaptive", "n": 4,
                                 "max_every": 32, "after": 3},
                     "options": {"work": 2}},
            "ckpt": {"stream": "train_state", "preset": "checkpoint",
                     "every": 50, "placement": "hybrid",
                     "options": {"directory": str(tmp_path)}},
        },
    }
    plan = InSituPlan.from_dict(d)
    d2 = plan.to_dict()
    # a second round-trip is a fixed point
    assert InSituPlan.from_dict(d2).to_dict() == d2
    plan2 = InSituPlan.from_dict(d2)
    assert [t.name for t in plan2.tasks] == ["gh", "spec", "ckpt"]
    assert plan2.tasks[0].trigger == Every(10)
    assert plan2.tasks[0].placement is Placement.SYNC
    assert plan2.tasks[1].trigger == Adaptive(4, max_every=32, after=3)
    assert plan2.workers == 3 and plan2.staging_capacity == 2


def test_plan_list_form_tasks():
    plan = InSituPlan.from_dict({
        "streams": ["a"],
        "tasks": [{"name": "t", "stream": "a", "preset": "grad_health"}]})
    assert plan.tasks[0].name == "t"


def test_callable_tasks_do_not_serialize():
    plan = InSituPlan(streams=["a"],
                      tasks=[TaskSpec(name="t", stream="a", sink=print)])
    with pytest.raises(PlanError, match="code"):
        plan.to_dict()
    with pytest.raises(PlanError, match="code"):
        TaskSpec(name="t", stream="a", sink=print,
                 trigger=When(lambda s: True)).to_dict()


# -- session basics -----------------------------------------------------------

def _collect_plan(trigger=Every(1), **kw):
    hits = []

    def sink(step, payload):
        hits.append((step, payload))
        return step

    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="t", stream="x", trigger=trigger,
                        placement=kw.pop("placement", Placement.SYNC),
                        sink=sink, **kw)],
        workers=2)
    return plan, hits


def test_emit_unknown_stream_raises():
    plan, _ = _collect_plan()
    with Session(plan) as s:
        with pytest.raises(PlanError, match=r"unknown stream 'y'"):
            s.emit("y", 0, 1)


def test_every_trigger_and_lazy_provider():
    plan, hits = _collect_plan(trigger=Every(3))
    calls = []
    with Session(plan) as s:
        for i in range(9):
            s.emit("x", i, lambda i=i: calls.append(i) or i)
    assert [h[0] for h in hits] == [0, 3, 6]
    assert calls == [0, 3, 6]        # provider only evaluated on firings


def test_when_trigger():
    plan, hits = _collect_plan(trigger=When(lambda s: s in (2, 5)))
    with Session(plan) as s:
        for i in range(7):
            s.emit("x", i, i)
    assert [h[0] for h in hits] == [2, 5]


def test_interval_trigger_fires_by_injected_clock():
    """Interval reads the session's monotonic clock — tests drive it by
    hand instead of sleeping, so the expected firings are exact."""
    now = [0.0]
    plan, hits = _collect_plan(trigger=Interval(10.0))
    with Session(plan, clock=lambda: now[0]) as s:
        for i in range(8):
            s.emit("x", i, i)                 # emit i happens at t = 4*i
            now[0] += 4.0
    # first emit always fires (t=0); then once >= 10s elapse: t=12 (i=3),
    # t=24 (i=6) — deterministic, no sleep-and-pray
    assert [h[0] for h in hits] == [0, 3, 6]


def test_interval_trigger_fires_every_emit_when_clock_outpaces():
    now = [0.0]
    plan, hits = _collect_plan(trigger=Interval(1.0))
    with Session(plan, clock=lambda: now[0]) as s:
        for i in range(4):
            s.emit("x", i, i)
            now[0] += 1.0                     # exactly one period per emit
    assert [h[0] for h in hits] == [0, 1, 2, 3]


def test_interval_trigger_never_refires_on_a_frozen_clock():
    plan, hits = _collect_plan(trigger=Interval(5.0))
    with Session(plan, clock=lambda: 100.0) as s:
        for i in range(5):
            s.emit("x", i, i)
    assert [h[0] for h in hits] == [0]        # only the always-fired first


def test_provider_evaluated_once_for_multiple_tasks_on_one_stream():
    hits = []

    def sink(step, payload):
        hits.append(payload)
        return payload

    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="a", stream="x", sink=sink,
                        placement=Placement.SYNC),
               TaskSpec(name="b", stream="x", sink=sink,
                        placement=Placement.SYNC)])
    calls = []
    with Session(plan) as s:
        s.emit("x", 0, lambda: calls.append(0) or 7)
    assert hits == [7, 7]          # both tasks fired ...
    assert calls == [0]            # ... off ONE payload materialization


def test_session_streams_property():
    plan, _ = _collect_plan()
    with Session(plan) as s:
        assert s.streams == frozenset({"x"})


def test_non_callable_payload_is_passed_through():
    plan, hits = _collect_plan()
    with Session(plan) as s:
        s.emit("x", 0, {"a": 1})
    assert hits == [(0, {"a": 1})]


# -- error propagation --------------------------------------------------------

def test_finish_raises_with_context():
    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="boom", stream="x",
                        sink=lambda s, p: 1 / 0,
                        placement=Placement.ASYNC)])
    s = Session(plan)
    s.emit("x", 4, 1)
    with pytest.raises(InSituTaskError) as ei:
        s.finish(raise_on_error=True)
    e = ei.value
    assert (e.task, e.stream, e.step) == ("boom", "x", 4)
    assert "step 4" in str(e) and "ZeroDivisionError" in str(e)
    assert isinstance(e.__cause__, ZeroDivisionError)
    # errors stay inspectable too
    assert len(s.errors()) == 1


def test_session_default_raise_on_error_via_context_manager():
    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="boom", stream="x",
                        sink=lambda s, p: 1 / 0)])
    with pytest.raises(InSituTaskError):
        with Session(plan, raise_on_error=True) as s:
            s.emit("x", 0, 1)


def test_app_exception_not_masked_by_task_error():
    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="boom", stream="x",
                        sink=lambda s, p: 1 / 0)])
    with pytest.raises(KeyError, match="app-bug"):
        with Session(plan, raise_on_error=True) as s:
            s.emit("x", 0, 1)
            raise KeyError("app-bug")


def test_finish_idempotent():
    plan, hits = _collect_plan()
    s = Session(plan)
    s.emit("x", 0, 1)
    s.finish()
    s.finish()
    assert len(hits) == 1


# -- checkpoint folded into the session ---------------------------------------

def test_checkpoint_task_saves_restores_and_reports(tmp_path):
    state = {"w": jnp.arange(512, dtype=jnp.float32),
             "mu": jnp.ones((32, 16), jnp.float32)}
    plan = InSituPlan.from_dict({
        "streams": ["train_state"],
        "tasks": {"checkpoint": {
            "stream": "train_state", "preset": "checkpoint", "every": 4,
            "options": {"directory": str(tmp_path), "keep": 2}}},
    })
    with Session(plan, raise_on_error=True) as s:
        for i in range(10):
            s.emit("train_state", i, lambda: state)
    rep = s.report()
    assert rep["checkpoint"]["saves"] == 3            # steps 0, 4, 8
    assert rep["checkpoint"]["last_step"] == 8
    assert rep["checkpoint"]["kept_steps"] == [4, 8]  # retention keep=2
    assert rep["tasks"]["checkpoint"]["results"] == 3
    step, restored = s.restore(state)
    assert step == 8
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_restore_without_checkpoint_task_raises():
    plan, _ = _collect_plan()
    with Session(plan) as s:
        pass
    with pytest.raises(PlanError, match="no checkpoint task"):
        s.restore({"w": jnp.zeros(4)})


# -- legacy-shim parity (fig02 / fig05 semantics) -----------------------------

def _device_step(step_s):
    def app_step(i):
        time.sleep(step_s)
        return {"x": lambda: np.zeros(64, np.float32)}
    return app_step


def _session_run(placement, *, n, step_s, every=1, task_s=0.0, p_i=2,
                 cap=4, backpressure=None, trigger=None):
    def work(step, payload):
        if task_s:
            time.sleep(task_s)
        return ("done", step)

    plan = InSituPlan(
        streams=["x"],
        tasks=[TaskSpec(name="t", stream="x", sink=work,
                        trigger=trigger or Every(every),
                        placement=placement, backpressure=backpressure)],
        workers=p_i, staging_capacity=cap)
    session = Session(plan)
    session.run(n, _device_step(step_s))
    return session


def _engine_run(mode, *, n, step_s, every=1, task_s=0.0, p_i=2, cap=4):
    def work(step, payload):
        if task_s:
            time.sleep(task_s)
        return ("done", step)

    eng = InSituEngine(
        [InSituTask("t", "x", work, mode=mode, every=every)],
        p_i=p_i, staging_capacity=cap)
    run_workflow(n, _device_step(step_s), eng)
    return eng


def test_parity_sync_placement_fig02():
    """fig02's sync semantics: the task runs on the loop thread, loop time
    includes it — identical through the shim and the Session."""
    sess = _session_run(Placement.SYNC, n=6, step_s=0.005)
    eng = _engine_run(InSituMode.SYNC, n=6, step_s=0.005)
    main = threading.main_thread().name
    assert len(sess.results) == len(eng.results) == 6
    assert all(r.worker == main for r in sess.results)
    assert all(r.worker == main for r in eng.results)
    for obj in (sess.telemetry, eng.telemetry):
        assert obj.total("insitu-sync/") > 0
        assert obj.total("insitu-async/") == 0


def test_parity_async_placement_fig02():
    """fig02's async semantics: workers consume, loop only pays hand-off."""
    sess = _session_run(Placement.ASYNC, n=6, step_s=0.02, task_s=0.02)
    eng = _engine_run(InSituMode.ASYNC, n=6, step_s=0.02, task_s=0.02)
    assert len(sess.results) == len(eng.results) == 6
    assert all(r.worker.startswith("insitu-") for r in sess.results)
    assert all(r.worker.startswith("insitu-") for r in eng.results)
    assert sess.telemetry.total("insitu-sync/") == 0


def test_parity_every_n_fig05():
    sess = _session_run(Placement.ASYNC, n=9, step_s=0.0, every=3)
    eng = _engine_run(InSituMode.ASYNC, n=9, step_s=0.0, every=3)
    assert sorted(r.step for r in sess.results) == [0, 3, 6]
    assert sorted(r.step for r in eng.results) == [0, 3, 6]


def test_parity_backpressure_fig05():
    """fig05's F3 regime: ring of 1, slow consumer — the producer visibly
    backpressures through both entry points."""
    sess = _session_run(Placement.ASYNC, n=8, step_s=0.001, task_s=0.05,
                        p_i=1, cap=1)
    eng = _engine_run(InSituMode.ASYNC, n=8, step_s=0.001, task_s=0.05,
                      p_i=1, cap=1)
    assert sess.telemetry.total("staging/wait") > 0
    assert eng.telemetry.total("staging/wait") > 0
    assert len(sess.results) == len(eng.results) == 8


def test_adaptive_trigger_widens_effective_every_fig05():
    """fig05's adapt row: under sustained pressure the runtime lengthens
    the effective firing period instead of stalling forever."""
    sess = _session_run(Placement.ASYNC, n=24, step_s=0.001, task_s=0.03,
                        p_i=1, cap=1, trigger=Adaptive(1, after=2))
    rep = sess.report()
    assert rep["effective_every"]["t"] > 1


def test_engine_report_matches_session_report_keys():
    """The shim's report IS a session report (one merged dict)."""
    eng = _engine_run(InSituMode.ASYNC, n=4, step_s=0.002)
    rep = eng.report()
    for key in ("step_compute_s", "handoff_s", "n_results", "tasks",
                "errors", "effective_every"):
        assert key in rep
    assert rep["n_results"] == 4
    assert rep["tasks"]["t"]["stream"] == "x"


# ---------------------------------------------------------------------------
# steering validation: invalid commands are rejected and counted, never
# half-applied (consumers can push anything up the back-channel)
# ---------------------------------------------------------------------------

def _steering_session():
    plan = InSituPlan.from_dict({
        "streams": ["grads"],
        "tasks": {"gh": {"stream": "grads", "preset": "grad_health",
                         "every": 2, "placement": "sync"}},
    })
    return Session(plan, raise_on_error=True)


def test_steering_rejects_bad_every_and_unknown_task():
    with _steering_session() as s:
        before = s.runtime.effective_every("gh")
        for msg in ({"task": "gh", "every": 0},
                    {"task": "gh", "every": -3},
                    {"task": "gh", "every": "soon"},
                    {"task": "nosuch", "every": 2}):
            rec = s._apply_steering("test", msg)
            s._steering.append(rec)
            assert "every" in rec["rejected"], msg
            assert rec["applied"] == {}
        assert s.runtime.effective_every("gh") == before   # untouched
        s.emit("grads", 0, {"params": np.zeros(8, np.float32)})
    st = s.report()["steering"]
    assert st["steering_rejected"] == 4
    assert len(st["commands"]) == 4


def test_steering_rejects_nonfinite_lossy_eps():
    """``nan <= 0`` is False — the guard must be isfinite, not a plain
    comparison, or NaN walks straight into the lossy codec."""
    with _steering_session() as s:
        for bad in (float("nan"), float("inf"), -1.0, 0.0, "tight"):
            rec = s._apply_steering("test", {"task": "gh",
                                             "lossy_eps": bad})
            s._steering.append(rec)
            assert "lossy_eps" in rec["rejected"], bad
        # valid value but no checkpoint task bound: ignored, not rejected
        rec = s._apply_steering("test", {"task": "gh", "lossy_eps": 0.5})
        s._steering.append(rec)
        assert rec["ignored"] == {"lossy_eps": 0.5}
        assert rec["rejected"] == {}
        s.emit("grads", 0, {"params": np.zeros(8, np.float32)})
    assert s.report()["steering"]["steering_rejected"] == 5


def test_steering_valid_command_still_applies():
    with _steering_session() as s:
        rec = s._apply_steering("test", {"task": "gh", "every": 4})
        s._steering.append(rec)
        assert rec["applied"] == {"every": 4} and rec["rejected"] == {}
        assert s.runtime.effective_every("gh") == 4
        s.emit("grads", 0, {"params": np.zeros(8, np.float32)})
    st = s.report()["steering"]
    assert st["steering_rejected"] == 0
    assert st["commands"][0]["applied"] == {"every": 4}
