"""Fault tolerance (heartbeat/straggler/remesh) + data pipeline."""
import numpy as np
import pytest

from repro.data.pipeline import BatchSpec, Prefetcher, synth_batch
from repro.distributed.fault import (HeartbeatTracker, StragglerMonitor,
                                     plan_elastic_remesh)


# -- heartbeat ----------------------------------------------------------------

def test_heartbeat_failure_detection():
    hb = HeartbeatTracker([0, 1, 2], grace_s=10.0)
    now = 1000.0
    for h in (0, 1, 2):
        hb.beat(h, now=now)
    hb.beat(0, now=now + 20)
    hb.beat(1, now=now + 20)
    assert hb.failed_hosts(now=now + 20) == [2]
    assert hb.alive_hosts(now=now + 20) == [0, 1]


# -- stragglers -----------------------------------------------------------------

def test_straggler_detection_and_policy():
    mon = StragglerMonitor(alpha=1.0, factor=1.5)
    for h in range(8):
        mon.observe(h, 1.0)
    mon.observe(7, 1.8)          # 1.8x median -> straggler, mild
    assert mon.stragglers() == [7]
    assert mon.mitigation(7) == "reduce_insitu_pi"
    mon.observe(7, 10.0)         # way over -> replace
    assert mon.mitigation(7) == "replace_at_checkpoint"
    assert mon.mitigation(0) == "none"


# -- elastic re-mesh ---------------------------------------------------------------

def test_remesh_shrinks_data_axis_first():
    plan = plan_elastic_remesh((16, 16), ("data", "model"),
                               surviving_devices=240)
    assert plan.new_shape == (15, 16)
    assert plan.model_merge_factor == 1


def test_remesh_merges_tp_when_needed():
    plan = plan_elastic_remesh((16, 16), ("data", "model"),
                               surviving_devices=24)
    d, m = plan.new_shape
    assert d * m <= 24
    assert 16 % m == 0


def test_remesh_multipod_drops_whole_pod():
    plan = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"),
                               surviving_devices=300)
    assert plan.new_shape[0] in (1, 2)
    n = 1
    for s in plan.new_shape:
        n *= s
    assert n <= 300


def test_remesh_impossible_raises():
    with pytest.raises(ValueError):
        plan_elastic_remesh((16, 16), ("data", "model"), surviving_devices=0)


# -- data pipeline ------------------------------------------------------------------

def test_synth_batch_deterministic():
    spec = BatchSpec(4, 64, 50000)
    a = synth_batch(spec, step=7, seed=1)
    b = synth_batch(spec, step=7, seed=1)
    c = synth_batch(spec, step=8, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 50000
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_produces_and_closes():
    spec = BatchSpec(2, 16, 1000)
    pf = Prefetcher(spec, depth=2)
    batches = [next(pf) for _ in range(5)]
    pf.close()
    assert all(b["tokens"].shape == (2, 16) for b in batches)


def test_prefetcher_preprocess_hook():
    spec = BatchSpec(2, 16, 1000)
    pf = Prefetcher(spec, depth=1,
                    preprocess=lambda s, b: {**b, "extra": np.ones(3)})
    b = next(pf)
    pf.close()
    assert "extra" in b


def test_frontend_prefix_in_batch():
    spec = BatchSpec(2, 16, 1000, frontend_tokens=8, d_model=64)
    b = synth_batch(spec, 0)
    assert b["prefix"].shape == (2, 8, 64)
