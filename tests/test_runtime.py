"""PipelineRuntime: placement policies, backpressure, stages, codec registry.

The tentpole contracts of the unified runtime:
  * one scheduler — SYNC / ASYNC / HYBRID are policies, sharded SYNC work
    rides the shared pool (no transient executors)
  * two-phase hand-off — the loop pays only ``handoff/dispatch``; pending
    transfers materialize FIFO on the consumers and fully drain
  * backpressure policies: block (staging/wait), drop (counted), adapt
    (the effective firing period lengthens under sustained pressure)
  * declarative stage chains get per-stage telemetry spans
  * every codec in the unified registry round-trips (exactly, or within
    its declared error bound)
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.core.runtime import (FanoutStage, PipelineRuntime, PipelineTask,
                                Placement, Stage, run_pipeline, split_payload)
from repro.core.telemetry import Telemetry


def _loop(runtime, n, step_s=0.0, payload=None):
    payload = payload if payload is not None else np.zeros(8)

    def app_step(i):
        if step_s:
            time.sleep(step_s)   # device step: host-idle wait
        return {"x": lambda: payload}

    return run_pipeline(n, app_step, runtime)


# -- placement scheduling -----------------------------------------------------

def test_sync_sharded_firings_reuse_the_shared_pool():
    """Sharded SYNC work runs on the persistent insitu-* workers."""
    seen = []

    def work(step, piece):
        seen.append(threading.current_thread().name)
        return piece.sum()

    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=work, placement=Placement.SYNC,
                      shards=4)],
        workers=2)
    _loop(rt, 3, payload=np.ones(64))
    assert len(seen) == 12                       # 3 firings x 4 shards
    assert all(name.startswith("insitu-") for name in seen)
    assert len(set(seen)) <= 2                   # the pool, not new threads
    # the loop still observed each firing as one blocking (sync) result
    assert len(rt.results) == 3
    assert rt.telemetry.total("insitu-sync/") > 0
    before = threading.active_count()
    _loop_again = _loop(rt, 0)                   # no thread growth afterwards
    assert threading.active_count() == before


def test_sync_sharded_results_preserve_shard_order():
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, pc: float(pc[0]),
                      placement=Placement.SYNC, shards=3)],
        workers=2)
    _loop(rt, 1, payload=np.asarray([0.0] * 10 + [1.0] * 10 + [2.0] * 10))
    assert rt.results[0].result == [0.0, 1.0, 2.0]


def test_host_stage_chain_runs_in_order_with_spans():
    order = []

    def stage_a(step, p):
        order.append("a")
        return p + 1

    def stage_b(step, p):
        order.append("b")
        return p * 10

    rt = PipelineRuntime(
        [PipelineTask("chain", "x",
                      host_stages=(Stage("add", stage_a),
                                   Stage("mul", stage_b)),
                      sink=lambda s, p: order.append("sink") or p,
                      placement=Placement.ASYNC)],
        workers=1)
    _loop(rt, 1, payload=np.asarray(2.0))
    assert order == ["a", "b", "sink"]
    assert rt.results[0].result == 30.0
    assert len(rt.telemetry.spans("stage/chain/add")) == 1
    assert len(rt.telemetry.spans("stage/chain/mul")) == 1


def test_device_stage_runs_before_handoff():
    events = []

    rt = PipelineRuntime(
        [PipelineTask("hy", "x",
                      device_stage=lambda s, p: events.append("device") or p,
                      handoff=lambda p: events.append("handoff") or p,
                      sink=lambda s, p: events.append("sink") or None,
                      placement=Placement.HYBRID)],
        workers=1)
    _loop(rt, 1)
    rt.wait_idle()
    assert events == ["device", "handoff", "sink"]
    assert rt.telemetry.total("insitu-device/hy") > 0


# -- two-phase (pipelined) hand-off -------------------------------------------

def test_pipelined_handoff_dispatches_on_loop_materializes_on_worker():
    """ASYNC: loop records only handoff/dispatch; the worker drains the
    transfer (handoff/materialize) and results arrive FIFO, fully drained."""
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: float(p.sum()))],
        workers=1)
    payloads = {i: jnp.arange(8.0) + i for i in range(4)}
    run_pipeline(4, lambda i: {"x": lambda: payloads[i]}, rt)
    assert [r.step for r in rt.results] == [0, 1, 2, 3]   # FIFO, all drained
    assert [r.result for r in rt.results] == [28.0, 36.0, 44.0, 52.0]
    assert not rt.errors
    dispatch = rt.telemetry.spans("handoff/dispatch")
    materialize = rt.telemetry.spans("handoff/materialize")
    assert len(dispatch) == 4 and len(materialize) == 4
    assert all(s.thread == threading.main_thread().name for s in dispatch)
    assert all(s.thread.startswith("insitu-") for s in materialize)
    # nothing blocked the loop beyond the dispatch
    assert rt.telemetry.spans("step/handoff") == []
    rep = rt.report()
    assert rep["handoff_s"] == pytest.approx(rep["handoff_dispatch_s"])


def test_pipelined_hybrid_custom_handoff_runs_on_worker_after_device():
    events = []

    def handoff(p):
        events.append(("handoff", threading.current_thread().name))
        return p * 2

    rt = PipelineRuntime(
        [PipelineTask(
            "hy", "x",
            device_stage=lambda s, p: events.append(
                ("device", threading.current_thread().name)) or p,
            handoff=handoff,
            sink=lambda s, p: float(p.sum()),
            placement=Placement.HYBRID)],
        workers=1)
    run_pipeline(1, lambda i: {"x": lambda: np.ones(4)}, rt)
    assert [e[0] for e in events] == ["device", "handoff"]
    assert events[0][1] == threading.main_thread().name     # device on loop
    assert events[1][1].startswith("insitu-")               # handoff on pool
    assert rt.results[0].result == 8.0


def test_non_pipelined_task_keeps_blocking_handoff():
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: p.sum(),
                      pipelined=False)],
        workers=1)
    run_pipeline(3, lambda i: {"x": lambda: np.ones(4)}, rt)
    blocking = rt.telemetry.spans("step/handoff")
    assert len(blocking) == 3
    assert all(s.thread == threading.main_thread().name for s in blocking)
    assert rt.telemetry.spans("handoff/dispatch") == []


def test_pipelined_handoff_survives_buffer_donation():
    """The dispatch snapshot detaches tokens from donated buffers: a train
    step that donates its input (jit_train_step's default) must not delete
    the payload out from under a pending transfer."""
    import functools

    import jax

    @functools.partial(jax.jit, donate_argnums=0)
    def train_step(x):
        return x + 1.0

    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: float(p.sum()))],
        workers=1, staging_capacity=4)
    x = jnp.ones(8)
    for i in range(4):
        rt.submit(i, {"x": lambda: x})
        x = train_step(x)            # donates the buffer the token holds
    rt.drain()
    assert not rt.errors, rt.errors[:1]
    assert [r.result for r in rt.results] == [8.0, 16.0, 24.0, 32.0]


def test_drain_semantics_pending_transfers_all_materialize():
    """A slow consumer + drain: every dispatched transfer still lands."""
    rt = PipelineRuntime(
        [PipelineTask("t", "x",
                      sink=lambda s, p: time.sleep(0.01) or float(p[0]))],
        workers=1, staging_capacity=2)
    run_pipeline(6, lambda i: {"x": lambda: jnp.full((4,), float(i))}, rt)
    assert sorted(r.result for r in rt.results) == [0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
    assert rt.staging.gets == rt.staging.puts == 6


# -- fan-out host stages ------------------------------------------------------

def _fanout_task(fn, *, placement=Placement.ASYNC, sink=None):
    stage = FanoutStage(
        "enc",
        split=lambda s, p: [(i, v) for i, v in enumerate(p)],
        fn=fn,
        gather=lambda s, p, results: {"orig": p, "results": results})
    return PipelineTask("t", "x", host_stages=(stage,),
                        sink=sink or (lambda s, p: p), placement=placement)


def test_fanout_stage_spreads_items_across_pool_and_orders_results():
    """Items of one firing are stolen by idle workers; gather sees the
    original payload plus results in split order (the barrier contract)."""
    threads = set()
    # rendezvous makes the two-thread assertion deterministic: whichever
    # thread takes item 0 blocks until a *different* thread reaches item 1,
    # so a busy scheduler cannot let the coordinator self-drain everything
    both = threading.Barrier(2, timeout=20)

    def work(step, item):
        i, v = item
        threads.add(threading.current_thread().name)
        if i < 2:
            both.wait()
        return i * 10 + v

    rt = PipelineRuntime([_fanout_task(work)], workers=2)
    payload = list(range(8))
    # submit + wait (not run_pipeline): drain would close the ring before
    # the stage runs, and tokens cannot be advertised on a closed ring
    rt.submit(0, {"x": lambda: payload})
    assert rt.wait_idle(timeout=30.0)
    rt.drain()
    assert not rt.errors, rt.errors[:1]
    out = rt.results[0].result
    assert out["orig"] == payload
    assert out["results"] == [i * 10 + v for i, v in enumerate(payload)]
    assert len(threads) == 2         # coordinator + a stealing worker
    assert len(rt.telemetry.spans("stage/t/enc/item")) == 8
    assert sum(s.name == "stage/t/enc"
               for s in rt.telemetry.spans("stage/t/enc")) == 1


def test_fanout_stage_works_with_a_single_worker():
    """A lone worker coordinates AND executes every item (no deadlock even
    though its steal tokens can never be claimed)."""
    rt = PipelineRuntime([_fanout_task(lambda s, it: it[1] + 1)],
                         workers=1, staging_capacity=1)
    run_pipeline(2, lambda i: {"x": lambda: [1, 2, 3, 4, 5]}, rt)
    assert not rt.errors, rt.errors[:1]
    assert [r.result["results"] for r in rt.results] == [[2, 3, 4, 5, 6]] * 2
    # tokens never occupy the ring's last free slot: on a capacity-1 ring
    # no steal token was ever put (only the 2 firings themselves)
    assert rt.staging.puts == 2


def test_fanout_stage_under_sync_placement_runs_on_the_pool_too():
    """SYNC: the loop thread coordinates; registration still spins up the
    pool so items can be stolen."""
    rt = PipelineRuntime(
        [_fanout_task(lambda s, it: it[1] * 2, placement=Placement.SYNC)],
        workers=2)
    assert rt._threads            # pool exists despite SYNC placement
    run_pipeline(1, lambda i: {"x": lambda: [3, 4]}, rt)
    assert rt.results[0].result["results"] == [6, 8]


def test_fanout_stage_empty_split_gathers_empty():
    rt = PipelineRuntime([_fanout_task(lambda s, it: 1 / 0)], workers=1)
    run_pipeline(1, lambda i: {"x": lambda: []}, rt)
    assert not rt.errors
    assert rt.results[0].result["results"] == []


def test_fanout_stage_item_error_fails_the_firing():
    def work(step, item):
        if item[0] == 2:
            raise RuntimeError("leaf 2 exploded")
        return item[1]

    rt = PipelineRuntime([_fanout_task(work)], workers=2)
    run_pipeline(1, lambda i: {"x": lambda: [0, 1, 2, 3]}, rt)
    assert len(rt.errors) == 1
    assert "leaf 2 exploded" in str(rt.errors[0][2])
    assert rt.results == []


# -- split_payload ------------------------------------------------------------

def test_split_payload_shards_pytree_leaves_on_leading_axis():
    tree = {"a": np.arange(10), "b": np.ones((10, 3))}
    parts = split_payload(tree, 2)
    assert len(parts) == 2
    assert parts[0]["a"].shape == (5,) and parts[1]["b"].shape == (5, 3)
    np.testing.assert_array_equal(
        np.concatenate([p["a"] for p in parts]), tree["a"])


def test_split_payload_rejects_unshardable_leaves():
    with pytest.raises(ValueError, match="leading axis"):
        split_payload({"a": 3.0}, 2)
    with pytest.raises(ValueError, match="0-d"):
        split_payload(np.asarray(1.0), 2)


def test_split_payload_rejects_undersized_leading_axis():
    """A leading axis shorter than the shard count would silently produce
    empty shards (np.array_split pads with empties) — raise instead."""
    with pytest.raises(ValueError, match="non-empty"):
        split_payload(np.ones(2), 4)
    with pytest.raises(ValueError, match="non-empty"):
        split_payload({"a": np.ones((1, 8))}, 4)


def test_sharded_async_pytree_firing_runs_each_shard():
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: float(p["a"].sum()),
                      placement=Placement.ASYNC, shards=2)],
        workers=2)
    run_pipeline(1, lambda i: {"x": lambda: {"a": np.ones(10)}}, rt)
    assert sorted(r.result for r in rt.results) == [5.0, 5.0]
    # sharded firings materialize on the loop (a token cannot be split)
    assert len(rt.telemetry.spans("step/handoff")) == 1


# -- telemetry: per-thread span buffers ---------------------------------------

def test_telemetry_concurrent_recording_is_complete_and_ordered():
    tm = Telemetry()
    n_threads, per_thread = 4, 300

    def writer(k):
        for i in range(per_thread):
            tm.record(f"x/{k}", float(i), float(i) + 0.5)

    threads = [threading.Thread(target=writer, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tm.spans("x/")
    assert len(spans) == n_threads * per_thread
    assert [s.t0 for s in spans] == sorted(s.t0 for s in spans)
    assert tm.total("x/") == pytest.approx(0.5 * n_threads * per_thread)
    tm.reset()
    assert tm.spans() == []


# -- backpressure policies ----------------------------------------------------

def _pressured(policy, *, n=12, workers=1, task_s=0.03, every=1):
    rt = PipelineRuntime(
        [PipelineTask("t", "x",
                      sink=lambda s, p: time.sleep(task_s),
                      placement=Placement.ASYNC, every=every,
                      backpressure=policy, adapt_after=2)],
        workers=workers, staging_capacity=1)
    _loop(rt, n, step_s=0.001)
    return rt

def test_block_policy_records_staging_wait():
    rt = _pressured("block")
    assert rt.telemetry.total("staging/wait") > 0
    assert len(rt.results) == 12                 # nothing lost
    assert rt.drops["t"] == 0


def test_drop_policy_counts_drops_and_never_stalls():
    rt = _pressured("drop")
    assert rt.drops["t"] > 0
    assert len(rt.results) + rt.drops["t"] == 12
    assert rt.telemetry.counters()["staging/drop/t"] == rt.drops["t"]
    # a dropping producer must not have blocked on the ring
    assert rt.telemetry.total("staging/wait") == 0


def test_adapt_policy_lengthens_every_under_sustained_pressure():
    rt = _pressured("adapt", n=24)
    assert rt.effective_every("t") > 1           # the runtime backed off
    assert rt.report()["effective_every"]["t"] == rt.effective_every("t")
    # adapted-but-delivered: every accepted firing still produced a result
    assert len(rt.results) == rt.staging.puts


def test_adapt_policy_is_quiet_without_pressure():
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: None,
                      placement=Placement.ASYNC, backpressure="adapt")],
        workers=2, staging_capacity=8)
    _loop(rt, 10, step_s=0.002)
    assert rt.effective_every("t") == 1

def test_bad_backpressure_policy_rejected():
    with pytest.raises(ValueError):
        PipelineTask("t", "x", sink=lambda s, p: None, backpressure="shrug")


def test_duplicate_registration_rejected():
    rt = PipelineRuntime(
        [PipelineTask("t", "x", sink=lambda s, p: None)], workers=1)
    with pytest.raises(ValueError):
        rt.register(PipelineTask("t", "x", sink=lambda s, p: None))
    rt.drain()


# -- codec registry -----------------------------------------------------------

def _smooth_signal(n=4096, seed=0):
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 8 * np.pi, n)
    return (np.sin(t) + 0.3 * np.sin(5.1 * t)
            + 0.01 * rng.standard_normal(n)).astype(np.float32)


@pytest.mark.parametrize("name", compression.available())
def test_registry_roundtrip_every_codec(name):
    codec = compression.get(name)
    x = _smooth_signal()
    blob = codec.encode(x)
    out = np.asarray(codec.decode(blob))
    if codec.lossy:
        out = out.ravel()[: x.size].reshape(x.shape)
        rel = float(np.linalg.norm(out - x) / np.linalg.norm(x))
        assert rel <= codec.error_bound(), (name, rel)
    else:
        np.testing.assert_array_equal(out, x)
        assert out.dtype == x.dtype


def test_registry_knows_lossless_from_lossy():
    names = set(compression.available())
    assert {"zlib", "bz2", "none"} <= set(compression.available(lossy=False))
    assert {"spectral", "int8-ef"} <= set(compression.available(lossy=True))
    assert (set(compression.available(lossy=False))
            | set(compression.available(lossy=True))) == names


def test_registry_unknown_codec_message():
    with pytest.raises(KeyError, match="available"):
        compression.get("nope")


def test_registry_rejects_duplicate_names():
    class Dummy:
        name = "zlib"
        lossy = False
        def encode(self, arr): return b""
        def decode(self, blob): return np.zeros(1)

    with pytest.raises(ValueError):
        compression.register(Dummy())
