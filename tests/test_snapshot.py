"""SnapshotStore behavior: chains, no-op hints, stats, session preset."""
import numpy as np
import pytest

from repro.insitu import InSituPlan, Placement, Session, TaskSpec
from repro.serving.snapshot import SnapshotCorruptError, SnapshotStore


def _slab(rng, n=20000):
    return {"k": rng.standard_normal(n).astype(np.float32),
            "v": rng.standard_normal(n).astype(np.float32)}


def _mutate(slab, rng, frac=0.05):
    n = slab["k"].size
    k = max(1, int(n * frac))
    at = int(rng.integers(0, n - k))
    for arr in slab.values():
        arr[at:at + k] = rng.standard_normal(k)


def test_base_delta_cadence_and_restore(tmp_path):
    rng = np.random.default_rng(0)
    slab = _slab(rng)
    store = SnapshotStore(str(tmp_path), base_every=3, chunk_bytes=1 << 12)
    snaps = []
    for i in range(7):
        _mutate(slab, rng)
        rec = store.publish("kv", i, slab)
        snaps.append({k: a.copy() for k, a in slab.items()})
        assert rec.kind == ("base" if i % 3 == 0 else "delta")
        assert rec.chain_pos == i % 3
    # newest and every intermediate chain position restore bit-identically
    for seq, snap in enumerate(snaps):
        step, leaves = store.restore("kv", upto=seq)
        assert step == seq
        for key, arr in snap.items():
            np.testing.assert_array_equal(leaves[f"['{key}']"], arr)
    st = store.stats("kv")
    assert st["bases"] == 3 and st["deltas"] == 4
    assert st["chain_depth"] == 0   # 7th publish (seq 6) opened a new chain
    # deltas must store far less than re-publishing full bases would
    assert st["stored_bytes"] < st["raw_bytes"]


def test_memory_store_roundtrip():
    rng = np.random.default_rng(1)
    slab = _slab(rng)
    store = SnapshotStore(None, base_every=4)
    for i in range(5):
        _mutate(slab, rng)
        store.publish("kv", i, slab)
    step, tree = store.restore("kv", template=slab)
    assert step == 4
    for key, arr in slab.items():
        np.testing.assert_array_equal(tree[key], arr)


def test_version_hint_short_circuits_to_noop():
    rng = np.random.default_rng(2)
    slab = _slab(rng)
    store = SnapshotStore(None, base_every=100)
    r0 = store.publish("kv", 0, slab, version=7)
    r1 = store.publish("kv", 1, slab, version=7)     # unchanged: no-op
    _mutate(slab, rng)
    r2 = store.publish("kv", 2, slab, version=8)
    assert (r0.kind, r1.kind, r2.kind) == ("base", "noop", "delta")
    assert r1.stored_bytes < 100                     # marker frame only
    assert r1.raw_bytes == r0.raw_bytes              # still represents the slab
    assert r1.ratio > 0.999                          # near-free firing
    step, leaves = store.restore("kv")
    assert step == 2
    np.testing.assert_array_equal(leaves["['k']"], slab["k"])
    # restoring up to the no-op frame yields the frame-0 snapshot state
    step, leaves = store.restore("kv", upto=1)
    assert step == 1


def test_idle_stream_noops_past_base_cadence(tmp_path):
    """An unchanged slab never pays a re-encode — not even when the base
    cadence expires — and consecutive no-ops collapse into ONE tip frame,
    so an idle stream's frame count stays bounded."""
    rng = np.random.default_rng(7)
    slab = {"x": rng.standard_normal(2000).astype(np.float32)}
    store = SnapshotStore(str(tmp_path), base_every=3)
    kinds = [store.publish("kv", i, slab, version=1).kind for i in range(6)]
    assert kinds == ["base"] + ["noop"] * 5          # idle: no re-encode
    assert store.published("kv") == [0, 1]           # noops collapsed
    step, leaves = store.restore("kv")
    assert step == 5                                 # tip carries last step
    np.testing.assert_array_equal(leaves["['x']"], slab["x"])
    # the next *changed* publish chains on (the collapsed chain is short,
    # so this is a cheap delta, not a forced base re-encode)
    slab["x"][:50] = 0.0
    rec = store.publish("kv", 6, slab, version=2)
    assert rec.kind == "delta" and rec.seq == 2
    step, leaves = store.restore("kv")
    assert step == 6
    np.testing.assert_array_equal(leaves["['x']"], slab["x"])
    # a fresh reader replays the collapsed chain from disk too
    step, leaves = SnapshotStore(str(tmp_path),
                                 base_every=3).restore("kv")
    assert step == 6


def test_out_of_order_publish_is_skipped_as_stale():
    """Concurrent pool workers can drain firings out of order; a late
    older-step publish must not become the chain tip."""
    rng = np.random.default_rng(8)
    slab = {"x": rng.standard_normal(2000).astype(np.float32)}
    store = SnapshotStore(None, base_every=4)
    store.publish("kv", 8, slab)
    newest = slab["x"].copy()
    old = {"x": np.zeros(2000, np.float32)}
    rec = store.publish("kv", 4, old)                # late firing
    assert rec.kind == "stale" and rec.stored_bytes == 0
    step, leaves = store.restore("kv")
    assert step == 8
    np.testing.assert_array_equal(leaves["['x']"], newest)
    assert store.stats("kv")["stale_skipped"] == 1
    # equal-step re-publish is allowed (writer restart semantics)
    assert store.publish("kv", 8, slab).kind == "delta"


@pytest.mark.parametrize("directory", [False, True])
def test_keep_chains_retention_prunes_retired_chains(tmp_path, directory):
    rng = np.random.default_rng(9)
    slab = {"x": rng.standard_normal(2000).astype(np.float32)}
    store = SnapshotStore(str(tmp_path) if directory else None,
                          base_every=2, keep_chains=2)
    for i in range(9):                   # bases at seq 0, 2, 4, 6, 8
        slab["x"][i * 10:(i + 1) * 10] = rng.standard_normal(10)
        store.publish("kv", i, slab)
    kept = store.published("kv")
    assert kept[0] == 6                  # chains behind base 6 pruned
    assert kept[-1] == 8
    step, leaves = store.restore("kv")   # live chain unaffected
    assert step == 8
    np.testing.assert_array_equal(leaves["['x']"], slab["x"])
    with pytest.raises(KeyError, match="no published snapshots"):
        store.restore("kv", upto=3)      # pruned prefix is gone


def test_publish_owns_its_base_despite_inplace_mutation():
    """The caller may mutate its slab buffer in place between publishes;
    the store must delta against the *published* bytes, not the alias."""
    rng = np.random.default_rng(3)
    slab = {"x": rng.standard_normal(5000).astype(np.float32)}
    store = SnapshotStore(None, base_every=10, chunk_bytes=1 << 10)
    snaps = []
    for i in range(4):
        slab["x"][i * 100:(i + 1) * 100] = rng.standard_normal(100)
        store.publish("kv", i, slab)    # same ndarray object every time
        snaps.append(slab["x"].copy())
    for seq, snap in enumerate(snaps):
        _, leaves = store.restore("kv", upto=seq)
        np.testing.assert_array_equal(leaves["['x']"], snap)


def test_tree_shape_change_falls_back_and_template_drift_raises(tmp_path):
    rng = np.random.default_rng(4)
    store = SnapshotStore(str(tmp_path), base_every=10)
    store.publish("kv", 0, {"a": rng.standard_normal(100).astype(np.float32)})
    grown = {"a": rng.standard_normal(200).astype(np.float32),
             "b": rng.standard_normal(50).astype(np.float32)}
    rec = store.publish("kv", 1, grown)      # resized leaf + new leaf
    assert rec.kind == "delta"
    _, leaves = store.restore("kv")
    np.testing.assert_array_equal(leaves["['a']"], grown["a"])
    np.testing.assert_array_equal(leaves["['b']"], grown["b"])
    with pytest.raises(KeyError, match="drifted"):
        store.restore("kv", template={"a": grown["a"], "zz": grown["b"]})


def test_bfloat16_leaves_roundtrip(tmp_path):
    """The serving KV cache is bf16 on every arch config — extension
    dtypes must survive the delta frame's dtype token."""
    import ml_dtypes

    rng = np.random.default_rng(11)
    slab = {"k": rng.standard_normal(4096).astype(ml_dtypes.bfloat16)}
    store = SnapshotStore(str(tmp_path), base_every=2)
    for i in range(3):
        slab["k"][i * 100:(i + 1) * 100] = rng.standard_normal(100)
        store.publish("kv", i, slab)
    step, tree = SnapshotStore(str(tmp_path), base_every=2).restore(
        "kv", template=slab)
    assert step == 2
    assert tree["k"].dtype == slab["k"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        tree["k"].view(np.uint16), slab["k"].view(np.uint16))


def test_restore_empty_stream_raises_keyerror(tmp_path):
    store = SnapshotStore(str(tmp_path))
    with pytest.raises(KeyError, match="no published snapshots"):
        store.restore("kv")


def test_bad_base_every_and_codec_rejected(tmp_path):
    with pytest.raises(ValueError, match="base_every"):
        SnapshotStore(str(tmp_path), base_every=0)
    with pytest.raises(KeyError, match="inner codec"):
        SnapshotStore(str(tmp_path), codec="nope")


# -- the serve_snapshot preset end to end -------------------------------------

def test_serve_snapshot_preset_publishes_and_reports():
    rng = np.random.default_rng(5)
    slab = _slab(rng, n=5000)
    version = [0]
    plan = InSituPlan(
        streams=["kv_pages"],
        tasks=[TaskSpec(name="snap", stream="kv_pages",
                        preset="serve_snapshot",
                        options={"base_every": 3},
                        placement=Placement.SYNC)])
    with Session(plan) as s:
        for i in range(6):
            if i % 2 == 0:               # mutate on even steps only
                _mutate(slab, rng)
                version[0] += 1
            s.emit("kv_pages", i,
                   {"cache": slab, "version": version[0]})
    rep = s.report()
    snap = rep["tasks"]["snap"]
    assert snap["results"] == 6
    assert snap["publishes"] == 6
    assert snap["bases"] == 2            # base_every=3 over 6 firings
    assert snap["noops"] > 0             # odd steps were unchanged
    assert snap["effective_compression_x"] > 1.0
    assert "chain_depth" in snap and "delta_ratio" in snap
    # the store is reachable for restore / chain inspection
    store = s.snapshot_store("snap")
    step, tree = store.restore("kv_pages", template=slab)
    assert step == 5
    for key, arr in slab.items():
        np.testing.assert_array_equal(tree[key], arr)


def test_serve_snapshot_preset_rejects_unknown_options():
    """Legacy options of the pre-delta probe (sample_elems) must fail
    loudly, not silently change semantics."""
    from repro.insitu import PlanError
    plan = InSituPlan(
        streams=["kv"],
        tasks=[TaskSpec(name="snap", stream="kv", preset="serve_snapshot",
                        options={"sample_elems": 65536})])
    with pytest.raises(PlanError, match=r"snap.*sample_elems"):
        Session(plan)


def test_snapshot_store_accessor_unknown_task():
    from repro.insitu import PlanError
    plan = InSituPlan(streams=["x"],
                      tasks=[TaskSpec(name="t", stream="x", sink=print)])
    with Session(plan) as s:
        pass
    with pytest.raises(PlanError, match="no snapshot store"):
        s.snapshot_store("t")
