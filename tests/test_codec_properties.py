"""Round-trip property suite over EVERY codec in the compression registry.

Two layers, one contract:

  * deterministic parametrized coverage of the named payload classes
    (empty, 1-byte, incompressible-random, highly-repetitive,
    larger-than-chunk) for every registered codec — always runs;
  * hypothesis-randomized round-trips (via the optional ``_hyp`` shim):
    arbitrary payloads through the lossless codecs, randomized base/target
    pairs through the ``delta`` codec, and v1/v2 frame cross-decoding.

Lossless codecs (including ``delta``, which self-contains when encoded
without a base) must round-trip bit-exactly with shape+dtype preserved;
lossy codecs must preserve shape and honour their declared
``error_bound()`` (relative L2).
"""
import struct

import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.core import codecs, compression, delta


def _payload(kind: str, *, floats: bool, seed: int = 0) -> np.ndarray:
    """The named payload classes; ``floats`` picks the float32 variants
    (lossy codecs are defined over float data)."""
    rng = np.random.default_rng(seed)
    if kind == "empty":
        return np.empty((0,), np.float32 if floats else np.int8)
    if kind == "one-byte":
        if floats:
            return np.asarray([2.5], np.float32)      # one-element payload
        return np.asarray([7], np.int8)               # literally one byte
    if kind == "incompressible":
        if floats:
            return rng.standard_normal(4096).astype(np.float32)
        return rng.integers(-128, 128, size=16384).astype(np.int8)
    if kind == "repetitive":
        if floats:
            return np.tile(np.linspace(0, 1, 32, dtype=np.float32), 512)
        return np.tile(np.arange(16, dtype=np.int8), 1024)
    if kind == "larger-than-chunk":
        # > DEFAULT_CHUNK (1 MiB) raw bytes => multi-chunk frame
        n = (codecs.DEFAULT_CHUNK // 4) + 4096
        return rng.standard_normal(n).astype(np.float32)
    raise KeyError(kind)


PAYLOAD_KINDS = ("empty", "one-byte", "incompressible", "repetitive",
                 "larger-than-chunk")


def _rel_l2(out: np.ndarray, ref: np.ndarray) -> float:
    denom = float(np.linalg.norm(ref.astype(np.float64).ravel()))
    if denom == 0.0:
        return 0.0
    return float(np.linalg.norm(
        out.astype(np.float64).ravel()
        - ref.astype(np.float64).ravel())) / denom


@pytest.mark.parametrize("kind", PAYLOAD_KINDS)
@pytest.mark.parametrize("name", compression.available())
def test_registry_roundtrip_payload_classes(name, kind):
    codec = compression.get(name)
    arr = _payload(kind, floats=codec.lossy)
    out = codec.decode(codec.encode(arr))
    assert out.shape == arr.shape
    if not codec.lossy:
        np.testing.assert_array_equal(out, arr)
        assert out.dtype == arr.dtype
    else:
        assert _rel_l2(out, arr) <= codec.error_bound()


@pytest.mark.parametrize("kind", PAYLOAD_KINDS)
def test_delta_roundtrip_against_base_payload_classes(kind):
    """Every payload class as a (base, target) pair: mutate a slice of the
    base and delta-encode the result against it."""
    base = _payload(kind, floats=True)
    target = base.copy()
    if target.size:
        target[: max(1, target.size // 8)] += 1.0
    blob, stats = delta.encode(target, base, chunk_bytes=1 << 12)
    needs = delta.frame_needs_base(blob)
    out = delta.decode(blob, base if needs else None)
    np.testing.assert_array_equal(out, target)
    assert out.dtype == target.dtype
    assert stats.raw_bytes == target.nbytes


# -- hypothesis-randomized round-trips ---------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=4000),
    seed=st.integers(min_value=0, max_value=999),
    codec=st.sampled_from(["zlib", "zlib1", "bz2", "lzma", "none", "delta"]),
    chunk=st.sampled_from([257, 1 << 12, codecs.DEFAULT_CHUNK]),
)
def test_lossless_roundtrip_property(n, seed, codec, chunk):
    """Any byte payload, any chunking, through every lossless codec
    (``delta`` encodes self-contained here: no base)."""
    r = np.random.default_rng(seed)
    arr = r.integers(-128, 127, size=n).astype(np.int8)
    if codec == "delta":
        out = delta.decode(delta.encode(arr, None, chunk_bytes=chunk)[0])
    else:
        out = codecs.decode(codecs.encode(arr, codec, chunk_bytes=chunk)[0])
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=5000),
    seed=st.integers(min_value=0, max_value=999),
    frac=st.floats(min_value=0.0, max_value=1.0),
    same_size=st.booleans(),
    chunk=st.sampled_from([257, 1 << 12]),
)
def test_delta_base_target_pairs_property(n, seed, frac, same_size, chunk):
    """Randomized base/target pairs: a mutated slice (append-mostly when
    ``frac`` is small, full rewrite at 1.0), and size-mismatched bases
    (which must fall back to self-contained frames)."""
    r = np.random.default_rng(seed)
    base = r.integers(-128, 127, size=n).astype(np.int8)
    if same_size:
        target = base.copy()
        k = int(n * frac)
        if k:
            target[n - k:] = r.integers(-128, 127, size=k)
    else:
        target = r.integers(-128, 127, size=n + 17).astype(np.int8)
    blob, stats = delta.encode(target, base, chunk_bytes=chunk)
    if delta.frame_needs_base(blob):
        out = delta.decode(blob, base)
        # a frame that references its base must refuse the wrong one
        with pytest.raises(delta.DeltaBaseMismatch):
            delta.decode(blob, None)
        if n:
            with pytest.raises(delta.DeltaBaseMismatch):
                delta.decode(blob, np.zeros(n + 3, np.int8))
    else:
        out = delta.decode(blob)
    np.testing.assert_array_equal(out, target)
    assert stats.n_copy + stats.n_xor + stats.n_self == -(-target.nbytes
                                                          // chunk)


def test_delta_bfloat16_dtype_token_roundtrip():
    """bfloat16's np.dtype .str is a void token ('<V2'); the delta frame
    must record it by name and restore the real dtype."""
    import ml_dtypes

    rng = np.random.default_rng(0)
    base = rng.standard_normal(1000).astype(ml_dtypes.bfloat16)
    target = base.copy()
    target[:64] = rng.standard_normal(64)
    blob, _ = delta.encode(target, base, chunk_bytes=256)
    out = delta.decode(blob, base)
    assert out.dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(out.view(np.uint16),
                                  target.view(np.uint16))
    out = delta.decode(delta.encode(target)[0])      # self-contained too
    assert out.dtype == ml_dtypes.bfloat16


def test_codecs_bfloat16_dtype_token_roundtrip():
    """The shared lossless framing records extension dtypes by name too."""
    import ml_dtypes

    rng = np.random.default_rng(1)
    arr = rng.standard_normal(2048).astype(ml_dtypes.bfloat16)
    out = codecs.decode(codecs.encode(arr, "zlib")[0])
    assert out.dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(out.view(np.uint16), arr.view(np.uint16))


def _encode_v1(arr: np.ndarray, codec: str = "zlib") -> bytes:
    """The legacy pre-chunking frame layout, byte-for-byte."""
    import zlib as _zlib
    comp = {"zlib": lambda b: _zlib.compress(b, 6),
            "none": lambda b: b}[codec]
    cid = {"zlib": 1, "none": 0}[codec]
    arr = np.ascontiguousarray(arr)
    raw = arr.tobytes()
    dt = np.dtype(arr.dtype).str.encode()
    return (codecs.MAGIC + struct.pack("<BBB", 1, cid, len(dt)) + dt
            + struct.pack("<B", arr.ndim)
            + struct.pack(f"<{arr.ndim}q", *arr.shape)
            + struct.pack("<q", len(raw)) + comp(raw))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=4000),
    seed=st.integers(min_value=0, max_value=999),
    codec=st.sampled_from(["zlib", "none"]),
)
def test_v1_v2_frame_cross_decoding_property(n, seed, codec):
    """The same array through the legacy v1 frame and the chunked v2 frame
    must decode identically (old snapshots/checkpoints restore unchanged)."""
    r = np.random.default_rng(seed)
    arr = (r.standard_normal(n) * 50).astype(np.float32)
    from_v1 = codecs.decode(_encode_v1(arr, codec))
    from_v2 = codecs.decode(codecs.encode(arr, codec, chunk_bytes=1 << 12)[0])
    np.testing.assert_array_equal(from_v1, from_v2)
    np.testing.assert_array_equal(from_v1, arr)
    assert from_v1.dtype == from_v2.dtype == arr.dtype


# ---------------------------------------------------------------------------
# two-level threshold selection: bin-edge identity on every payload class
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", PAYLOAD_KINDS)
@pytest.mark.parametrize("eps", [1e-3, 1e-2, 1e-1, 1.0, 2.0])
def test_two_level_selector_bin_edge_identical_payload_classes(kind, eps):
    """The coarse-32 + refine-16 selector must pick the same quantized bin
    edge as the flat 512-bin selector on every payload class (including
    eps >= 1 drop-everything), so spectral_compress outputs stay
    bit-identical across the kernel rework."""
    import jax.numpy as jnp

    from repro.kernels import ref as kref

    arr = _payload(kind, floats=True)
    if arr.size == 0:
        pytest.skip("blockize is undefined for empty tensors")
    x = jnp.asarray(arr)
    y = kref.dct_blocks(kref.blockize(x)[0])
    _, energies = kref.energy_histogram(y)
    t_flat = kref.threshold_from_histogram(energies, eps)
    t_two = kref.threshold_two_level(y, eps)
    np.testing.assert_array_equal(np.asarray(t_flat), np.asarray(t_two))
    c_flat = kref.compress(x, eps)
    c_two = kref.compress(x, eps, selector="two_level")
    np.testing.assert_array_equal(np.asarray(c_flat.q), np.asarray(c_two.q))
    np.testing.assert_array_equal(np.asarray(c_flat.scale),
                                  np.asarray(c_two.scale))


# ---------------------------------------------------------------------------
# streamed chunk-aligned lossy framing == monolithic framing, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [300,                       # single chunk
                               (1 << 20) + 70_000])       # multi-chunk q
def test_streamed_chunked_lossy_frame_byte_identical(n):
    """The fused quantize+chunking path (device-sliced q chunks framed as
    they land) must produce the exact bytes of the monolithic path — the
    frame is the checkpoint wire format, so this is a hard contract."""
    import jax.numpy as jnp

    from repro.core import lossy

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    blob_plain, st_plain = lossy.compress_tensor(x, 1e-2, stream=False)
    blob_stream, st_stream = lossy.compress_tensor(x, 1e-2, stream=True)
    assert blob_stream == blob_plain
    assert st_stream == st_plain
    pool = codecs.codec_pool()
    blob_pool, _ = lossy.compress_tensor(x, 1e-2, stream=True, pool=pool)
    assert blob_pool == blob_plain
    rt = np.asarray(lossy.decompress_tensor(blob_stream))
    rt_plain = np.asarray(lossy.decompress_tensor(blob_plain))
    np.testing.assert_array_equal(rt, rt_plain)


def test_assemble_frame_matches_encode():
    """assemble_frame over self-compressed chunk payloads reproduces
    encode()'s frame bytes exactly."""
    rng = np.random.default_rng(6)
    arr = rng.integers(-120, 120, size=300_000).astype(np.int8)
    chunk = 1 << 16
    blob, _ = codecs.encode(arr, "zlib", chunk_bytes=chunk)
    _, comp, _ = codecs.compressor("zlib")
    mv = memoryview(arr)
    payloads = [comp(mv[o:o + chunk]) for o in range(0, arr.nbytes, chunk)]
    rebuilt = codecs.assemble_frame("zlib", arr.dtype, arr.shape,
                                    arr.nbytes, chunk, payloads)
    assert rebuilt == blob
    with pytest.raises(KeyError):
        codecs.compressor("nope")
