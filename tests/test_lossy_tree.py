"""core/lossy: pytree compression, policies, framed-blob roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.core import lossy


def _tree(rng):
    return {
        "w": jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32)),
        "opt": {"mu": jnp.asarray(rng.standard_normal(512)
                                  .astype(np.float32)),
                "nu": jnp.asarray(np.abs(rng.standard_normal(512))
                                  .astype(np.float32))},
    }


def test_policy_selects_moments_only(rng):
    tree = _tree(rng)
    blobs, stats = lossy.compress_tree(tree, eps=1e-2)
    lossy_keys = {k for k, b in blobs.items() if b[:4] == lossy.LOSSY_MAGIC}
    assert lossy_keys == {"['opt']['mu']", "['opt']['nu']"}


def test_restore_tree_structure_and_errors(rng):
    tree = _tree(rng)
    blobs, _ = lossy.compress_tree(tree, eps=1e-2)
    rt = lossy.restore_tree(tree, blobs)
    assert jax.tree_util.tree_structure(rt) == \
        jax.tree_util.tree_structure(tree)
    np.testing.assert_array_equal(np.asarray(rt["w"]), np.asarray(tree["w"]))
    rel = float(jnp.linalg.norm(rt["opt"]["mu"] - tree["opt"]["mu"])
                / jnp.linalg.norm(tree["opt"]["mu"]))
    assert rel <= lossy.error_bound(1e-2) + 1e-5


def test_frame_roundtrip_bf16():
    x = jnp.asarray(np.linspace(-2, 2, 777), dtype=jnp.bfloat16)
    blob, st_ = lossy.compress_tensor(x, eps=1e-2)
    y = lossy.decompress_tensor(blob)
    assert y.dtype == jnp.bfloat16 and y.shape == x.shape
    err = float(jnp.max(jnp.abs((y - x).astype(jnp.float32))))
    assert err < 0.1


def test_measure_flag_reports_error(rng):
    x = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    _, st_ = lossy.compress_tensor(x, eps=1e-1, measure=True)
    assert st_.rel_l2_error is not None
    assert st_.rel_l2_error <= lossy.error_bound(1e-1) + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999),
       eps=st.sampled_from([1e-1, 1e-2]),
       lossless=st.sampled_from(["zlib", "bz2"]))
def test_tensor_blob_property(seed, eps, lossless):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(rng.integers(1, 2000))
                    .astype(np.float32))
    blob, st_ = lossy.compress_tensor(x, eps=eps, lossless=lossless)
    y = lossy.decompress_tensor(blob)
    assert y.shape == x.shape
    num = float(jnp.linalg.norm(y - x))
    den = max(float(jnp.linalg.norm(x)), 1e-30)
    assert num / den <= lossy.error_bound(eps) + 1e-4
