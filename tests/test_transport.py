"""The transport layer's contract, wire-level and end-to-end.

Three layers:

  * deterministic frame/payload round-trips and corruption cases — every
    failure mode a reader can hit (truncation, bit flips, magic damage,
    seq gaps across reconnects) must surface as a typed error *naming the
    stream and step*, never a silent skip or a bare struct.error;
  * socketpair round-trips through the real ``StreamSink``/``StreamSource``
    wire path, including interleaved streams, the steering back-channel,
    and reconnect gap detection;
  * hypothesis-randomized frames and payload trees (via the optional
    ``_hyp`` shim) through pack/parse and pack_payload/unpack_payload.

Plus the refactor's parity contract: a preset terminal behaves identically
whether its task sinks to a legacy callable, ``memory://``, or
``file://`` — sinks are interchangeable terminals, which is the point.
"""
import dataclasses
import os
import socket
import struct
import zlib

import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.core import transport
from repro.core.runtime import TransientError
from repro.core.transport import (CODEC_FILE, CODEC_RAW, CODEC_TREE,
                                  CallableSink, FileSink, FileSource, Frame,
                                  FrameCorruptError, MemorySink,
                                  StreamGapError, StreamSink, StreamSource,
                                  TransportError, as_sink, connect,
                                  pack_frame, pack_payload, parse_body,
                                  unpack_payload)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((8, 4)).astype(np.float32),
        "meta": {"step": 7, "tag": "x", "ok": True, "none": None},
        "ints": np.arange(13, dtype=np.int32),
        "blob": b"\x00\x01raw",
        "list": [1.5, "two", [3]],
    }


def _assert_tree_equal(a, b):
    assert sorted(a) == sorted(b)
    np.testing.assert_array_equal(a["w"], b["w"])
    assert a["w"].dtype == b["w"].dtype
    np.testing.assert_array_equal(a["ints"], b["ints"])
    assert b["meta"] == a["meta"]
    assert b["blob"] == a["blob"]
    assert b["list"] == [1.5, "two", [3]]


# ---------------------------------------------------------------------------
# payload packing
# ---------------------------------------------------------------------------

def test_pack_payload_round_trip():
    out = unpack_payload(pack_payload(_tree()))
    _assert_tree_equal(_tree(), out)


def test_pack_payload_dataclass_and_scalars():
    @dataclasses.dataclass
    class Report:
        name: str
        value: float

    packed = pack_payload({"r": Report("gn", 2.5), "s": np.float32(1.25)})
    out = unpack_payload(packed)
    assert out["r"] == {"__dataclass__": "Report",
                        "fields": {"name": "gn", "value": 2.5}}
    assert out["s"] == 1.25          # np scalars become plain floats


def test_pack_payload_rejects_unknown_leaf():
    with pytest.raises(TypeError, match="cannot pack payload leaf"):
        pack_payload({"x": object()})


def test_pack_file_round_trip():
    payload = transport.pack_file("a/b.bin", b"\x00\xffdata")
    assert transport.unpack_file(payload) == ("a/b.bin", b"\x00\xffdata")


# ---------------------------------------------------------------------------
# wire frames: round-trip + every corruption mode, typed and attributed
# ---------------------------------------------------------------------------

def _wire(frame):
    return pack_frame(frame)


def _body(frame):
    return pack_frame(frame)[4:]


def test_frame_round_trip():
    f = Frame("grads", 42, 3, CODEC_TREE, b"payload")
    out = parse_body(_body(f))
    assert out == f


def test_frame_truncated_header():
    with pytest.raises(FrameCorruptError, match="truncated frame header"):
        parse_body(b"RPTF\x01")


def test_frame_bad_magic():
    body = bytearray(_body(Frame("s", 1, 0, CODEC_RAW, b"x")))
    body[:4] = b"JUNK"
    with pytest.raises(FrameCorruptError, match="bad frame magic"):
        parse_body(bytes(body))


def test_frame_truncated_body_names_stream_and_step():
    body = _body(Frame("kv_pages", 99, 0, CODEC_RAW, b"0123456789"))
    with pytest.raises(FrameCorruptError, match="truncated frame body") as ei:
        parse_body(body[:-3])
    assert "kv_pages" in str(ei.value)
    assert "step 99" in str(ei.value)
    assert ei.value.stream == "kv_pages" and ei.value.step == 99


def test_frame_bit_flip_names_stream_and_step():
    body = bytearray(_body(Frame("grads", 17, 5, CODEC_RAW, b"payload")))
    body[-1] ^= 0x40                       # flip one payload bit
    with pytest.raises(FrameCorruptError, match="crc mismatch") as ei:
        parse_body(bytes(body))
    assert ei.value.stream == "grads" and ei.value.step == 17


def test_frame_version_rejected():
    body = bytearray(_body(Frame("s", 1, 0, CODEC_RAW, b"x")))
    body[4] = 99                           # version byte
    with pytest.raises(FrameCorruptError, match="unsupported frame version"):
        parse_body(bytes(body))


# ---------------------------------------------------------------------------
# sinks: protocol, seq assignment, rollback
# ---------------------------------------------------------------------------

def test_memory_sink_write_and_decode():
    sink = MemorySink(stream="grads")
    rec = sink.write(3, _tree())
    assert rec["stream"] == "grads" and rec["seq"] == 0 and rec["step"] == 3
    assert sink.write(4, _tree())["seq"] == 1
    (s1, st1, p1), (s2, st2, _) = sink.payloads()
    assert (s1, st1, s2, st2) == ("grads", 3, "grads", 4)
    _assert_tree_equal(_tree(), p1)


def test_sink_is_callable_like_legacy():
    sink = MemorySink()
    rec = sink(5, {"x": 1})
    assert rec["step"] == 5 and sink.frames_written == 1


def test_as_sink_normalizes():
    calls = []
    shim = as_sink(lambda step, payload: calls.append((step, payload)))
    assert isinstance(shim, CallableSink)
    shim.write(1, "p")
    assert calls == [(1, "p")]
    sink = MemorySink()
    assert as_sink(sink) is sink
    with pytest.raises(TypeError, match="must be a transport.Sink"):
        as_sink(42)


def test_seq_rollback_on_failed_write():
    class Flaky(MemorySink):
        fail = True

        def write_frame(self, frame):
            if self.fail:
                raise TransientError("injected")
            super().write_frame(frame)

    sink = Flaky(stream="s")
    with pytest.raises(TransientError):
        sink.write(1, {"a": 1})
    sink.fail = False
    rec = sink.write(1, {"a": 1})     # the retry reuses the seq: no gap
    assert rec["seq"] == 0
    assert sink.write(2, {"a": 2})["seq"] == 1


def test_file_sink_source_round_trip(tmp_path):
    d = str(tmp_path / "frames")
    sink = FileSink(d, stream="grads")
    for step in range(3):
        sink.write(step, {"step": step, "arr": np.full(4, step, np.int32)})
    sink.close()
    frames = list(FileSource(d).frames())
    assert [f.step for f in frames] == [0, 1, 2]
    assert [f.seq for f in frames] == [0, 1, 2]
    out = transport.decode_frame_payload(frames[2])
    np.testing.assert_array_equal(out["arr"], np.full(4, 2, np.int32))


def test_file_source_detects_gap(tmp_path):
    d = str(tmp_path / "frames")
    sink = FileSink(d, stream="grads")
    for step in range(3):
        sink.write(step, {"step": step})
    os.remove(os.path.join(d, "grads", "frame_00000001.tfr"))
    with pytest.raises(StreamGapError) as ei:
        list(FileSource(d).frames())
    assert ei.value.stream == "grads"
    assert (ei.value.expected, ei.value.got) == (1, 2)


def test_file_source_detects_bit_flip(tmp_path):
    d = str(tmp_path / "frames")
    FileSink(d, stream="s").write(1, {"a": 1})
    fn = os.path.join(d, "s", "frame_00000000.tfr")
    raw = bytearray(open(fn, "rb").read())
    raw[-1] ^= 0x01
    open(fn, "wb").write(bytes(raw))
    with pytest.raises(FrameCorruptError, match="crc mismatch"):
        list(FileSource(d).frames())


# ---------------------------------------------------------------------------
# URL scheme
# ---------------------------------------------------------------------------

def test_connect_urls(tmp_path):
    assert isinstance(connect("memory://"), MemorySink)
    fs = connect(f"file://{tmp_path}/out", stream="s")
    assert isinstance(fs, FileSink)
    ts = connect("tcp://127.0.0.1:19999", stream="s")
    assert isinstance(ts, StreamSink)
    assert (ts.host, ts.port) == ("127.0.0.1", 19999)


@pytest.mark.parametrize("url,match", [
    ("no-scheme", "needs a scheme"),
    ("file://", "needs a directory"),
    ("tcp://nohost", "host:port"),
    ("tcp://host:notaport", "host:port"),
    ("carrier-pigeon://x", "unknown transport scheme"),
])
def test_connect_rejects_junk(url, match):
    with pytest.raises(ValueError, match=match):
        connect(url)


def test_materialize_file_rejects_escapes(tmp_path):
    f = Frame("ck", 1, 0, CODEC_FILE,
              transport.pack_file("../escape.bin", b"x"))
    with pytest.raises(TransportError, match="refusing to materialize"):
        transport.materialize_file(f, str(tmp_path))
    f2 = Frame("ck", 1, 0, CODEC_FILE,
               transport.pack_file("/abs/path.bin", b"x"))
    with pytest.raises(TransportError, match="refusing to materialize"):
        transport.materialize_file(f2, str(tmp_path))


def test_send_directory_manifest_last(tmp_path):
    d = tmp_path / "step_000000001"
    d.mkdir()
    (d / "manifest.json").write_bytes(b"{}")
    (d / "shard_0.bin").write_bytes(b"\x01" * 64)
    (d / "zz_late.bin").write_bytes(b"\x02" * 8)
    sink = MemorySink(stream="ck")
    n = transport.send_directory(sink, 1, str(d), prefix="step_000000001")
    assert n == 3
    rels = [transport.unpack_file(f.payload)[0] for f in sink.frames]
    assert rels[-1].endswith("manifest.json")
    root = str(tmp_path / "replica")
    for f in sink.frames:
        transport.materialize_file(f, root)
    assert open(os.path.join(root, "step_000000001", "shard_0.bin"),
                "rb").read() == b"\x01" * 64


# ---------------------------------------------------------------------------
# the streaming wire: socketpair round-trips
# ---------------------------------------------------------------------------

def _pair(stream="grads", check_gaps=True):
    a, b = socket.socketpair()
    return (StreamSink.over_socket(a, stream=stream),
            StreamSource.over_socket(b, check_gaps=check_gaps))


def test_socketpair_round_trip():
    sink, source = _pair()
    try:
        for step in (0, 1, 2):
            sink.write(step, {"step": step, "w": np.arange(6) + step})
        got = [source.recv_frame(timeout=2.0) for _ in range(3)]
        assert [f.step for f in got] == [0, 1, 2]
        assert [f.seq for f in got] == [0, 1, 2]
        out = transport.decode_frame_payload(got[1])
        np.testing.assert_array_equal(out["w"], np.arange(6) + 1)
    finally:
        sink.close(), source.close()


def test_socketpair_interleaved_streams():
    sink, source = _pair()
    try:
        sink.write(0, {"a": 1}, stream="grads")
        sink.write(0, {"b": 2}, stream="spectra")
        sink.write(1, {"a": 3}, stream="grads")
        sink.write(1, {"b": 4}, stream="spectra")
        got = [source.recv_frame(timeout=2.0) for _ in range(4)]
        # per-stream seqs are independent and contiguous
        assert [(f.stream, f.seq) for f in got] == [
            ("grads", 0), ("spectra", 0), ("grads", 1), ("spectra", 1)]
    finally:
        sink.close(), source.close()


def test_socketpair_truncated_frame_is_typed():
    a, b = socket.socketpair()
    source = StreamSource.over_socket(b)
    try:
        wire = pack_frame(Frame("grads", 11, 0, CODEC_RAW, b"0123456789"))
        a.sendall(wire[:len(wire) - 4])       # tear the final bytes
        a.close()                              # EOF mid-frame
        with pytest.raises(FrameCorruptError, match="mid-frame"):
            source.recv_frame(timeout=2.0)
    finally:
        source.close()


def test_socketpair_bit_flip_is_typed_with_stream():
    a, b = socket.socketpair()
    source = StreamSource.over_socket(b)
    try:
        wire = bytearray(pack_frame(Frame("grads", 23, 0, CODEC_RAW,
                                          b"payloadpayload")))
        wire[-2] ^= 0x10
        a.sendall(bytes(wire))
        with pytest.raises(FrameCorruptError, match="crc mismatch") as ei:
            source.recv_frame(timeout=2.0)
        assert ei.value.stream == "grads" and ei.value.step == 23
    finally:
        a.close(), source.close()


def test_socketpair_implausible_length_is_typed():
    a, b = socket.socketpair()
    source = StreamSource.over_socket(b)
    try:
        a.sendall(struct.pack("<I", 0xFFFFFFFF))
        with pytest.raises(FrameCorruptError, match="implausible"):
            source.recv_frame(timeout=2.0)
    finally:
        a.close(), source.close()


def test_reconnect_gap_detected_and_named():
    """Frames lost across a producer reconnect surface as StreamGapError
    naming the stream/step — the seq survives the reconnect because the
    sink (not the connection) owns the counter."""
    listener = StreamSource(port=0)
    sink = connect(listener.address, stream="grads")
    try:
        sink.write(0, {"a": 0})
        assert listener.recv_frame(timeout=2.0).seq == 0
        # simulate dropped writes: burn seqs while disconnected
        sink._next_seq("grads")
        sink._next_seq("grads")
        sink.drop_connection()
        sink.write(5, {"a": 5})               # reconnects, seq 3
        with pytest.raises(StreamGapError) as ei:
            listener.recv_frame(timeout=2.0)
        assert ei.value.stream == "grads"
        assert (ei.value.expected, ei.value.got) == (1, 3)
        assert "2 frame(s) lost" in str(ei.value)
        assert sink.reconnects == 2
    finally:
        sink.close(), listener.close()


def test_reconnect_without_loss_is_clean():
    listener = StreamSource(port=0)
    sink = connect(listener.address, stream="grads")
    try:
        sink.write(0, {"a": 0})
        sink.drop_connection()
        sink.write(1, {"a": 1})               # transparent reconnect
        assert [listener.recv_frame(timeout=2.0).seq for _ in range(2)] \
            == [0, 1]
        assert sink.reconnects == 2
    finally:
        sink.close(), listener.close()


def test_unreachable_consumer_is_transient():
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    port = lst.getsockname()[1]
    lst.close()                               # nobody listening
    sink = StreamSink("127.0.0.1", port, connect_timeout_s=0.2)
    with pytest.raises(TransientError, match="cannot reach"):
        sink.write(0, {"a": 1})
    # the failed write rolled its seq back: a later success starts at 0
    assert sink._seq.get("default", 0) == 0


def test_steering_back_channel():
    sink, source = _pair()
    try:
        sink.write(0, {"a": 1})
        assert source.recv_frame(timeout=2.0) is not None
        assert source.send_control({"task": "gh", "every": 4}) == 1
        msgs = sink.poll_control()
        assert msgs == [{"task": "gh", "every": 4}]
        assert sink.poll_control() == []      # drained
    finally:
        sink.close(), source.close()


def test_bye_frame_closes_cleanly():
    listener = StreamSource(port=0)
    sink = connect(listener.address, stream="s")
    try:
        sink.write(0, {"a": 1})
        assert listener.recv_frame(timeout=2.0) is not None
        sink.close()
        assert listener.recv_frame(timeout=0.5) is None   # BYE, not an error
        assert listener.connections == 0
    finally:
        listener.close()


# ---------------------------------------------------------------------------
# parity: presets behave identically across sink backends
# ---------------------------------------------------------------------------

def _run_grad_health(to=None):
    from repro.core import InSituPlan, Session
    opts = {} if to is None else {"to": to}
    plan = InSituPlan.from_dict({
        "streams": ["grads"],
        "tasks": {"gh": {"stream": "grads", "preset": "grad_health",
                         "every": 2, "placement": "sync",
                         "options": opts}},
    })
    rng = np.random.default_rng(0)
    g = rng.standard_normal(64).astype(np.float32)
    with Session(plan, raise_on_error=True) as session:
        for step in range(6):
            session.emit("grads", step, {"params": g + step})
    return session


def test_preset_parity_across_backends(tmp_path):
    plain = _run_grad_health()
    mem = _run_grad_health("memory://")
    filed = _run_grad_health(f"file://{tmp_path}/gh")

    def reports(s):
        return [(r.step, r.result.stats["global_norm"]) for r in s.results
                if r.task == "gh"]

    assert reports(plain) == reports(mem) == reports(filed)
    # and the transport targets really got the frames
    mem_sink = mem.transport_of("gh")
    assert mem_sink.frames_written == 3
    decoded = transport.decode_frame_payload(mem_sink.frames[0])
    assert decoded["__dataclass__"] == "Artifact"
    assert decoded["fields"]["stats"]["global_norm"] == pytest.approx(
        reports(plain)[0][1], rel=1e-6)
    files = list(FileSource(str(tmp_path / "gh")).frames())
    assert [f.step for f in files] == [0, 2, 4]


def test_plan_rejects_bad_transport_url():
    from repro.core import InSituPlan, Session
    plan = InSituPlan.from_dict({
        "streams": ["grads"],
        "tasks": {"gh": {"stream": "grads", "preset": "grad_health",
                         "options": {"to": "warp://elsewhere"}}},
    })
    with pytest.raises(ValueError, match="unknown transport scheme"):
        Session(plan)


# ---------------------------------------------------------------------------
# hypothesis layer (skips when hypothesis is absent)
# ---------------------------------------------------------------------------

_streams = st.text(
    alphabet=st.characters(min_codepoint=33, max_codepoint=126),
    min_size=1, max_size=24)


@settings(max_examples=50, deadline=None)
@given(stream=_streams, step=st.integers(-2**62, 2**62),
       seq=st.integers(0, 2**32 - 1),
       payload=st.binary(max_size=2048),
       kind=st.sampled_from([0, 1, 2]))
def test_hyp_frame_round_trip(stream, step, seq, payload, kind):
    f = Frame(stream, step, seq, CODEC_RAW, payload, kind=kind)
    assert parse_body(pack_frame(f)[4:]) == f


@settings(max_examples=25, deadline=None)
@given(data=st.binary(min_size=8, max_size=512),
       flip=st.integers(0, 7))
def test_hyp_any_bit_flip_is_caught_or_equal(data, flip):
    """Any single-bit flip anywhere in a frame body either raises a typed
    transport error or (if it hit the length prefix consistency outside
    the body) never silently yields different frame contents."""
    f = Frame("s", 1, 0, CODEC_RAW, data)
    body = bytearray(pack_frame(f)[4:])
    pos = (flip * 97) % len(body)
    body[pos] ^= 1 << (flip % 8)
    try:
        out = parse_body(bytes(body))
    except FrameCorruptError:
        return
    assert out == f      # flip landed back on itself? impossible: fail loud


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5), seed=st.integers(0, 2**16))
def test_hyp_payload_trees_round_trip(n, seed):
    rng = np.random.default_rng(seed)
    tree = {f"k{i}": rng.standard_normal(
        rng.integers(1, 64)).astype(np.float32) for i in range(n)}
    tree["scalars"] = {"i": int(rng.integers(-1000, 1000)), "s": "tag"}
    out = unpack_payload(pack_payload(tree))
    for i in range(n):
        np.testing.assert_array_equal(out[f"k{i}"], tree[f"k{i}"])
    assert out["scalars"] == tree["scalars"]


@settings(max_examples=20, deadline=None)
@given(steps=st.lists(st.integers(0, 1000), min_size=1, max_size=20))
def test_hyp_socketpair_sequences(steps):
    sink, source = _pair(stream="s")
    try:
        for step in steps:
            sink.write(step, {"v": step})
        got = [source.recv_frame(timeout=2.0) for _ in steps]
        assert [f.step for f in got] == steps
        assert [f.seq for f in got] == list(range(len(steps)))
    finally:
        sink.close(), source.close()
