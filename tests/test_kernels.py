"""Pallas spectral-lossy kernels vs the pure-jnp oracle (ref.py).

Covers: shape/dtype sweeps, threshold-by-histogram ≡ threshold-by-sort
(paper finding F7's TPU replacement), error bounds, and hypothesis
properties of the end-to-end codec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.kernels import ops, ref
from repro.kernels import spectral_lossy as K


def _signal(n, seed=0, kind="smooth"):
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 20, n)
    if kind == "smooth":
        x = np.sin(t) + 0.25 * np.sin(9 * t) + 0.02 * rng.standard_normal(n)
    elif kind == "noise":
        x = rng.standard_normal(n)
    else:  # spiky
        x = np.zeros(n)
        x[rng.integers(0, n, size=max(1, n // 50))] = rng.standard_normal(
            max(1, n // 50)) * 10
    return jnp.asarray(x.astype(np.float32))


# ---------------------------------------------------------------------------
# kernel vs oracle, shape sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 2048, 2048 + 256, 40000, 257])
@pytest.mark.parametrize("kind", ["smooth", "noise"])
def test_dct_hist_kernel_matches_oracle(n, kind):
    x = _signal(n, kind=kind)
    xb, _ = ref.blockize(x)
    pad = (-xb.shape[0]) % K.HIST_TILE
    xb = jnp.pad(xb, ((0, pad), (0, 0)))
    y_k, cnt_k, eng_k = K.dct_hist(xb, interpret=True)
    y_o = ref.dct_blocks(xb)
    cnt_o, eng_o = ref.energy_histogram(y_o)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt_k), np.asarray(cnt_o))
    np.testing.assert_allclose(np.asarray(eng_k), np.asarray(eng_o),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("nb", [8, 64, 72, 136])
def test_quant_dequant_kernels_match_oracle(nb):
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.standard_normal((nb, ref.BLOCK)).astype(np.float32))
    t = jnp.asarray(0.3, jnp.float32)
    q_k, s_k = K.threshold_quant(y, t, interpret=True)
    q_o, s_o = ref.quantize_blocks(y, t)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_o))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_o), rtol=1e-6)
    x_k = K.dequant_idct(q_k, s_k, interpret=True)
    x_o = ref.idct_blocks(ref.dequantize_blocks(q_o, s_o))
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_o),
                               rtol=1e-5, atol=1e-5)


def test_dct_hist_tiled_matches_accumulated_histogram():
    """Per-tile rows sum to the accumulated histogram of kernel 1."""
    x = _signal(40000)
    xb, _ = ref.blockize(x)
    pad = (-xb.shape[0]) % K.HIST_TILE
    xb = jnp.pad(xb, ((0, pad), (0, 0)))
    y_t, cnt_t, eng_t = K.dct_hist_tiled(xb, interpret=True)
    y_a, cnt_a, eng_a = K.dct_hist(xb, interpret=True)
    assert cnt_t.shape == (xb.shape[0] // K.HIST_TILE, ref.NBINS)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_a))
    np.testing.assert_allclose(np.asarray(cnt_t).sum(0), np.asarray(cnt_a))
    np.testing.assert_allclose(np.asarray(eng_t).sum(0), np.asarray(eng_a),
                               rtol=1e-5, atol=1e-6)


def test_threshold_quant_per_block_vector_matches_scalar_slices():
    """A vector of per-block thresholds ≡ scalar invocations per segment —
    the contract the fused multi-leaf dispatch relies on."""
    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.standard_normal((24, ref.BLOCK)).astype(np.float32))
    t_vec = jnp.asarray(np.repeat([0.1, 0.5, 1.0], 8).astype(np.float32))
    q_v, s_v = K.threshold_quant(y, t_vec, interpret=True)
    for seg, t in enumerate([0.1, 0.5, 1.0]):
        sl = slice(seg * 8, (seg + 1) * 8)
        q_s, s_s = K.threshold_quant(y[sl], jnp.asarray(t), interpret=True)
        np.testing.assert_array_equal(np.asarray(q_v[sl]), np.asarray(q_s))
        np.testing.assert_array_equal(np.asarray(s_v[sl]), np.asarray(s_s))


def test_fused_packed_kernel_path_matches_per_leaf_kernels():
    """The TPU fused-tree recipe (packed dct_hist_tiled -> segment-summed
    histograms -> per-block-threshold quant), executed in interpret mode,
    reproduces the per-leaf kernel results bit-for-bit."""
    rng = np.random.default_rng(3)
    leaves = [jnp.asarray(rng.standard_normal(n).astype(np.float32))
              for n in (2048, 6000, 512)]
    eps = 1e-2
    blocks = []
    for x in leaves:
        xb, _ = ref.blockize(x)
        xb = jnp.pad(xb, ((0, (-xb.shape[0]) % K.HIST_TILE), (0, 0)))
        blocks.append(xb)
    counts = [b.shape[0] for b in blocks]
    packed = jnp.concatenate(blocks, 0)
    y, _, eng_t = K.dct_hist_tiled(packed, interpret=True)
    tile_seg = np.repeat(np.arange(len(counts)),
                         [c // K.HIST_TILE for c in counts])
    seg_eng = jnp.zeros((len(counts), ref.NBINS), jnp.float32
                        ).at[jnp.asarray(tile_seg)].add(eng_t)
    t_seg = jax.vmap(
        lambda e: ref.threshold_from_histogram(e, eps))(seg_eng)
    block_seg = np.repeat(np.arange(len(counts)), counts)
    q, s = K.threshold_quant(y, t_seg[jnp.asarray(block_seg)],
                             interpret=True)
    off = 0
    for xb, c in zip(blocks, counts):
        y_k, _, eng_k = K.dct_hist(xb, interpret=True)
        t_k = ref.threshold_from_histogram(eng_k, eps)
        q_k, s_k = K.threshold_quant(y_k, t_k, interpret=True)
        np.testing.assert_array_equal(np.asarray(q[off:off + c]),
                                      np.asarray(q_k))
        np.testing.assert_array_equal(np.asarray(s[off:off + c]),
                                      np.asarray(s_k))
        off += c


def test_fused_tree_trace_cache_buckets_shapes():
    """Elastic-mesh contract: leaf resizes that stay inside the same
    power-of-two block bucket reuse the compiled fused kernel instead of
    re-tracing (ROADMAP perf candidate), and stay bit-identical to the
    per-leaf path."""
    policy = lambda k: True    # noqa: E731 - compress every leaf

    # 6100 elems -> 24 blocks -> bucket 32; 8100 elems -> 32 blocks -> 32.
    # Without bucketing these are distinct trace keys (24 vs 32 rows).
    t1 = {"a": jnp.ones(6100, jnp.float32),
          "b": jnp.ones((40, 40), jnp.float32)}
    t2 = {"a": jnp.ones(8100, jnp.float32),
          "b": jnp.ones((41, 40), jnp.float32)}
    ops.spectral_compress_tree(t1, 1e-2, policy)
    size_after_first = ops.packed_tree_cache_size()
    ops.spectral_compress_tree(t2, 1e-2, policy)
    assert ops.packed_tree_cache_size() == size_after_first, \
        "same pow2 buckets must not re-trace the fused tree kernel"

    # a genuinely new bucket (crossing a pow2 boundary) does compile
    t3 = {"a": jnp.ones(20000, jnp.float32),     # 79 blocks -> bucket 128
          "b": jnp.ones((41, 40), jnp.float32)}
    ops.spectral_compress_tree(t3, 1e-2, policy)
    assert ops.packed_tree_cache_size() == size_after_first + 1

    # bucketed fused output stays bit-identical to the per-leaf path
    rng = np.random.default_rng(11)
    t4 = {"a": jnp.asarray(rng.standard_normal(6100).astype(np.float32)),
          "b": jnp.asarray(rng.standard_normal((40, 40))
                           .astype(np.float32))}
    fused = ops.spectral_compress_tree(t4, 1e-2, policy, fused=True)
    plain = ops.spectral_compress_tree(t4, 1e-2, policy, fused=False)
    for key in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(fused[key].q),
                                      np.asarray(plain[key].q))
        np.testing.assert_array_equal(np.asarray(fused[key].scale),
                                      np.asarray(plain[key].scale))


def test_fused_tree_bit_equal_to_per_leaf():
    """Tentpole contract: the single-dispatch fused tree compression is
    bit-identical to the per-leaf path, leaf by leaf."""
    rng = np.random.default_rng(4)
    state = {
        "w": jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32)),
        "opt": {"mu": jnp.asarray(rng.standard_normal(5000)
                                  .astype(np.float32)),
                "nu": jnp.asarray(rng.standard_normal((16, 100))
                                  .astype(np.float32)),
                "mu_b": jnp.asarray(rng.standard_normal(77)
                                    .astype(np.float32))},
    }
    policy = lambda k: "mu" in k or "nu" in k   # noqa: E731
    fused = ops.spectral_compress_tree(state, 1e-2, policy, fused=True)
    plain = ops.spectral_compress_tree(state, 1e-2, policy, fused=False)
    for key in ("mu", "nu", "mu_b"):
        f, p = fused["opt"][key], plain["opt"][key]
        np.testing.assert_array_equal(np.asarray(f.q), np.asarray(p.q))
        np.testing.assert_array_equal(np.asarray(f.scale),
                                      np.asarray(p.scale))
        assert (f.n_elements, f.shape, f.dtype) == \
            (p.n_elements, p.shape, p.dtype)
    # non-selected leaves pass through untouched
    assert fused["w"] is state["w"]
    # roundtrip still honors the codec's error bound
    back = ops.spectral_decompress(fused["opt"]["mu"])
    assert ref.rel_l2_error(state["opt"]["mu"], back) \
        <= ref.error_bound(1e-2) + 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_codec_dtype_sweep(dtype):
    x = _signal(5000).astype(dtype)
    c = ops.spectral_compress(x, 1e-2)
    xh = ops.spectral_decompress(c)
    assert xh.dtype == dtype and xh.shape == x.shape
    err = ref.rel_l2_error(x.astype(jnp.float32), xh.astype(jnp.float32))
    assert err <= ref.error_bound(1e-2) + 0.02  # + dtype rounding slack


# ---------------------------------------------------------------------------
# histogram select ≡ sort select (the F7 TPU adaptation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eps", [1e-1, 1e-2, 1e-3])
@pytest.mark.parametrize("kind", ["smooth", "noise", "spiky"])
def test_histogram_select_equals_sort_select(eps, kind):
    x = _signal(30000, seed=3, kind=kind)
    xb, _ = ref.blockize(x)
    y = ref.dct_blocks(xb)
    _, energies = ref.energy_histogram(y)
    t_hist = ref.threshold_from_histogram(energies, eps)
    t_sort = ref.threshold_by_sort(y, eps)
    total = float(jnp.sum(y * y))
    a = np.abs(np.asarray(y)).reshape(-1)
    dropped_hist = float(np.sum((a[a < float(t_hist)]) ** 2))
    # guarantee: histogram threshold never discards more than the budget
    assert dropped_hist <= (eps * eps) * total * (1 + 1e-5)
    # conservatism: within one bin resolution of the sort-optimal threshold
    if float(t_sort) > 0 and float(t_hist) > 0:
        ratio = float(t_hist) / float(t_sort)
        assert ratio <= 2 ** (80.0 / ref.NBINS) + 1e-6  # one bin width
    kept_hist = float(np.mean(a >= float(t_hist)))
    kept_sort = float(np.mean(a >= float(t_sort)))
    assert kept_hist >= kept_sort - 1e-9  # never keeps fewer than optimal


# ---------------------------------------------------------------------------
# error-bound property (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=16, max_value=4096),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    eps=st.sampled_from([1e-1, 1e-2, 1e-3]),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
def test_roundtrip_error_bound_property(n, seed, eps, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal(n) * scale).astype(np.float32))
    c = ref.compress(x, eps)
    xh = ref.decompress(c)
    assert ref.rel_l2_error(x, xh) <= ref.error_bound(eps) + 1e-5
    assert not np.isnan(np.asarray(xh)).any()


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from([(7,), (33, 5), (4, 4, 17), (256,), (2, 128)]))
def test_shape_preservation_property(shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    c = ops.spectral_compress(x, 1e-2)
    xh = ops.spectral_decompress(c)
    assert xh.shape == tuple(shape)


def test_compression_ratio_on_smooth_data_matches_paper():
    """Paper §IV-B: lossy+lossless removes ~98% at eps=1e-2 on smooth fields."""
    from repro.core import codecs
    x = _signal(200_000, kind="smooth")
    c = ops.spectral_compress(x, 1e-2)
    blob, st_ = codecs.encode(np.asarray(c.q), "zlib")
    stored = len(blob) + int(np.asarray(c.scale).nbytes)
    ratio = (x.nbytes - stored) / x.nbytes
    assert ratio >= 0.95, f"only {ratio:.3f} removed"


def test_zero_input_exact():
    x = jnp.zeros(1000)
    xh = ops.spectral_decompress(ops.spectral_compress(x, 1e-2))
    np.testing.assert_array_equal(np.asarray(xh), np.asarray(x))


def test_constant_input_block_aligned_exact():
    # a constant block is pure DC -> survives any threshold, exact to quant
    x = jnp.full((1024,), 3.25)   # 4 whole blocks, no zero-padding
    xh = ops.spectral_decompress(ops.spectral_compress(x, 1e-2))
    np.testing.assert_allclose(np.asarray(xh), np.asarray(x), atol=0.02)


def test_constant_input_padded_l2_bound():
    # zero-padding makes the tail block a step function (Gibbs ringing);
    # the codec's guarantee is relative-L2, which must still hold
    x = jnp.full((1000,), 3.25)
    c = ops.spectral_compress(x, 1e-2)
    xh = ops.spectral_decompress(c)
    assert ref.rel_l2_error(x, xh) <= ref.error_bound(1e-2)


# ---------------------------------------------------------------------------
# two-level histogram selection (coarse 32 + refine 16)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["smooth", "noise", "spiky"])
@pytest.mark.parametrize("eps", [1e-3, 1e-2, 1e-1, 0.5])
def test_two_level_selector_matches_flat(kind, eps):
    """The coarse+refine selector picks the same quantized bin edge as the
    flat 512-bin selector — the invariant that keeps spectral_compress
    outputs bit-identical across the kernel rework."""
    y = ref.dct_blocks(ref.blockize(_signal(40000, kind=kind))[0])
    _, energies = ref.energy_histogram(y)
    t_flat = ref.threshold_from_histogram(energies, eps)
    t_two = ref.threshold_two_level(y, eps)
    np.testing.assert_array_equal(np.asarray(t_flat), np.asarray(t_two))


@pytest.mark.parametrize("selector", ["histogram", "two_level"])
@pytest.mark.parametrize("case", ["eps_ge_1", "zeros", "single_block"])
def test_selector_edge_cases(selector, case):
    if case == "eps_ge_1":
        x, eps = _signal(4096, kind="noise"), 1.5     # budget >= total energy
    elif case == "zeros":
        x, eps = jnp.zeros(4096), 1e-2
    else:
        x, eps = _signal(ref.BLOCK, kind="smooth"), 1e-2   # exactly one block
    c = ref.compress(x, eps, selector=selector)
    base = ref.compress(x, eps)                       # flat selector
    np.testing.assert_array_equal(np.asarray(c.q), np.asarray(base.q))
    np.testing.assert_array_equal(np.asarray(c.scale), np.asarray(base.scale))
    if case in ("eps_ge_1", "zeros"):
        # drop-everything / no-energy: every coefficient must be zeroed
        assert not np.asarray(c.q).any()


def test_coarse_and_refine_kernels_match_oracle():
    x = _signal(40000, kind="noise")
    xb, _ = ref.blockize(x)
    xb = jnp.pad(xb, ((0, (-xb.shape[0]) % K.HIST_TILE), (0, 0)))
    y_k, cnt_k, eng_k = K.dct_hist_coarse(xb, interpret=True)
    y_o = ref.dct_blocks(xb)
    cnt_o, eng_o = ref.coarse_energy_histogram(y_o)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt_k), np.asarray(cnt_o))
    np.testing.assert_allclose(np.asarray(eng_k), np.asarray(eng_o),
                               rtol=1e-4, atol=1e-6)
    _, cc, _, _ = ref.select_coarse(eng_o, 1e-2)
    fcnt_k, feng_k = K.hist_refine(y_o, cc, interpret=True)
    fcnt_o, feng_o = ref.refine_energy_histogram(y_o, cc)
    np.testing.assert_allclose(np.asarray(fcnt_k), np.asarray(fcnt_o))
    np.testing.assert_allclose(np.asarray(feng_k), np.asarray(feng_o),
                               rtol=1e-4, atol=1e-6)


def test_kernel_two_level_path_threshold_equals_flat():
    """The full kernel recipe (coarse kernel -> select_coarse -> refine
    kernel -> select_fine) lands on the flat selector's threshold exactly."""
    x = _signal(40000, kind="smooth")
    xb, _ = ref.blockize(x)
    xb = jnp.pad(xb, ((0, (-xb.shape[0]) % K.HIST_TILE), (0, 0)))
    eps = 1e-2
    y, _, ce = K.dct_hist_coarse(xb, interpret=True)
    c, cc, base, budget = ref.select_coarse(ce, eps)
    _, fe = K.hist_refine(y, cc, interpret=True)
    t_two = ref.select_fine(fe, c, cc, base, budget)
    _, energies = K.dct_hist(xb, interpret=True)[1:]
    t_flat = ref.threshold_from_histogram(energies, eps)
    np.testing.assert_array_equal(np.asarray(t_two), np.asarray(t_flat))


def test_tiled_rows_segment_sum_parity_with_accumulated():
    """dct_hist's grid accumulation vs dct_hist_tiled rows segment-summed —
    the invariant _compress_tree_packed relies on but never asserts
    directly. y and the (integer-valued) counts must match BITWISE; the
    energy sums may differ by an ulp per bin (the accumulating kernel fuses
    ``+=`` into the dot_general reduction, so its fp association is not an
    ordered sum of the rounded tile partials), so the bit-identity boundary
    the fused tree path actually depends on is the *selected threshold* —
    asserted bitwise across an eps sweep."""
    x = _signal(64 * ref.BLOCK, kind="noise")
    xb, _ = ref.blockize(x)
    y_t, cnt_t, eng_t = K.dct_hist_tiled(xb, interpret=True)
    y_a, cnt_a, eng_a = K.dct_hist(xb, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_a))
    cnt_seq = np.zeros(ref.NBINS, np.float32)
    eng_seq = np.zeros(ref.NBINS, np.float32)
    for row in range(eng_t.shape[0]):        # same order as the grid walks
        cnt_seq = cnt_seq + np.asarray(cnt_t[row])
        eng_seq = eng_seq + np.asarray(eng_t[row])
    np.testing.assert_array_equal(cnt_seq, np.asarray(cnt_a))
    np.testing.assert_allclose(eng_seq, np.asarray(eng_a), rtol=1e-6)
    seg = jnp.sum(eng_t, axis=0)             # what the fused path feeds in
    for eps in (1e-3, 1e-2, 1e-1, 0.5):
        t_seg = ref.threshold_from_histogram(seg, eps)
        t_acc = ref.threshold_from_histogram(eng_a, eps)
        np.testing.assert_array_equal(np.asarray(t_seg), np.asarray(t_acc))


# ---------------------------------------------------------------------------
# kernel-layer bugfixes: prime block counts + loud shape errors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [7, 13, 97])
def test_prime_block_count_uses_full_tile_and_roundtrips(nb):
    """Prime-sized leaves used to degrade to tile=1 (an nb-step grid of
    single-block launches); now the buffer is padded to the tile multiple
    and sliced back, keeping a full-width tile."""
    tile, pad = K._tile_and_pad(nb, K.QUANT_TILE)
    assert tile == min(K.QUANT_TILE, nb) and tile > 1
    assert (nb + pad) % tile == 0
    rng = np.random.default_rng(nb)
    y = jnp.asarray(rng.standard_normal((nb, ref.BLOCK)).astype(np.float32))
    t = jnp.asarray(0.3, jnp.float32)
    q_k, s_k = K.threshold_quant(y, t, interpret=True)
    q_o, s_o = ref.quantize_blocks(y, t)
    assert q_k.shape == (nb, ref.BLOCK)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_o))
    # scale: amax/127 may fuse to a reciprocal multiply inside the kernel
    # (1-ulp wobble, independent of padding) — oracle parity is 1e-6
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_o), rtol=1e-6)
    # the padding itself must be transparent: manually pre-padding to the
    # tile multiple and slicing must reproduce the internal path BITWISE
    pad = (-nb) % tile
    y_pad = jnp.pad(y, ((0, pad), (0, 0)))
    q_p, s_p = K.threshold_quant(y_pad, t, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_p[:nb]), np.asarray(q_k))
    np.testing.assert_array_equal(np.asarray(s_p[:nb]), np.asarray(s_k))
    x_k = K.dequant_idct(q_k, s_k, interpret=True)
    x_p = K.dequant_idct(q_p, s_p, interpret=True)
    np.testing.assert_array_equal(np.asarray(x_p[:nb]), np.asarray(x_k))
    x_o = ref.idct_blocks(ref.dequantize_blocks(q_o, s_o))
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_o),
                               rtol=1e-5, atol=1e-5)


def test_hist_kernels_raise_valueerror_on_bad_shapes():
    with pytest.raises(ValueError, match="multiple"):
        K.dct_hist(jnp.zeros((7, ref.BLOCK)), interpret=True)
    with pytest.raises(ValueError, match="blocked buffer"):
        K.dct_hist(jnp.zeros((8, 128)), interpret=True)
    with pytest.raises(ValueError, match="multiple"):
        K.dct_hist_tiled(jnp.zeros((9, ref.BLOCK)), interpret=True)
    with pytest.raises(ValueError, match="expected"):
        K.threshold_quant(jnp.zeros((4, 128)), jnp.asarray(0.1),
                          interpret=True)
