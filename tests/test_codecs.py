"""Lossless codec layer: framing, roundtrips, Table II-style ratios."""
import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.core import codecs


@pytest.mark.parametrize("codec", codecs.available())
@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.uint16, np.int64])
def test_roundtrip_all_codecs(codec, dtype, rng):
    arr = (rng.standard_normal((37, 21)) * 100).astype(dtype)
    blob, stats = codecs.encode(arr, codec)
    out = codecs.decode(blob)
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype
    assert stats.raw_bytes == arr.nbytes


def test_frame_self_describing(rng):
    arr = rng.standard_normal((3, 4, 5)).astype(np.float32)
    blob, _ = codecs.encode(arr, "bz2")
    out = codecs.decode(blob)   # no out-of-band metadata
    assert out.shape == (3, 4, 5)


def test_bad_magic_rejected():
    with pytest.raises(ValueError):
        codecs.decode(b"XXXX" + b"\x00" * 32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=3000),
    seed=st.integers(min_value=0, max_value=999),
    codec=st.sampled_from(["zlib", "bz2", "lzma", "none"]),
)
def test_roundtrip_property(n, seed, codec):
    r = np.random.default_rng(seed)
    arr = r.integers(-128, 127, size=n).astype(np.int8)
    out = codecs.decode(codecs.encode(arr, codec)[0])
    np.testing.assert_array_equal(out, arr)


def test_table2_ordering_on_float_data(rng):
    """Paper Table II: plain lossless on float scientific data removes only
    a few percent; zeros-heavy int8 (post-lossy) compresses drastically."""
    floats = rng.standard_normal(200_000).astype(np.float32)
    sparse = np.zeros(200_000, np.int8)
    sparse[rng.integers(0, 200_000, 4000)] = rng.integers(-127, 127, 4000)
    for codec in ("zlib", "bz2", "lzma"):
        cr_float = codecs.compression_ratio(floats, codec).ratio
        cr_sparse = codecs.compression_ratio(sparse, codec).ratio
        assert cr_float < 0.2, f"{codec} on random floats: {cr_float}"
        assert cr_sparse > 0.9, f"{codec} on sparse int8: {cr_sparse}"


def test_compression_stats_eq1():
    s = codecs.CompressionStats("zlib", 100, 25)
    assert s.ratio == pytest.approx(0.75)   # paper Eq. (1)
